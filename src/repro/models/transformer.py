"""Model assembly: any pool architecture from one ``ModelConfig``.

* ``init_params``   — stacked per-layer params ([L, ...] leaves) for
  scan-over-layers (O(1) HLO size at 95 layers), plus embed/head/shared.
* ``forward``       — train/prefill path. Chunked attention beyond 2k
  context; per-layer remat; optional OSSL local-update mode (per-block
  losses behind stop_gradient — the chip's backward-free learning).
* ``init_cache`` / ``decode_step`` — serving path: GQA KV caches (ring
  buffer under SWA), Mamba2 recurrent state, Zamba2 shared-block caches.
* ``lm_loss``       — vocab-sharded cross entropy.

Families: dense | moe | ssm | hybrid | vlm | audio (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import ossl as ossl_lib
from . import layers as L
from . import mamba2 as M
from . import moe as MOE

ATTN_FAMILIES = ("dense", "moe", "vlm", "audio")
CHUNKED_ATTN_THRESHOLD = 2048


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(rng, cfg: ModelConfig, dtype):
    p: Dict[str, Any] = {"norm1": L.rmsnorm_init(cfg.d_model, dtype)}
    if cfg.family in ATTN_FAMILIES:
        r1, r2 = jax.random.split(rng)
        p["attn"] = L.attn_init(r1, cfg, dtype, cfg.sparsity)
        p["norm2"] = L.rmsnorm_init(cfg.d_model, dtype)
        if cfg.family == "moe":
            p["moe"] = MOE.moe_init(r2, cfg, dtype, cfg.sparsity)
        else:
            p["mlp"] = L.mlp_init(r2, cfg, dtype, cfg.sparsity)
    else:  # ssm / hybrid trunk
        p["mixer"] = M.mamba2_init(rng, cfg, dtype, cfg.sparsity)
    return p


def _shared_block_init(rng, cfg: ModelConfig, dtype):
    """Zamba2's shared attention+MLP block (one set of params, reused)."""
    r1, r2 = jax.random.split(rng)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attn_init(r1, cfg, dtype, None),
        "norm2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(r2, cfg, dtype, None),
    }


def init_params(rng, cfg: ModelConfig, local_heads: bool = False) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    r_embed, r_layers, r_head, r_shared, r_local = jax.random.split(rng, 5)
    layer_keys = jax.random.split(r_layers, cfg.n_layers)
    params: Dict[str, Any] = {
        "embed": L.embed_init(r_embed, cfg, dtype),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            r_head, (cfg.d_model, cfg.vocab), dtype) * (cfg.d_model ** -0.5)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        params["shared"] = _shared_block_init(r_shared, cfg, dtype)
    if local_heads:  # OSSL predictor heads, one per block
        hk = jax.random.split(r_local, cfg.n_layers)
        params["local_heads"] = jax.vmap(
            lambda k: ossl_lib.local_head_init(k, cfg.d_model, dtype))(hk)
    return params


def init_params_shaped(rng, cfg: ModelConfig, **kw):
    """eval_shape twin of init_params (no memory) — used by the dry-run."""
    return jax.eval_shape(lambda r: init_params(r, cfg, **kw), rng)


# ---------------------------------------------------------------------------
# rotary helpers
# ---------------------------------------------------------------------------

def _angles_for(cfg: ModelConfig, positions, b, s):
    if cfg.rope_mode == "none":
        return None
    if cfg.rope_mode == "mrope":
        if positions is None:
            pos1 = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            positions = jnp.stack([pos1] * 3)                   # text-degenerate
        return L.mrope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _attn_fn(cfg: ModelConfig, s: int, probe: bool = False):
    from repro.launch import spmd as spmd_lib
    ctx = spmd_lib.current()
    if ctx is not None and ctx.flash_attn and cfg.family in ATTN_FAMILIES:
        return L.attn_full_flash   # TPU runtime path (kernels/flash_attn)
    if s > CHUNKED_ATTN_THRESHOLD:
        return functools.partial(L.attn_full_chunked, q_chunk=512, unroll=probe)
    return L.attn_full


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _scan_or_loop(f, carry, xs, probe: bool):
    """lax.scan, or (probe mode) a python loop with *static* per-layer index
    so layer-position conditionals resolve at trace time and cost_analysis
    sees each layer's ops exactly once."""
    if not probe:
        return jax.lax.scan(f, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        if "idx" in xi:
            xi["idx"] = i           # python int -> static conditionals
        carry, y = f(carry, xi)
        ys.append(y)
    return carry, jax.tree.map(lambda *z: jnp.stack(z), *ys)


def _maybe_cond(pred, true_fn, operand):
    """lax.cond, or a static python branch when pred is concrete (probe)."""
    if isinstance(pred, (bool, int)):
        return true_fn(operand) if pred else operand
    return jax.lax.cond(pred, true_fn, lambda o: o, operand)


def _shared_apply(shared, h, angles, cfg, attn):
    a, _ = attn(shared["attn"], L.rmsnorm(shared["norm1"], h, cfg.norm_eps), angles, cfg)
    h = h + a
    return h + L.mlp_apply(shared["mlp"], L.rmsnorm(shared["norm2"], h, cfg.norm_eps), cfg)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None,
            local_mode: bool = False, probe: bool = False,
            want_hidden: bool = False
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward. Returns (logits [B,S,V], aux) — or the final
    normed hidden states when ``want_hidden`` (chunked-loss path).

    ``probe=True`` is cost-accounting mode (launch/dryrun.py): python loop
    over layers + unrolled inner scans + no remat, so ``cost_analysis()``
    sees every op exactly once per execution. Numerically identical.
    """
    h = L.embed_apply(params["embed"], tokens, embeds)
    b, s, _ = h.shape
    angles = _angles_for(cfg, positions, b, s)
    attn = _attn_fn(cfg, s, probe)
    sp = cfg.sparsity
    shared = params.get("shared")
    every = cfg.hybrid_attn_every

    def block(carry, xs):
        h, lloss = carry
        lp, idx = xs["p"], xs["idx"]
        h_in = jax.lax.stop_gradient(h) if local_mode else h
        if cfg.family in ATTN_FAMILIES:
            a, _ = attn(lp["attn"], L.rmsnorm(lp["norm1"], h_in, cfg.norm_eps), angles, cfg, sp)
            h1 = h_in + a
            if cfg.family == "moe":
                mo, aux = MOE.moe_apply(lp["moe"], L.rmsnorm(lp["norm2"], h1, cfg.norm_eps), cfg, sp)
                h2 = h1 + mo
                moe_aux, moe_drop = aux["moe_aux"], aux["moe_dropped"]
            else:
                h2 = h1 + L.mlp_apply(lp["mlp"], L.rmsnorm(lp["norm2"], h1, cfg.norm_eps), cfg, sp)
                moe_aux = moe_drop = jnp.zeros((), jnp.float32)
        else:
            h2 = h_in + M.mamba2_forward(lp["mixer"], L.rmsnorm(lp["norm1"], h_in, cfg.norm_eps), cfg, sp)
            moe_aux = moe_drop = jnp.zeros((), jnp.float32)
            if shared is not None and every:
                h2 = _maybe_cond((idx + 1) % every == 0,
                                 lambda hh: _shared_apply(shared, hh, angles, cfg, attn),
                                 h2)
        if local_mode:
            head = jax.tree.map(lambda x: x[idx], params["local_heads"]) \
                if "local_heads" in params else None
            if head is not None:
                lloss = lloss + ossl_lib.local_loss(h2, head, ossl_lib.OSSLConfig())
        # sequence-parallel layer boundary (launch/spmd): stored activations
        # shard S over the TP axis — 16x less remat-saved memory per layer
        from repro.launch import spmd as spmd_lib
        h2 = spmd_lib.constrain_seq(h2)
        # IA / pooled-output stats for the activity-dependent gating engine
        ia = jnp.abs(h_in).mean().astype(jnp.float32)
        pooled = h2.mean(axis=(0, 1)).astype(jnp.float32)
        return (h2, lloss), {"moe_aux": moe_aux, "moe_dropped": moe_drop,
                             "ia": ia, "pooled": pooled}

    carry = (h, jnp.zeros((), jnp.float32))
    block_fn = block if (probe or not cfg.remat) else jax.checkpoint(block)
    xs = {"p": params["layers"], "idx": jnp.arange(cfg.n_layers)}
    (h, lloss), aux_stack = _scan_or_loop(block_fn, carry, xs, probe)

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if local_mode:
        h = jax.lax.stop_gradient(h)   # readout learns on frozen features (SL layer)
    if want_hidden:
        logits = h
    else:
        head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
        logits = h @ head
    aux = {"local_loss": lloss,
           "moe_aux": aux_stack["moe_aux"].mean(),
           "moe_dropped": aux_stack["moe_dropped"].mean(),
           "ia": aux_stack["ia"],            # [L]
           "pooled": aux_stack["pooled"]}    # [L, D]
    return logits, aux


def lm_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token cross entropy (vocab dim may be model-sharded)."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def lm_loss_chunked(h: jax.Array, head: jax.Array, targets: jax.Array,
                    chunk: int) -> jax.Array:
    """CE over sequence chunks: logits live as [B, chunk, V] slabs under
    remat — the full [B, S, V] (+f32 copies) is never materialised.
    (§Perf memory-term lever for large-vocab training cells.)"""
    b, s, d = h.shape
    chunk = min(chunk, s)
    nc = s // chunk
    hc = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)       # [nc, B, c, D]
    tc = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, xt):
        hh, tt = xt
        logits = (hh @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        return acc + (logz - gold).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return total / (b * s)


# ---------------------------------------------------------------------------
# serving: cache init / decode step / prefill
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    return min(max_seq, cfg.swa_window) if cfg.swa_window else max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    c = cache_len(cfg, max_seq)
    kv, dh, nl = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ATTN_FAMILIES:
        cache["k"] = jnp.zeros((nl, batch, c, kv, dh), dtype)
        cache["v"] = jnp.zeros((nl, batch, c, kv, dh), dtype)
    else:
        mc = M.mamba2_init_cache(cfg, batch, dtype)
        cache["conv"] = jnp.zeros((nl,) + mc["conv"].shape, mc["conv"].dtype)
        cache["ssm"] = jnp.zeros((nl,) + mc["ssm"].shape, mc["ssm"].dtype)
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            slots = cfg.n_layers // cfg.hybrid_attn_every
            cache["shared_k"] = jnp.zeros((slots, batch, c, kv, dh), dtype)
            cache["shared_v"] = jnp.zeros((slots, batch, c, kv, dh), dtype)
    return cache


def decode_step(params, cache, tokens: jax.Array, cfg: ModelConfig,
                positions=None, probe: bool = False
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. tokens [B] int32 -> (logits [B, V], new cache).
    ``probe``: cost-accounting mode (see forward)."""
    sp = cfg.sparsity
    h = L.embed_apply(params["embed"], tokens[:, None])          # [B,1,D]
    b = h.shape[0]
    pos = cache["pos"]
    if cfg.rope_mode == "mrope":
        p1 = jnp.broadcast_to(pos[None, None], (b, 1))
        angles = L.mrope_angles(jnp.stack([p1] * 3), cfg.head_dim,
                                cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_mode == "rope":
        angles = L.rope_angles(jnp.broadcast_to(pos[None, None], (b, 1)),
                               cfg.head_dim, cfg.rope_theta)
    else:
        angles = None

    every = cfg.hybrid_attn_every
    shared = params.get("shared")

    if cfg.family in ATTN_FAMILIES:
        def block(h, xs):
            lp, ck, cv = xs["p"], xs["k"], xs["v"]
            hn = L.rmsnorm(lp["norm1"], h, cfg.norm_eps)
            a, nk, nv = L.attn_decode(lp["attn"], hn, angles, ck, cv, pos, cfg, sp)
            h = h + a
            hn = L.rmsnorm(lp["norm2"], h, cfg.norm_eps)
            if cfg.family == "moe":
                mo, _ = MOE.moe_apply(lp["moe"], hn, cfg, sp)
                h = h + mo
            else:
                h = h + L.mlp_apply(lp["mlp"], hn, cfg, sp)
            return h, {"k": nk, "v": nv}

        xs = {"p": params["layers"], "k": cache["k"], "v": cache["v"],
              "idx": jnp.arange(cfg.n_layers)}
        h, new = _scan_or_loop(lambda c, x: block(c, x), h, xs, probe)
        new_cache = {"pos": pos + 1, "k": new["k"], "v": new["v"]}
    else:
        def block(carry, xs):
            h, sk, sv = carry
            lp, idx = xs["p"], xs["idx"]
            hn = L.rmsnorm(lp["norm1"], h, cfg.norm_eps)
            mc = {"conv": xs["conv"], "ssm": xs["ssm"]}
            o, nmc = M.mamba2_decode(lp["mixer"], hn, mc, cfg, sp)
            h = h + o

            if shared is not None and every:
                slot = (idx + 1) // every - 1

                def with_shared(args):
                    h, sk, sv = args
                    ck = jax.lax.dynamic_index_in_dim(sk, slot, 0, keepdims=False)
                    cv = jax.lax.dynamic_index_in_dim(sv, slot, 0, keepdims=False)
                    hn = L.rmsnorm(shared["norm1"], h, cfg.norm_eps)
                    a, nk, nv = L.attn_decode(shared["attn"], hn, angles, ck, cv, pos, cfg)
                    h2 = h + a
                    hn2 = L.rmsnorm(shared["norm2"], h2, cfg.norm_eps)
                    h2 = h2 + L.mlp_apply(shared["mlp"], hn2, cfg)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, nk, slot, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, nv, slot, 0)
                    return h2, sk, sv

                if isinstance(idx, int):    # probe: static layer position
                    pred = (idx + 1) % every == 0 and slot >= 0
                else:
                    pred = ((idx + 1) % every == 0) & (slot >= 0)
                h, sk, sv = _maybe_cond(pred, with_shared, (h, sk, sv))
            return (h, sk, sv), {"conv": nmc["conv"], "ssm": nmc["ssm"]}

        sk = cache.get("shared_k", jnp.zeros((1, 1, 1, 1, 1), h.dtype))
        sv = cache.get("shared_v", jnp.zeros((1, 1, 1, 1, 1), h.dtype))
        xs = {"p": params["layers"], "conv": cache["conv"], "ssm": cache["ssm"],
              "idx": jnp.arange(cfg.n_layers)}
        (h, sk, sv), new = _scan_or_loop(block, (h, sk, sv), xs, probe)
        new_cache = {"pos": pos + 1, "conv": new["conv"], "ssm": new["ssm"]}
        if "shared_k" in cache:
            new_cache["shared_k"], new_cache["shared_v"] = sk, sv

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ head)[:, 0, :], new_cache


def prefill(params, cfg: ModelConfig, tokens, max_seq: int):
    """Run the full prompt, build a decode cache. Returns (last_logits, cache).

    Simple implementation: forward for logits + a per-layer re-run to collect
    K/V (attention families). Serving-quality fused prefill is a perf lever,
    not a correctness need, at our scale.
    """
    b, s = tokens.shape
    logits, _ = forward(params, cfg, tokens=tokens)
    cache = init_cache(cfg, b, max_seq)
    if cfg.family in ATTN_FAMILIES:
        h = L.embed_apply(params["embed"], tokens)
        angles = _angles_for(cfg, None, b, s)
        attn = _attn_fn(cfg, s)
        c = cache_len(cfg, max_seq)

        def block(h, lp):
            hn = L.rmsnorm(lp["norm1"], h, cfg.norm_eps)
            a, (k, v) = attn(lp["attn"], hn, angles, cfg, cfg.sparsity)
            h = h + a
            hn = L.rmsnorm(lp["norm2"], h, cfg.norm_eps)
            if cfg.family == "moe":
                mo, _ = MOE.moe_apply(lp["moe"], hn, cfg, cfg.sparsity)
                h = h + mo
            else:
                h = h + L.mlp_apply(lp["mlp"], hn, cfg, cfg.sparsity)
            return h, (k, v)

        _, (ks, vs) = jax.lax.scan(block, h, params["layers"])   # [L,B,S,KV,dh]
        take = min(s, c)
        # last `take` positions land at slots (pos % c) consistent with decode
        sl = [(s - take + i) % c for i in range(take)]
        cache["k"] = cache["k"].at[:, :, jnp.array(sl)].set(ks[:, :, s - take:])
        cache["v"] = cache["v"].at[:, :, jnp.array(sl)].set(vs[:, :, s - take:])
        cache["pos"] = jnp.asarray(s, jnp.int32)
        return logits[:, -1, :], cache
    # SSM/hybrid: replay tokens through decode_step (state is O(1))
    def step(cache, t):
        lg, cache = decode_step(params, cache, t, cfg)
        return cache, lg
    cache, lgs = jax.lax.scan(step, cache, tokens.T)
    return lgs[-1], cache
