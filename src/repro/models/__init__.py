"""Composable model zoo covering the assigned architecture pool.

All models are pure functions over parameter pytrees (init / apply), with
scan-over-layers stacking for compile-time O(1) HLO depth, optional
activation rematerialisation, and the paper's block-N:M sparsity available
on every large projection (models/sparse_linear via configs.SparsityConfig).
"""
from . import layers, moe, mamba2, transformer  # noqa: F401
