"""Primitive layers: norms, rotary embeddings, GQA/SWA attention, MLPs,
and the (optionally block-N:M sparse) linear projection.

Sparse linear parameter forms (configs.SparsityConfig.mode):

* dense    : {"w": [K, O]}
* masked   : {"w": [K, O], "umask": bool [K/block, 1]} — dense storage,
             pattern applied at use; CPU-friendly, used by training tests.
* compact  : {"w": [Kc, O], "rows": int32 [Kc]} — only kept rows stored
             (Kc = K·n/m); forward is gather + dense matmul. This is the
             paper's weight-memory cut, and what the dry-run/roofline sees.
             The pattern is shared across output columns (J=1 — the
             coarsest point on the paper's mask-diversity/efficiency
             trade-off, Fig. 5 middle); per-out-tile diversity lives in the
             Pallas kernel path (kernels/nm_spmm).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SparsityConfig
from repro.core.sparsity import NMSpec, expand_unit_mask, random_unit_mask


# ---------------------------------------------------------------------------
# (sparse) linear
# ---------------------------------------------------------------------------

def linear_init(rng, k: int, o: int, dtype, sp: Optional[SparsityConfig] = None,
                scale: Optional[float] = None):
    scale = (k ** -0.5) if scale is None else scale
    if sp is not None and (k % sp.block or (k // sp.block) % sp.m):
        # input dim doesn't tile into N:M groups (e.g. deepseek w2 with
        # d_ff=22016 -> 172 blocks % m) — stay dense rather than mis-mask.
        sp = None
    if sp is None:
        return {"w": jax.random.normal(rng, (k, o), dtype) * scale}
    r1, r2 = jax.random.split(rng)
    spec = NMSpec(n=sp.n, m=sp.m, block=sp.block, out_tile=o)
    umask = random_unit_mask(r1, spec, k, o)                      # [KB, 1]
    scale = scale / (sp.density ** 0.5)                           # variance-preserving
    if sp.mode == "masked":
        w = jax.random.normal(r2, (k, o), dtype) * scale
        return {"w": w, "umask": umask}
    kc = k * sp.n // sp.m
    rows = _rows_from_umask(umask[:, 0], sp.block, n=sp.n, m=sp.m)
    w = jax.random.normal(r2, (kc, o), dtype) * scale
    return {"w": w, "rows": rows}


def _rows_from_umask(block_mask: jax.Array, block: int, *, n: int, m: int) -> jax.Array:
    """bool [KB] -> int32 [KB·n/m·block] kept dense-row indices (sorted).

    The kept count is static by construction (exactly n per group of m), so
    this traces under vmap/eval_shape — no data-dependent shapes."""
    kb = block_mask.shape[0]
    t = kb * n // m
    blocks = jnp.sort(jnp.argsort(~block_mask, stable=True)[:t])  # kept block ids
    return (blocks[:, None] * block + jnp.arange(block)[None, :]).reshape(-1).astype(jnp.int32)


def linear_apply(p: Dict[str, jax.Array], x: jax.Array, sp: Optional[SparsityConfig] = None):
    """x [..., K] @ W -> [..., O] for any storage form."""
    if "rows" in p:
        return jnp.take(x, p["rows"], axis=-1) @ p["w"]
    if "umask" in p:
        # straight-through estimator: forward sees w·mask, backward sees a
        # DENSE gradient — exactly what DSST's regrow scoring needs (RigL).
        # The optimizer re-masks updates (optim/sparse.build_update_scale).
        maskf = jnp.repeat(p["umask"], _block_rows(p), axis=-2).astype(p["w"].dtype)
        w = p["w"]
        w_used = w - jax.lax.stop_gradient(w * (1.0 - maskf))
        return x @ w_used
    return x @ p["w"]


def _block_rows(p) -> int:
    """Rows per mask unit: K / KB (umask is [KB, 1], w is [K, O])."""
    return p["w"].shape[-2] // p["umask"].shape[-2]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(g: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt((x32 * x32).mean(-1, keepdims=True) + eps)
    return (x32 * inv).astype(x.dtype) * g


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE and Qwen2-VL's M-RoPE)
# ---------------------------------------------------------------------------

def _inv_freq(d_half: int, theta: float, dtype=jnp.float32) -> jax.Array:
    return theta ** (-jnp.arange(0, d_half, dtype=dtype) / d_half)


def rope_angles(pos: jax.Array, d_head: int, theta: float) -> jax.Array:
    """pos [B, S] -> angles [B, S, d_head//2]."""
    return pos[..., None].astype(jnp.float32) * _inv_freq(d_head // 2, theta)


def mrope_angles(pos3: jax.Array, d_head: int, theta: float,
                 sections: Tuple[int, int, int]) -> jax.Array:
    """Multi-axis RoPE: pos3 [3, B, S] (temporal, height, width).

    Frequency slot i takes its position from the section it falls in —
    Qwen2-VL's M-RoPE with the text-degenerate case pos3[0]==pos3[1]==pos3[2].
    """
    d_half = d_head // 2
    assert sum(sections) == d_half, (sections, d_half)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d_half)
    pos_per_freq = pos3[sec_id]                                   # [d_half, B, S]
    pos_per_freq = jnp.moveaxis(pos_per_freq, 0, -1)              # [B, S, d_half]
    return pos_per_freq.astype(jnp.float32) * _inv_freq(d_half, theta)


def apply_rotary(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x [B, S, H, dh], angles [B, S, dh//2] — rotate-half convention."""
    d_half = x.shape[-1] // 2
    x1, x2 = x[..., :d_half], x[..., d_half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, full + cached decode paths)
# ---------------------------------------------------------------------------

def attn_init(rng, cfg: ModelConfig, dtype, sp: Optional[SparsityConfig] = None):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    sp_attn = sp if (sp and "attn" in sp.targets) else None
    return {
        "wq": linear_init(ks[0], d, h * dh, dtype, sp_attn),
        "wk": linear_init(ks[1], d, kv * dh, dtype, sp_attn),
        "wv": linear_init(ks[2], d, kv * dh, dtype, sp_attn),
        "wo": linear_init(ks[3], h * dh, d, dtype, sp_attn),
    }


def _gqa_scores(q, k):
    """q [B,S,H,dh], k [B,T,KV,dh] -> [B, KV, H/KV, S, T]."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, dh)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) / (dh ** 0.5)


def _gqa_out(probs, v):
    """probs [B,KV,G,S,T], v [B,T,KV,dh] -> [B,S,H,dh]."""
    b, kvh, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, kvh * g, -1)


def causal_mask(s: int, window: Optional[int] = None, dtype=jnp.float32) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    ok = j <= i
    if window is not None:
        ok &= (i - j) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(dtype)


def attn_full(p, x, angles, cfg: ModelConfig, sp=None):
    """Training / prefill attention over the whole sequence."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear_apply(p["wq"], x, sp).reshape(b, s, h, dh)
    k = linear_apply(p["wk"], x, sp).reshape(b, s, kv, dh)
    v = linear_apply(p["wv"], x, sp).reshape(b, s, kv, dh)
    if angles is not None:
        q, k = apply_rotary(q, angles), apply_rotary(k, angles)
    scores = _gqa_scores(q, k)
    scores = scores + causal_mask(s, cfg.swa_window, scores.dtype)[None, None, None]
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v).reshape(b, s, h * dh)
    return linear_apply(p["wo"], out, sp), (k, v)


def attn_full_chunked(p, x, angles, cfg: ModelConfig, sp=None, q_chunk: int = 512,
                      unroll: bool = False):
    """Query-chunked causal attention — O(q_chunk · S) live memory.

    A ``lax.scan`` over query chunks keeps the [qc, S] score slab (not the
    full [S, S] one) live; with per-layer remat this bounds attention memory
    at 32k+ contexts. Keys/values stay whole (they are KV-head-sharded on the
    mesh); causal/SWA masking is reconstructed from absolute positions.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qc = min(q_chunk, s)
    assert s % qc == 0, (s, qc)
    q = linear_apply(p["wq"], x, sp).reshape(b, s, h, dh)
    k = linear_apply(p["wk"], x, sp).reshape(b, s, kv, dh)
    v = linear_apply(p["wv"], x, sp).reshape(b, s, kv, dh)
    if angles is not None:
        q, k = apply_rotary(q, angles), apply_rotary(k, angles)

    nq = s // qc
    qs = jnp.moveaxis(q.reshape(b, nq, qc, h, dh), 1, 0)        # [nq, B, qc, H, dh]
    j_abs = jnp.arange(s)

    def chunk_fn(_, inp):
        qi, ci = inp
        i_abs = ci * qc + jnp.arange(qc)
        ok = j_abs[None, :] <= i_abs[:, None]
        if cfg.swa_window is not None:
            ok &= (i_abs[:, None] - j_abs[None, :]) < cfg.swa_window
        scores = _gqa_scores(qi, k)                             # [B,KV,G,qc,S]
        scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        return None, _gqa_out(probs, v)                         # [B,qc,H,dh]

    # unroll=True: cost-probe mode — XLA's cost_analysis counts a while-loop
    # body once, so flop-accounting probes inline the chunk loop.
    _, outs = jax.lax.scan(chunk_fn, None, (qs, jnp.arange(nq)), unroll=unroll)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h * dh)
    return linear_apply(p["wo"], out, sp), (k, v)


def attn_full_flash(p, x, angles, cfg: ModelConfig, sp=None,
                    interpret: bool = False, force_pallas: bool = False):
    """Training/prefill attention through the flash Pallas kernel
    (kernels/flash_attn): O(S·d) HBM traffic instead of the score path.
    TPU runtime path; interpret mode for CPU validation."""
    from repro.kernels.flash_attn.ops import flash_attention
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear_apply(p["wq"], x, sp).reshape(b, s, h, dh)
    k = linear_apply(p["wk"], x, sp).reshape(b, s, kv, dh)
    v = linear_apply(p["wv"], x, sp).reshape(b, s, kv, dh)
    if angles is not None:
        q, k = apply_rotary(q, angles), apply_rotary(k, angles)
    out = flash_attention(q, k, v, cfg.swa_window, interpret, force_pallas)
    out = out.reshape(b, s, h * dh)
    return linear_apply(p["wo"], out, sp), (k, v)


def attn_decode(p, x, angles, cache_k, cache_v, pos, cfg: ModelConfig, sp=None):
    """One-token decode against a (possibly ring-buffered SWA) KV cache.

    ``cache_k/v``: [B, C, KV, dh] with C = min(max_seq, swa_window or inf);
    ``pos``: scalar int32 — tokens already in the cache.
    Returns (out [B,1,D], new_k, new_v).
    """
    b, s, d = x.shape
    assert s == 1
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    c = cache_k.shape[1]
    q = linear_apply(p["wq"], x, sp).reshape(b, 1, h, dh)
    k = linear_apply(p["wk"], x, sp).reshape(b, 1, kv, dh)
    v = linear_apply(p["wv"], x, sp).reshape(b, 1, kv, dh)
    if angles is not None:
        q, k = apply_rotary(q, angles), apply_rotary(k, angles)

    slot = pos % c                                   # ring write (SWA) / linear (full)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))

    scores = _gqa_scores(q, cache_k)                 # [B,KV,G,1,C]
    slot_ids = jnp.arange(c)
    # absolute position each slot currently holds
    abs_pos = jnp.where(slot_ids <= slot, pos - slot + slot_ids,
                        pos - slot + slot_ids - c)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if cfg.swa_window is not None:
        valid &= (pos - abs_pos) < cfg.swa_window
    scores = jnp.where(valid[None, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _gqa_out(probs, cache_v).reshape(b, 1, h * dh)
    return linear_apply(p["wo"], out, sp), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(rng, cfg: ModelConfig, dtype, sp: Optional[SparsityConfig] = None,
             d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    sp_mlp = sp if (sp and "mlp" in sp.targets) else None
    ks = jax.random.split(rng, 3)
    p = {"w1": linear_init(ks[0], d, f, dtype, sp_mlp),
         "w2": linear_init(ks[1], f, d, dtype, sp_mlp)}
    if cfg.act == "swiglu":
        p["w3"] = linear_init(ks[2], d, f, dtype, sp_mlp)
    return p


def mlp_apply(p, x, cfg: ModelConfig, sp: Optional[SparsityConfig] = None):
    sp_mlp = sp if (sp and "mlp" in sp.targets) else None
    h = linear_apply(p["w1"], x, sp_mlp)
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * linear_apply(p["w3"], x, sp_mlp)
    elif cfg.act == "relu2":                       # Nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(h))
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.act)
    return linear_apply(p["w2"], h, sp_mlp)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_init(rng, cfg: ModelConfig, dtype):
    p = {"tok": jax.random.normal(rng, (cfg.vocab, cfg.d_model), dtype) * 0.02}
    if cfg.frontend:
        r2 = jax.random.fold_in(rng, 1)
        p["frontend_proj"] = jax.random.normal(
            r2, (cfg.frontend_dim, cfg.d_model), dtype) * (cfg.frontend_dim ** -0.5)
    return p


def embed_apply(p, tokens=None, embeds=None):
    if embeds is not None:
        return embeds @ p["frontend_proj"]
    return jnp.take(p["tok"], tokens, axis=0)
