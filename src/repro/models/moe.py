"""Mixture-of-Experts layer (Mixtral / Moonlight families).

Token-choice top-k routing with **capacity-bounded scatter dispatch**: tokens
are ranked within their expert (sort + searchsorted, all static shapes) and
scattered into an ``[E, C, D]`` buffer — never the quadratic one-hot
``[tokens, E, C]`` einsum, which is unusable at 32k contexts. FLOPs scale
with ``top_k · capacity_factor``, matching the 6·N_active·D roofline
accounting; overflowing tokens are dropped (contribute 0), standard for
TPU MoE.

Sharding (launch/sharding.py):
* ``moe_shard_experts=False`` (Mixtral: 8 big experts) — TP *inside* each
  expert: ``w1 [E, D, F]`` sharded on F over "model"; dispatch buffer stays
  on the token shards (no all-to-all).
* ``moe_shard_experts=True`` (Moonlight: 64 small experts) — EP: experts
  sharded over "model"; the scatter/gather across expert shards lowers to
  all-to-all on the dispatch buffer (visible in the §Dry-run collective
  schedule).

The paper's techniques compose here: MoE is *itself* structured sparsity at
expert granularity; block-N:M DSST applies inside each expert's FFN (shared
kept-row pattern across experts in compact mode), and per-expert router load
is the natural IA statistic for gated updates.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SparsityConfig
from .layers import linear_init, _rows_from_umask
from repro.core.sparsity import NMSpec, random_unit_mask


def moe_init(rng, cfg: ModelConfig, dtype, sp: Optional[SparsityConfig] = None):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(rng, 5)
    sp_e = sp if (sp and "expert" in sp.targets) else None
    p: Dict[str, jax.Array] = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * (d ** -0.5),
    }

    def expert_mat(rng, k_in, k_out, sp_):
        if sp_ is None or sp_.mode == "masked":
            w = jax.random.normal(rng, (e, k_in, k_out), dtype) * (k_in ** -0.5)
            if sp_ is None:
                return {"w": w}
            spec = NMSpec(n=sp_.n, m=sp_.m, block=sp_.block, out_tile=k_out)
            umask = random_unit_mask(jax.random.fold_in(rng, 7), spec, k_in, k_out)
            return {"w": w, "umask": umask}
        # compact: shared kept-row pattern across experts
        spec = NMSpec(n=sp_.n, m=sp_.m, block=sp_.block, out_tile=k_out)
        umask = random_unit_mask(jax.random.fold_in(rng, 7), spec, k_in, k_out)
        rows = _rows_from_umask(umask[:, 0], sp_.block, n=sp_.n, m=sp_.m)
        kc = k_in * sp_.n // sp_.m
        scale = (k_in * sp_.density) ** -0.5
        return {"w": jax.random.normal(rng, (e, kc, k_out), dtype) * scale, "rows": rows}

    p["w1"] = expert_mat(ks[1], d, f, sp_e)
    p["w2"] = expert_mat(ks[2], f, d, sp_e)
    if cfg.act == "swiglu":
        p["w3"] = expert_mat(ks[3], d, f, sp_e)
    return p


def _expert_apply(pm, x, sp: Optional[SparsityConfig]):
    """x [E, C, K] @ w [E, K', O] for any storage form."""
    if "rows" in pm:
        return jnp.einsum("eck,eko->eco", jnp.take(x, pm["rows"], axis=-1), pm["w"])
    if "umask" in pm:
        # STE as in layers.linear_apply (dense grads for DSST regrow)
        e, k, o = pm["w"].shape
        maskf = jnp.repeat(pm["umask"], k // pm["umask"].shape[-2], axis=-2)
        w = pm["w"]
        w_used = w - jax.lax.stop_gradient(w * (1.0 - maskf.astype(w.dtype)))
        return jnp.einsum("eck,eko->eco", x, w_used)
    return jnp.einsum("eck,eko->eco", x, pm["w"])


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.moe_top_k * cfg.moe_capacity_factor / cfg.moe_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch(flat: jax.Array, router_w: jax.Array, cfg: ModelConfig, c: int):
    """Route flat [N, D] tokens: returns (slot [N*K], gate [N,K], aux pieces)."""
    n, d = flat.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    logits = flat @ router_w.astype(flat.dtype)               # [N, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eids = jax.lax.top_k(probs, k)                      # [N, K]
    gate = (gate / gate.sum(-1, keepdims=True)).astype(flat.dtype)

    # rank of each (token, choice) within its expert — sort-based, static shapes
    flat_e = eids.reshape(-1)                                 # [N*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank_sorted = jnp.arange(n * k) - starts[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    slot = jnp.where(rank < c, flat_e * c + rank, e * c)      # overflow -> trash

    me = probs.mean(axis=0)
    ce_frac = jnp.zeros((e,)).at[flat_e].add(1.0) / (n * k)
    aux = {"moe_aux": (e * jnp.sum(me * ce_frac)).astype(jnp.float32),
           "moe_dropped": (rank >= c).mean().astype(jnp.float32),
           "moe_load": ce_frac}
    return slot, gate, aux


def _expert_ffn(p, ebuf: jax.Array, cfg: ModelConfig, sp) -> jax.Array:
    h = _expert_apply(p["w1"], ebuf, sp)
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * _expert_apply(p["w3"], ebuf, sp)
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return _expert_apply(p["w2"], h, sp)                      # [E, C, D]


def _combine(flat, eout, slot, gate, c):
    n, d = flat.shape
    e = eout.shape[0]
    k = gate.shape[1]
    flat_out = jnp.concatenate([eout.reshape(e * c, d),
                                jnp.zeros((1, d), flat.dtype)])
    routed = flat_out[slot].reshape(n, k, d)
    return (routed * gate[..., None]).sum(axis=1)


def moe_apply(p, x: jax.Array, cfg: ModelConfig,
              sp: Optional[SparsityConfig] = None) -> Tuple[jax.Array, Dict]:
    """x [B, S, D] -> (out [B, S, D], aux dict with load-balance loss/stats).

    Under an active SPMD context with ``shardmap_moe`` the dispatch runs
    inside shard_map so token scatter/gather stays LOCAL per data shard —
    the pjit partitioner otherwise replicates the dispatch buffer across
    shards (EXPERIMENTS.md §Perf, mixtral/moonshot cells)."""
    from repro.launch import spmd as spmd_lib
    ctx = spmd_lib.current()
    compact_experts = any("rows" in p[w] for w in ("w1", "w2") if w in p)
    if ctx is not None and ctx.shardmap_moe and not compact_experts:
        return _moe_apply_shardmap(p, x, cfg, sp, ctx)

    b, s, d = x.shape
    n = b * s
    c = capacity(n, cfg)
    flat = x.reshape(n, d)
    slot, gate, aux = _dispatch(flat, p["router"], cfg, c)
    buf = jnp.zeros((cfg.moe_experts * c + 1, d), x.dtype)
    buf = buf.at[slot].set(jnp.repeat(flat, cfg.moe_top_k, axis=0))
    ebuf = buf[: cfg.moe_experts * c].reshape(cfg.moe_experts, c, d)
    eout = _expert_ffn(p, ebuf, cfg, sp)
    out = _combine(flat, eout, slot, gate, c).reshape(b, s, d)
    return out, aux


def _moe_apply_shardmap(p, x, cfg: ModelConfig, sp, ctx) -> Tuple[jax.Array, Dict]:
    """MoE with data-shard-local dispatch (shard_map).

    * TP-inside-expert (mixtral): expert FFN hidden dim sharded on the TP
      axis; every shard builds the full local dispatch buffer, computes its
      F-slice, and one psum over TP finishes the down-projection — the same
      all-reduce a dense Megatron MLP pays. No token buffers ever cross the
      data axis.
    * EP (moonshot): experts sharded on TP; each shard scatters its local
      tokens into the full [E, C, D] buffer, computes only its E/TP expert
      slice, and the combined output psums over TP (non-local experts
      contribute zeros). Comm = one [n_local, D] all-reduce instead of the
      partitioner's buffer replication.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, tp = ctx.mesh, ctx.tp_axis
    b, s, d = x.shape
    dp_n = 1
    for a in ctx.dp_axes:
        dp_n *= mesh.shape[a]
    dp = ctx.dp_axes if (b % dp_n == 0 and dp_n > 1) else None
    xspec = P(dp, None, None)
    tp_n = mesh.shape[tp]
    e = cfg.moe_experts
    n_loc = (b // dp_n if dp else b) * s
    c = capacity(n_loc, cfg)

    ep = cfg.moe_shard_experts
    if ep:
        wspec = {"w1": {"w": P(tp, None, None)}, "w2": {"w": P(tp, None, None)}}
        if "w3" in p:
            wspec["w3"] = {"w": P(tp, None, None)}
        e_loc = e // tp_n
    else:
        wspec = {"w1": {"w": P(None, None, tp)}, "w2": {"w": P(None, tp, None)}}
        if "w3" in p:
            wspec["w3"] = {"w": P(None, None, tp)}
    in_specs = (xspec, P(None, None), wspec)
    out_specs = (xspec, {"moe_aux": P(), "moe_dropped": P(), "moe_load": P()})

    def body(xl, router, wl):
        nl = xl.shape[0] * xl.shape[1]
        flat = xl.reshape(nl, d)
        slot, gate, aux = _dispatch(flat, router, cfg, c)
        buf = jnp.zeros((e * c + 1, d), xl.dtype)
        buf = buf.at[slot].set(jnp.repeat(flat, cfg.moe_top_k, axis=0))
        ebuf = buf[: e * c].reshape(e, c, d)
        if ep:
            shard = jax.lax.axis_index(tp)
            ebuf_loc = jax.lax.dynamic_slice_in_dim(ebuf, shard * e_loc, e_loc, 0)
            eout_loc = _expert_ffn(wl, ebuf_loc, cfg, sp)      # [E/tp, C, D]
            eout = jnp.zeros((e, c, d), xl.dtype)
            eout = jax.lax.dynamic_update_slice_in_dim(
                eout, eout_loc.astype(xl.dtype), shard * e_loc, 0)
            out = _combine(flat, eout, slot, gate, c)
            out = jax.lax.psum(out, tp)                        # sum expert shards
        else:
            eout = _expert_ffn(wl, ebuf, cfg, sp)              # partial over F
            out = jax.lax.psum(_combine(flat, eout, slot, gate, c), tp)
        if dp:
            aux = {k: (jax.lax.pmean(v, dp) if v.ndim == 0 else
                       jax.lax.pmean(v, dp)) for k, v in aux.items()}
        return out.reshape(xl.shape), aux

    wl = {k: p[k] for k in ("w1", "w2", "w3") if k in p}
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    out, aux = fn(x, p["router"], wl)
    return out, aux
