"""Mamba2 (SSD — state-space duality) block: chunked-parallel training path
and recurrent decode path over the *same* parameters.

The chunked path follows the SSD algorithm (Dao & Gu, arXiv:2405.21060):
within a chunk the recurrence is expanded into an attention-like quadratic
form (MXU-friendly); across chunks a small [H, P, N] state is carried by a
``lax.scan``. The decode path is the plain per-token recurrence — the long-
context (``long_500k``) shape runs entirely through it with O(state) memory.

Equivalence of the two paths is a *test* (tests/test_mamba2.py): the duality
is exactly the kind of claim that silently breaks, so we assert it to 1e-4
over random inputs.

ElfCore tie-in (DESIGN.md §6): the SSM state is a *trace* in the chip's
sense; PC-style local learning reads it directly, and the in/out projections
(the big matmuls) take block-N:M sparsity. The recurrence itself is not a
weight matmul — N:M is inapplicable there and we say so rather than force it.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SparsityConfig
from .layers import linear_apply, linear_init, rmsnorm


def mamba2_init(rng, cfg: ModelConfig, dtype, sp: Optional[SparsityConfig] = None):
    d, di, ns, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * ns
    ks = jax.random.split(rng, 4)
    sp_mlp = sp if (sp and "mlp" in sp.targets) else None
    return {
        # z, xBC, dt — fused input projection (the dominant matmul)
        "in_proj": linear_init(ks[0], d, 2 * di + 2 * ns + h, dtype, sp_mlp),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "d_skip": jnp.ones((h,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(dtype),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": linear_init(ks[2], di, d, dtype, sp_mlp),
    }


def _split_proj(p, x, cfg: ModelConfig, sp):
    di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = linear_apply(p["in_proj"], x, sp)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * ns]
    dt = zxbcdt[..., 2 * di + 2 * ns:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time. xbc [B, S, C], w [W, C]."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def mamba2_forward(p, x: jax.Array, cfg: ModelConfig,
                   sp: Optional[SparsityConfig] = None,
                   unroll: bool = False) -> jax.Array:
    """Chunked SSD over a full sequence. x [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    di, ns, h, pdim, q = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_chunk
    assert s % q == 0, (s, q)
    nc = s // q
    sp_mlp = sp if (sp and "mlp" in sp.targets) else None

    z, xbc, dt = _split_proj(p, x, cfg, sp_mlp)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(b, s, h, pdim)
    bm = xbc[..., di: di + ns]
    cm = xbc[..., di + ns:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    da = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt          # [B, S, H] (<=0)

    # chunk views
    xdt = (xs.astype(jnp.float32) * dt[..., None]).reshape(b, nc, q, h, pdim)
    bm_c = bm.astype(jnp.float32).reshape(b, nc, q, ns)
    cm_c = cm.astype(jnp.float32).reshape(b, nc, q, ns)
    da_c = da.reshape(b, nc, q, h)
    cs = jnp.cumsum(da_c, axis=2)                               # inclusive [B,NC,Q,H]

    # intra-chunk quadratic ("attention") term
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]           # [B,NC,Q_i,Q_j,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    y_diag = jnp.einsum("bcin,bcjn,bcijh,bcjhp->bcihp", cm_c, bm_c, l_mat, xdt)

    # per-chunk local end-state and total decay
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)               # [B,NC,Q,H]
    local_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bm_c, decay_to_end, xdt)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                      # [B,NC,H]

    # inter-chunk recurrence (small state, lax.scan)
    def scan_fn(s_prev, inp):
        st, dec = inp
        return dec[:, :, None, None] * s_prev + st, s_prev

    s0 = jnp.zeros((b, h, pdim, ns), jnp.float32)
    _, s_prevs = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(local_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=unroll)  # unroll: cost-probe mode (see layers.attn_full_chunked)
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                       # [B,NC,H,P,N]

    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", cm_c, s_prevs, jnp.exp(cs))
    y = (y_diag + y_off).reshape(b, s, h, pdim)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)

    y = rmsnorm(p["norm_g"], y * jax.nn.silu(z), cfg.norm_eps)
    return linear_apply(p["out_proj"], y, sp_mlp)


# ---------------------------------------------------------------------------
# recurrent decode
# ---------------------------------------------------------------------------

def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype):
    di, ns, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * ns
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, pdim, ns), jnp.float32),
    }


def mamba2_decode(p, x: jax.Array, cache: Dict[str, jax.Array], cfg: ModelConfig,
                  sp: Optional[SparsityConfig] = None) -> Tuple[jax.Array, Dict]:
    """One token. x [B, 1, D] -> ([B, 1, D], new cache)."""
    b, s, d = x.shape
    assert s == 1
    di, ns, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    sp_mlp = sp if (sp and "mlp" in sp.targets) else None

    z, xbc, dt = _split_proj(p, x[:, 0, :], cfg, sp_mlp)

    # conv over the rolling window
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B, W, C]
    conv_out = jax.nn.silu((window * p["conv_w"][None]).sum(axis=1) + p["conv_b"])
    new_conv = window[:, 1:, :]

    xs = conv_out[..., :di].reshape(b, h, pdim)
    bm = conv_out[..., di: di + ns]
    cm = conv_out[..., di + ns:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,H]
    da = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt

    xdt = xs.astype(jnp.float32) * dt[..., None]                 # [B,H,P]
    new_ssm = (jnp.exp(da)[:, :, None, None] * cache["ssm"]
               + xdt[..., None] * bm.astype(jnp.float32)[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, cm.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, di).astype(x.dtype)

    y = rmsnorm(p["norm_g"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear_apply(p["out_proj"], y, sp_mlp)[:, None, :]
    return out, {"conv": new_conv, "ssm": new_ssm}
