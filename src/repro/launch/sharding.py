"""Logical-axis sharding rules (MaxText-style) for every pool architecture.

Megatron-pattern tensor parallelism on "model", data parallelism on
("pod","data"):

* embeddings / lm_head: vocab on "model" (sharded softmax cross-entropy);
* attention QKV column-parallel (heads on "model"), O row-parallel;
* MLP up column-parallel, down row-parallel (one all-reduce per block);
* MoE: EP (experts on "model") for many-small-expert configs, TP-inside-
  expert (d_ff on "model") for few-big-expert configs (configs decide);
* Mamba2: in/out projections column/row-parallel; recurrent state sharded
  on the head-dim axis (P) — head count (80) is not divisible by 16, P=64 is;
* KV caches: batch on DP axes, head_dim on "model";
* N:M kept-row index tables: replicated (tiny int32);
* norms/scalars: replicated.

Rules are matched on the path *suffix*; leaves under stacked subtrees
("layers", "local_heads") automatically get a leading ``None`` for the layer
dim, expert tensors get one for E, etc., by right-aligning the rule with the
leaf rank. Divisibility is checked and demoted to replication with a warning
(a rule that silently no-ops is a bug magnet; the dry-run prints demotions).
"""
from __future__ import annotations

import logging
import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from .mesh import dp_axes

log = logging.getLogger(__name__)


def _rules(cfg: ModelConfig) -> Sequence[Tuple[str, Tuple]]:
    """(path regex, right-aligned partition tuple). First match wins."""
    if cfg.moe_shard_experts:      # EP: experts on model
        moe_mat = ("model", None, None)
    else:                          # TP inside experts
        moe_up = (None, None, "model")
        moe_dn = (None, "model", None)
    r: list = [
        # alternatives: first fully-divisible option wins. Embedding prefers
        # d_model sharding: a vocab-sharded table turns the token gather into
        # a full-table all-gather (§Perf, decode cells); D-sharded gathers
        # locally and the [B, D/16] result reshards for free.
        (r"embed/tok$", [(None, "model"), ("model", None)]),
        (r"embed/frontend_proj$", (None, "model")),
        (r"lm_head$", [(None, "model"), ("model", None)]),
        (r"(wq|wk|wv)/w$", (None, "model")),
        (r"(wq|wk|wv)/rows$", (None,)),
        (r"wo/w$", ("model", None)),
        (r"moe/router$", (None, None)),
    ]
    if cfg.family == "moe":
        if cfg.moe_shard_experts:
            r += [(r"moe/(w1|w3|w2)/w$", moe_mat)]
        else:
            r += [(r"moe/(w1|w3)/w$", moe_up), (r"moe/w2/w$", moe_dn)]
    r += [
        (r"(w1|w3)/w$", (None, "model")),
        (r"w2/w$", ("model", None)),
        (r"rows$", (None,)),
        (r"umask$", (None, None)),
        (r"mixer/in_proj/w$", (None, "model")),
        (r"mixer/out_proj/w$", ("model", None)),
        (r"mixer/conv_w$", (None, "model")),
        (r"mixer/conv_b$", ("model",)),
        (r"mixer/norm_g$", ("model",)),
        (r"mixer/(a_log|d_skip|dt_bias)$", (None,)),
        (r"local_heads/p$", (None, "model")),
        (r"(norm1|norm2|final_norm|norm_g)$", (None,)),
    ]
    return r


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for(path_str: str, shape: Tuple[int, ...], cfg: ModelConfig,
             mesh: Mesh) -> P:
    base: Optional[Any] = None
    for pat, spec in _rules(cfg):
        if re.search(pat, path_str):
            base = spec
            break
    candidates = base if isinstance(base, list) else [base if base is not None else ()]

    def fit(b) -> Tuple[P, bool]:
        # right-align: leading stacked dims (layers L, experts E, …) replicate
        full = (None,) * (len(shape) - len(b)) + tuple(b)
        full = full[-len(shape):] if shape else ()
        fixed, clean = [], True
        for dim, ax in zip(shape, full):
            if ax is None:
                fixed.append(None)
            elif dim % mesh.shape[ax] == 0:
                fixed.append(ax)
            else:
                fixed.append(None)
                clean = False
        return P(*fixed), clean

    first = None
    for cand in candidates:
        p, clean = fit(cand)
        if first is None:
            first = p
        if clean:
            return p
    log.warning("demoted sharding for %s %s -> %s", path_str, shape, first)
    return first


def tree_shardings(tree: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """ShapeDtypeStruct/array tree -> NamedSharding tree (same structure)."""
    def one(path, leaf):
        if np.ndim(leaf) == 0 or not hasattr(leaf, "shape"):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for(_path_str(path), leaf.shape, cfg, mesh))
    return jax.tree_util.tree_map_with_path(one, tree)


# ---------------------------------------------------------------------------
# activations / batch / cache
# ---------------------------------------------------------------------------

def opt_state_shardings(opt_tree: Any, params_tree: Any, cfg: ModelConfig,
                        mesh: Mesh) -> Any:
    """ZeRO-1: optimizer moments additionally shard one spare dim over the
    DP axes. Params stay DP-replicated; XLA turns the moment update into a
    per-DP-slice computation plus one param-sized gather — the classic
    ZeRO-1 exchange. Cuts Adam-state memory by the DP width (§Perf,
    deepseek train: 33.7 -> 2.1 GB/device)."""
    axes = dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def one(path, leaf):
        if np.ndim(leaf) == 0 or not hasattr(leaf, "shape"):
            return NamedSharding(mesh, P())
        base = spec_for(_path_str(path), leaf.shape, cfg, mesh)
        if total <= 1:
            return NamedSharding(mesh, base)
        spec = list(base) + [None] * (len(leaf.shape) - len(base))
        for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
            if ax is None and dim % total == 0 and dim >= total:
                spec[i] = axes if len(axes) > 1 else axes[0]
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, opt_tree)


def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    """[B, ...]: batch on DP axes when divisible, replicated otherwise
    (long_500k has B=1 — the data axis idles and the roofline says so)."""
    axes = dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and global_batch % total == 0:
        return P(axes, *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    def one(leaf):
        nd = np.ndim(leaf)
        if nd == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, batch_spec(mesh, leaf.shape[0], nd - 1))
    return jax.tree.map(one, batch)


def cache_shardings(cache: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """KV / SSM caches: [L, B, ...]: B on DP; KV caches shard the *sequence*
    dim on "model" (flash-decode style: per-shard partial attention + tiny
    softmax-stat/output psums — §Perf decode cells; sharding head_dim instead
    turned the score reduction into a per-layer GB-scale all-reduce)."""
    def one(path, leaf):
        ps = _path_str(path)
        nd = np.ndim(leaf)
        if nd == 0:
            return NamedSharding(mesh, P())
        shape = leaf.shape
        dp = batch_spec(mesh, shape[1], 0) if nd > 1 else P(None)
        dpax = dp[0] if len(dp) else None
        spec: list = [None] * nd
        spec[1] = dpax
        model_dim = None
        if re.search(r"(^|/)(k|v|shared_k|shared_v)$", ps):
            # [L, B, C, KV, dh]: prefer C (sequence); fall back to dh
            model_dim = 2 if shape[2] % mesh.shape["model"] == 0 else nd - 1
        elif ps.endswith("ssm"):
            model_dim = nd - 2          # P (head dim), N stays whole
        elif ps.endswith("conv"):
            model_dim = nd - 1          # channels
        if model_dim is not None and shape[model_dim] % mesh.shape["model"] == 0:
            spec[model_dim] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache)


def logits_sharding(mesh: Mesh, global_batch: int, cfg: ModelConfig,
                    with_seq: bool = True) -> NamedSharding:
    bspec = batch_spec(mesh, global_batch, 0)
    dpax = bspec[0] if len(bspec) else None
    vocab_ok = cfg.vocab % mesh.shape["model"] == 0
    dims = (dpax, None, "model" if vocab_ok else None) if with_seq \
        else (dpax, "model" if vocab_ok else None)
    return NamedSharding(mesh, P(*dims))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# serving slot grid (slot-axis rules; consumed by serving/adapt.make_chunk_fn)
# ---------------------------------------------------------------------------
#
# The SNN serving chunk step is per-slot separable — every per-stream
# quantity is a single slot-leading array (``StreamState`` leaves, the
# compact ``[S, L, J, T, bk, bo]`` delta tensor — or its dense
# ``[S, L, Kmax, N]`` baseline; ``slot_spec(0)`` is a rank-agnostic prefix
# so both share one rule — and the ``[S]`` adapt mask) or carries the slot
# axis second (the ``[C, S, n_in]`` event and ``[C, S]`` valid staging
# buffers). Sharding is therefore one rule applied twice: "slots" on the
# slot axis, everything else replicated. The frozen base params replicate —
# under the compact hot path that is the ``{"wc", "idx", "readout"}`` exec
# rep, read-only and small next to the delta grid.

SLOT_AXIS = "slots"


def slot_devices(mesh: Mesh) -> int:
    return mesh.shape[SLOT_AXIS]


def round_up_slots(n_slots: int, mesh: Mesh) -> int:
    """Smallest multiple of the mesh's slot-device count >= ``n_slots``."""
    d = slot_devices(mesh)
    return -(-n_slots // d) * d


def tier_slot_allocation(counts, mesh: Mesh) -> list:
    """Device-aware slot widths for a multi-tier grid: each tier's
    requested slot count is padded to a multiple of the slot-mesh size
    (every device owns an equal shard of every tier) and floored at two
    slots per device (below that XLA:CPU's gemv path changes the
    K-reduction order and costs bit-identity with 1-device) — the same
    rule the single-grid scheduler has always applied, per tier."""
    floor = 2 * slot_devices(mesh)
    return [max(round_up_slots(int(n), mesh), floor) for n in counts]


def check_slot_divisible(n_slots: int, mesh: Mesh) -> None:
    d = slot_devices(mesh)
    if n_slots % d != 0:
        raise ValueError(
            f"n_slots={n_slots} not divisible by the {d}-device slot mesh; "
            f"use round_up_slots ({round_up_slots(n_slots, mesh)})")


def slot_spec(slot_dim: int = 0) -> P:
    """Partition the ``slot_dim``-th axis over "slots", rest replicated."""
    return P(*((None,) * slot_dim), SLOT_AXIS)


def slot_sharding(mesh: Mesh, slot_dim: int = 0) -> NamedSharding:
    return NamedSharding(mesh, slot_spec(slot_dim))


def stream_shardings(tree: Any, mesh: Mesh) -> Any:
    """Slot-leading NamedShardings for StreamState / delta pytrees (every
    leaf has the slot axis first — the lane-surgery layout invariant)."""
    return jax.tree_util.tree_map(lambda _: slot_sharding(mesh), tree)


def chunk_step_specs(want_factors: bool = True) -> Tuple[Tuple, Tuple]:
    """shard_map specs for ``fn(params, deltas, state, events, valid,
    adapt_mask) -> (deltas, state, metrics)``.

    Pytree-prefix form: ``P()`` replicates the whole params tree, one
    slot-leading spec covers every StreamState leaf; ``ChunkMetrics`` needs
    per-field specs because ``logits``/``window_end`` carry the slot axis
    second. Zero collectives inside the step — each device advances only
    its slot shard.

    ``want_factors`` mirrors the static flag on ``make_chunk_fn``: when
    False the metrics carry no DSST factor leaves (``pre_mag``/``post_mag``
    are None) and the spec tree matches; when True the factors leave the
    shard-mapped step per-slot (``[S, L, ·]`` — the slot reduction happens
    *outside* shard_map, see ``chunk_step_shardings``).
    """
    from repro.core.snn import ChunkMetrics
    s0, s1 = slot_spec(0), slot_spec(1)
    fac = s0 if want_factors else None
    metrics = ChunkMetrics(
        logits=s1, window_end=s1, sop_forward=s0, sop_wu=s0,
        sop_wu_offered=s0, gate_opened=s0, gate_offered=s0,
        local_loss=s0, steps=s0, pre_mag=fac, post_mag=fac)
    in_specs = (P(), s0, s0, s1, s1, s0)
    out_specs = (s0, s0, metrics)
    return in_specs, out_specs


def chunk_step_shardings(mesh: Mesh,
                         want_factors: bool = True) -> Tuple[Tuple, Tuple]:
    """The chunk-fn jit's in/out NamedShardings.

    Mostly ``chunk_step_specs`` as shardings, with one deliberate
    difference: the jitted chunk fn slot-reduces the DSST factors with the
    order-fixed ``engine.ordered_slot_sum`` *after* the shard-mapped step,
    so by the time they are jit outputs they have no slot axis — they
    replicate (``P()``), ``[L, Kmax]`` / ``[L, N]`` and a few KB per grid
    step instead of an ``[S, L, ·]`` device→host transfer.
    """
    in_specs, out_specs = chunk_step_specs(want_factors)
    as_sh = lambda tree: jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), tree)
    in_sh, out_sh = as_sh(in_specs), as_sh(out_specs)
    if want_factors:
        rep = replicated(mesh)
        out_sh = (out_sh[0], out_sh[1],
                  out_sh[2]._replace(pre_mag=rep, post_mag=rep))
    return in_sh, out_sh
