"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod). Multi-pod adds a
leading "pod" axis: (pod=2, data=16, model=16) = 512 chips; the pod axis
carries only data parallelism (gradient all-reduce), which is the axis
layout that extends to N pods — DCN-ish links only ever see the pod axis.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run pins the device count before any jax
init; tests/benches see 1 device).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} (dryrun.py sets this)")
    return jax.sharding.Mesh(
        np.asarray(devs[:need]).reshape(shape), axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Whatever this process has (tests / local runs): (data=N/model, model)."""
    devs = jax.devices()
    data = len(devs) // model
    return jax.sharding.Mesh(
        np.asarray(devs[: data * model]).reshape(data, model), ("data", "model"))


def make_serving_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """1-D ``("slots",)`` mesh for the sharded serving slot grid.

    The event-stream chunk step is per-slot separable (no collectives), so
    the only useful serving topology is a flat slot axis over every device
    this host can see — the software analogue of replicating the on-chip
    learning datapath across cores with strictly core-local state.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if len(devs) < n:
        raise RuntimeError(
            f"serving mesh needs {n} devices, found {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("slots",))


def dp_axes(mesh: jax.sharding.Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: jax.sharding.Mesh) -> int:
    return math.prod(mesh.shape[a] for a in dp_axes(mesh))
