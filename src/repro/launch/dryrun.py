import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import/init: jax locks the device count on first use.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (benchmarks/artifacts/<cell>.json):

* proof of compile on the production meshes — (16,16) single-pod and
  (2,16,16) multi-pod (the "pod" axis must shard);
* ``memory_analysis()`` — per-device bytes (args/outputs/temps): fits-check;
* ``cost_analysis()``   — per-device HLO FLOPs + bytes accessed;
* the collective schedule parsed from the optimized HLO: per-op type,
  payload bytes, group sizes, and ring-model wire bytes per device —
  the §Roofline collective term reads these.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out benchmarks/artifacts [--sparsity] [--force]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.configs.base import ModelConfig, ShapeConfig, SparsityConfig
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import make_serve_step
from repro.launch.train import TrainHParams, make_train_step
from repro.models import transformer as T
from repro.optim import adamw_init

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
          "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
          "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(?P<rtype>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^,]*\}|\[\d+,\d+\]<=\[[^\]]*\])")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dtype, 4)


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return n_devices
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, len([x for x in first.split(",") if x.strip() != ""]))
    m2 = re.match(r"\[(\d+),(\d+)\]", g)
    if m2:
        return int(m2.group(2))
    return n_devices


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Any]:
    """Sum payloads per collective type from optimized HLO.

    Ring-model wire bytes per device: all-gather (G-1)/G·result;
    reduce-scatter (G-1)·result; all-reduce 2·(G-1)/G·payload;
    all-to-all (G-1)/G·payload; collective-permute = payload.
    Async ``-start`` ops report a (operand, result) tuple — we take the last
    element as the payload.
    """
    per_op: Dict[str, Dict[str, float]] = {}
    total_payload = 0.0
    total_wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rtype = m.group("rtype")
        shapes = _SHAPE_RE.findall(rtype)
        if not shapes:
            continue
        dtype, dims = shapes[-1]          # tuple -> result buffer
        rbytes = _shape_bytes(dtype, dims)
        g = _group_size(line, n_devices)
        if op == "all-gather":
            wire = rbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = rbytes * (g - 1)
        elif op == "all-reduce":
            wire = 2 * rbytes * (g - 1) / g
        elif op == "all-to-all":
            wire = rbytes * (g - 1) / g
        else:  # collective-permute
            wire = rbytes
        d = per_op.setdefault(op, {"count": 0, "payload_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["payload_bytes"] += rbytes
        d["wire_bytes"] += wire
        total_payload += rbytes
        total_wire += wire
    return {"per_op": per_op, "payload_bytes": total_payload,
            "wire_bytes_per_device": total_wire}


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend:  # vlm/audio: precomputed patch/frame embeddings (stub)
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                                   jnp.dtype(cfg.dtype)),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "prefill":
        if cfg.frontend:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                                   jnp.dtype(cfg.dtype)),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(lambda: T.init_cache(cfg, b, s))
    return {"tokens": jax.ShapeDtypeStruct((b,), i32), "cache": cache}


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------

def _lower_one(cfg: ModelConfig, shape: ShapeConfig, mesh, hp: TrainHParams,
               probe: bool):
    """Lower (not compile) the cell's step for ``cfg`` (possibly a probe-
    shrunk layer count)."""
    rng = jax.random.PRNGKey(0)
    params_shapes = T.init_params_shaped(rng, cfg)
    p_sh = SH.tree_shardings(params_shapes, cfg, mesh)
    spec = input_specs(cfg, shape)
    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        if hp.zero1:  # ZeRO-1: moments DP-sharded
            o_sh = SH.opt_state_shardings(opt_shapes, params_shapes, cfg, mesh)
        else:
            o_sh = SH.tree_shardings(opt_shapes, cfg, mesh)
        from repro.optim.sparse import SparseTrainState
        ss_shapes = jax.eval_shape(
            lambda: SparseTrainState.init(cfg.n_layers, cfg.d_model))
        ss_sh = jax.tree.map(lambda _: SH.replicated(mesh), ss_shapes)
        batch = dict(spec)
        b_sh = SH.batch_shardings(batch, mesh)
        step = make_train_step(cfg, hp, probe=probe)
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, ss_sh, b_sh),
                     out_shardings=(p_sh, o_sh, ss_sh, None),
                     donate_argnums=(0, 1))
        return fn.lower(params_shapes, opt_shapes, ss_shapes, batch)
    if shape.kind == "prefill":
        def fwd(params, batch):
            logits, _ = T.forward(params, cfg, tokens=batch.get("tokens"),
                                  embeds=batch.get("embeds"), probe=probe)
            return logits
        batch = {k: v for k, v in spec.items() if k != "labels"}
        b_sh = SH.batch_shardings(batch, mesh)
        out_sh = SH.logits_sharding(mesh, shape.global_batch, cfg)
        fn = jax.jit(fwd, in_shardings=(p_sh, b_sh), out_shardings=out_sh)
        return fn.lower(params_shapes, batch)
    # decode
    serve = make_serve_step(cfg, probe=probe)
    cache_shapes = spec["cache"]
    c_sh = SH.cache_shardings(cache_shapes, cfg, mesh)
    tok_sh = SH.batch_shardings(spec["tokens"], mesh)
    out_sh = (SH.logits_sharding(mesh, shape.global_batch, cfg,
                                 with_seq=False), c_sh)
    fn = jax.jit(serve, in_shardings=(p_sh, c_sh, tok_sh),
                 out_shardings=out_sh, donate_argnums=(1,))
    return fn.lower(params_shapes, cache_shapes, spec["tokens"])


def _costs(compiled, n_dev) -> Dict[str, Any]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jaxlib: one dict per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": parse_collectives(compiled.as_text(), n_dev)}


def _probe_group(cfg: ModelConfig) -> int:
    """Layer-repeat period: hybrids repeat (every mamba + 1 shared) groups."""
    return cfg.hybrid_attn_every if (cfg.family == "hybrid"
                                     and cfg.hybrid_attn_every) else 1


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               hp: Optional[TrainHParams] = None,
               cost_probes: bool = True) -> Dict[str, Any]:
    """Compile the real (scan+remat) program, then reconstruct exact per-step
    costs from two unrolled probe compiles.

    XLA's ``cost_analysis`` counts a while-loop body ONCE, so the scan-over-
    layers program under-reports FLOPs/bytes/collectives by ~n_layers.
    Probes at (g, 2g) layers (g = layer-repeat group) are fully unrolled;
    ``total = head + (L/g)·(cost(2g) − cost(g))``, ``head = 2·cost(g) − cost(2g)``.
    """
    import dataclasses as _dc
    hp = hp or TrainHParams()
    n_dev = mesh.devices.size
    rec: Dict[str, Any] = {"arch": cfg.name, "shape": shape.name,
                           "mesh": "x".join(map(str, mesh.devices.shape)),
                           "n_devices": int(n_dev), "kind": shape.kind,
                           "n_layers": cfg.n_layers}

    with mesh:
        t0 = time.time()
        lowered = _lower_one(cfg, shape, mesh, hp, probe=False)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1

        raw = _costs(compiled, n_dev)
        rec["raw"] = {"flops_per_device": raw["flops"],
                      "bytes_per_device": raw["bytes"],
                      "collectives": raw["coll"]}
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                           + ma.output_size_in_bytes
                                           + ma.temp_size_in_bytes
                                           - ma.alias_size_in_bytes),
            }

        if cost_probes:
            g = _probe_group(cfg)
            t2 = time.time()
            cost_pair = []
            # probes run microbatch=1: grad accumulation is a lax.scan whose
            # body cost_analysis would count once; total FLOPs are identical.
            hp_probe = _dc.replace(hp, microbatch=1)
            for nl in (g, 2 * g):
                pcfg = _dc.replace(cfg, n_layers=nl, remat=False)
                pl = _lower_one(pcfg, shape, mesh, hp_probe, probe=True)
                cost_pair.append(_costs(pl.compile(), n_dev))
            c1, c2 = cost_pair
            groups = cfg.n_layers / g
            def corr(k):
                body = c2[k] - c1[k]
                return max(0.0, (2 * c1[k] - c2[k])) + groups * body
            rec["flops_per_device"] = corr("flops")
            rec["bytes_per_device"] = corr("bytes")
            w1 = c1["coll"]["wire_bytes_per_device"]
            w2 = c2["coll"]["wire_bytes_per_device"]
            rec["collective_wire_bytes_per_device"] = (
                max(0.0, 2 * w1 - w2) + groups * (w2 - w1))
            p1 = c1["coll"]["payload_bytes"]
            p2 = c2["coll"]["payload_bytes"]
            rec["collective_payload_bytes"] = (
                max(0.0, 2 * p1 - p2) + groups * (p2 - p1))
            rec["collectives_probe_2g"] = c2["coll"]["per_op"]
            rec["probe_s"] = time.time() - t2
        else:
            rec["flops_per_device"] = raw["flops"]
            rec["bytes_per_device"] = raw["bytes"]
            rec["collective_wire_bytes_per_device"] = \
                raw["coll"]["wire_bytes_per_device"]
            rec["collective_payload_bytes"] = raw["coll"]["payload_bytes"]
        rec["collectives"] = raw["coll"]
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def cell_id(arch: str, shape: str, mesh_name: str, tag: str = "") -> str:
    return f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sparsity", action="store_true",
                    help="lower with compact block-N:M on MLP projections")
    ap.add_argument("--mode", default="backprop", choices=["backprop", "local"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="",
                    help="comma list: seq (sequence-parallel boundaries), "
                         "moe (shard_map dispatch), losschunk[:N] (chunked CE)")
    args = ap.parse_args()
    opts = {"seq_shard": False, "shardmap_moe": False, "loss_chunk": 0}
    hp_kw = {}
    for o in filter(None, args.opt.split(",")):
        if o == "seq":
            opts["seq_shard"] = True
        elif o == "moe":
            opts["shardmap_moe"] = True
        elif o.startswith("losschunk"):
            opts["loss_chunk"] = int(o.split(":")[1]) if ":" in o else 512
        elif o == "zero1":
            hp_kw["zero1"] = True
        elif o.startswith("mb"):
            hp_kw["microbatch"] = int(o.split(":")[1])

    os.makedirs(args.out, exist_ok=True)
    archs = C.ARCH_IDS if args.arch == "all" else [C.normalize(args.arch)]
    shapes = list(C.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        cfg = C.get_config(arch)
        if args.sparsity:
            cfg = cfg.with_sparsity(SparsityConfig(n=2, m=8, block=128,
                                                   targets=("mlp",), mode="compact"))
        hp = TrainHParams(mode=args.mode, **hp_kw)
        for shape_name in shapes:
            shape = C.SHAPES[shape_name]
            ok, why = C.shape_applicable(cfg, shape)
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                cid = cell_id(arch, shape_name, mesh_name, args.tag)
                path = os.path.join(args.out, cid + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {cid}")
                    n_ok += 1
                    continue
                if not ok:
                    with open(path, "w") as f:
                        json.dump({"arch": cfg.name, "shape": shape_name,
                                   "mesh": mesh_name, "skipped": why}, f, indent=1)
                    print(f"[skip]   {cid}: {why}")
                    n_skip += 1
                    continue
                try:
                    from repro.launch import spmd as spmd_lib
                    mesh = make_production_mesh(multi_pod=multi)
                    cell_opts = dict(opts)
                    if shape.kind != "train":
                        # sequence-parallel boundaries only pay off when
                        # activations are *saved* for backward; on pure
                        # inference they just add resharding traffic
                        # (measured: dense prefill cells regress 2.5x).
                        cell_opts["seq_shard"] = False
                    with spmd_lib.activate(mesh, **cell_opts):
                        rec = lower_cell(cfg, shape, mesh, hp=hp)
                    rec["opts"] = cell_opts
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[ok]     {cid}: compile {rec['compile_s']:.1f}s "
                          f"flops/dev {rec['flops_per_device']:.3e} "
                          f"coll wire/dev {rec['collectives']['wire_bytes_per_device']:.3e}B")
                    n_ok += 1
                except Exception as e:  # a failed cell is a bug in our sharding
                    n_fail += 1
                    with open(path + ".err", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"[FAIL]   {cid}: {type(e).__name__}: {e}")
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
