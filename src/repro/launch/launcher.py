"""Fleet launcher: the entry point a real multi-host deployment runs.

On a TPU fleet every host executes the *same* program;
``jax.distributed.initialize`` wires hosts into one runtime (coordinator
address + process index from the scheduler's env). This module provides:

* ``fleet_init()`` — env-driven distributed init (no-op single-host, which
  is what this container exercises; the code path is identical on a pod);
* ``launch_train()`` — mesh + shardings + spmd flags + data shards per
  host + checkpoint/recovery, around launch/train.make_train_step;
* the CLI: ``python -m repro.launch.launcher --arch <id> [--multi-pod]
  [--opt seq,losschunk,zero1,mb:4,moe] ...``

The same binary covers the three fleet roles: trainer (default), server
(``--serve``), and dry-run validator (``--validate`` — lowers without
running, the CI gate a deployment would run before burning pod-hours).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Optional

import numpy as np


def fleet_init() -> tuple[int, int]:
    """Initialize distributed JAX from scheduler env vars.

    Returns (process_index, process_count). Single-host when no coordinator
    is configured — the identical code path runs on a real fleet with
    COORDINATOR_ADDRESS/PROCESS_COUNT/PROCESS_ID set by the scheduler.
    """
    import jax
    coord = os.environ.get("COORDINATOR_ADDRESS")
    if coord:
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(os.environ["PROCESS_COUNT"]),
            process_id=int(os.environ["PROCESS_ID"]))
    return jax.process_index(), jax.process_count()


def launch_train(arch: str, *, multi_pod: bool, opt: str, steps: int,
                 seq_len: int, global_batch: int, ckpt_dir: Optional[str],
                 validate_only: bool) -> int:
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.data.pipeline import PipelineConfig, synthetic_lm_batch
    from repro.launch import sharding as SH, spmd as spmd_lib
    from repro.launch.mesh import make_production_mesh, make_host_mesh, dp_size
    from repro.launch.train import TrainHParams, init_train_state, make_train_step
    from repro.optim import adamw_init

    pid, pcount = fleet_init()
    cfg = C.get_config(arch)

    opts = {"seq_shard": "seq" in opt, "shardmap_moe": "moe" in opt,
            "loss_chunk": 512 if "losschunk" in opt else 0,
            "flash_attn": "flash" in opt}
    hp = TrainHParams(zero1="zero1" in opt,
                      microbatch=next((int(o.split(":")[1]) for o in opt.split(",")
                                       if o.startswith("mb:")), 1))

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
    except RuntimeError:
        mesh = make_host_mesh(model=1)   # local smoke: whatever we have
        cfg = C.make_reduced(cfg)
    if pid == 0:
        print(f"[launcher] {cfg.name} mesh={dict(mesh.shape)} "
              f"hosts={pcount} opts={opts} zero1={hp.zero1} mb={hp.microbatch}")

    if validate_only:
        from repro.launch.dryrun import lower_cell
        from repro.configs.base import ShapeConfig
        shape = ShapeConfig("validate", seq_len, global_batch, "train")
        with spmd_lib.activate(mesh, **opts):
            rec = lower_cell(cfg, shape, mesh, hp=hp, cost_probes=False)
        print(f"[launcher] validate OK: compile {rec['compile_s']:.1f}s, "
              f"peak/dev {rec['memory']['peak_estimate_bytes']/1e9:.1f} GB")
        return 0

    # real run: shard data per host, jit with mesh shardings, train
    params, opt_state, sparse_state = init_train_state(
        jax.random.PRNGKey(0), cfg, hp)
    p_sh = SH.tree_shardings(params, cfg, mesh)
    o_sh = (SH.opt_state_shardings(opt_state, params, cfg, mesh)
            if hp.zero1 else SH.tree_shardings(opt_state, cfg, mesh))
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)
    pcfg = PipelineConfig(vocab=cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch)
    with mesh, spmd_lib.activate(mesh, **opts):
        step_fn = jax.jit(make_train_step(cfg, hp), donate_argnums=(0, 1))
        from repro import checkpoint as ckpt
        start = 0
        if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            start, (params, opt_state, sparse_state), _ = ckpt.restore(
                ckpt_dir, (params, opt_state, sparse_state))
            start += 1
        for step in range(start, steps):
            batch = {k: jnp.asarray(v) for k, v in
                     synthetic_lm_batch(pcfg, step, pid, pcount).items()}
            params, opt_state, sparse_state, m = step_fn(
                params, opt_state, sparse_state, batch)
            if pid == 0 and step % 10 == 0:
                print(f"  step {step} loss {float(m['loss']):.4f}")
            if ckpt_dir and step % 50 == 49 and pid == 0:
                ckpt.save(ckpt_dir, step, (params, opt_state, sparse_state))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", default="seq,losschunk,zero1,mb:4,moe")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--validate", action="store_true",
                    help="lower+compile only (CI gate), no execution")
    args = ap.parse_args(argv)
    return launch_train(args.arch, multi_pod=args.multi_pod, opt=args.opt,
                        steps=args.steps, seq_len=args.seq_len,
                        global_batch=args.global_batch,
                        ckpt_dir=args.ckpt_dir, validate_only=args.validate)


if __name__ == "__main__":
    raise SystemExit(main())
