"""Training step factory + local training loop driver.

``make_train_step`` builds the jit-able pure step for any pool config:

* ``mode="backprop"`` — standard CE + AdamW (the published-architecture
  baseline every dry-run cell lowers);
* ``mode="local"``    — OSSL: per-block predictive+contrastive losses behind
  stop_gradient + supervised readout on frozen features (the chip's
  backward-free learning; no inter-layer backward dependency → no backward
  collectives across stages);
* ``gating``          — activity-dependent per-layer update skipping
  (optim/sparse.compute_gates);
* ``dsst_every``      — connectivity prune/regrow for masked N:M configs.

``run_training`` is the single-host loop used by examples/tests: pipeline,
checkpoints, recovery hooks. The multi-pod path is the same step function
jit-ted with the production mesh shardings (launch/dryrun.py proves it
lowers & compiles; a real fleet would land here with runtime devices).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.gating import GatingConfig
from repro.models import transformer as T
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         SparseTrainState, gated_scale_tree, lm_dsst_event)
from repro.optim.sparse import compute_gates


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    mode: str = "backprop"            # "backprop" | "local"
    gating: Optional[GatingConfig] = None
    dsst_every: int = 0               # 0 = static connectivity
    moe_aux_weight: float = 0.01
    microbatch: int = 1               # grad-accumulation splits of the batch
    #   (activation/logit memory scales 1/microbatch; §Perf memory lever)
    zero1: bool = False               # DP-shard optimizer moments (ZeRO-1)


class TrainState:
    """Bundled (params, opt, sparse) — kept as a plain tuple in jit calls."""


def make_train_step(cfg: ModelConfig, hp: TrainHParams, probe: bool = False):
    local = hp.mode == "local"

    def loss_fn(params, batch):
        from repro.launch import spmd as spmd_lib
        ctx = spmd_lib.current()
        chunked = bool(ctx and ctx.loss_chunk) and not cfg.tie_embeddings
        logits, aux = T.forward(
            params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            local_mode=local, probe=probe, want_hidden=chunked)
        if chunked:  # logits is the hidden stream; CE in [B, chunk, V] slabs
            ce = T.lm_loss_chunked(logits, params["lm_head"], batch["labels"],
                                   ctx.loss_chunk)
        else:
            ce = T.lm_loss(logits, batch["labels"])
        loss = ce + hp.moe_aux_weight * aux["moe_aux"]
        if local:
            loss = loss + aux["local_loss"]
        return loss, (ce, aux)

    def _grad(params, batch):
        # allow_int: mask/index leaves (bool/int32) ride along with float0 grads
        return jax.value_and_grad(loss_fn, has_aux=True, allow_int=True)(
            params, batch)

    def train_step(params, opt_state, sparse_state: SparseTrainState, batch):
        if hp.microbatch > 1:
            # gradient accumulation: batch -> microbatch slices scanned with
            # running-mean grads; activation memory scales 1/microbatch.
            k = hp.microbatch
            mb = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

            def acc_fn(carry, mbatch):
                (loss, (ce, aux)), g = _grad(params, mbatch)
                gsum, lsum, cesum, auxl = carry
                gsum = jax.tree.map(
                    lambda a, b: a + (b.astype(jnp.float32) / k
                                      if jnp.issubdtype(b.dtype, jnp.floating)
                                      else a * 0),
                    gsum, g)
                return (gsum, lsum + loss / k, cesum + ce / k,
                        jax.tree.map(lambda a, b: a + b / k, auxl, aux)), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if jnp.issubdtype(p.dtype, jnp.floating)
                else jnp.zeros((), jnp.float32), params)
            aux0 = jax.eval_shape(lambda b: _grad(params, b)[0][1][1],
                                  jax.tree.map(lambda x: x[0], mb))
            aux0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aux0)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc_fn, (g0, jnp.zeros(()), jnp.zeros(()), aux0), mb)
        else:
            (loss, (ce, aux)), grads = _grad(params, batch)

        # --- activity-dependent gated updates (ElfCore WU gating at LM scale)
        if hp.gating is not None:
            gates, sparse_state = compute_gates(
                sparse_state, aux["ia"], aux["pooled"], hp.gating)
            scale = gated_scale_tree(params, gates, cfg.sparsity)
            gate_frac = gates.mean()
        else:
            scale = gated_scale_tree(params, None, cfg.sparsity) \
                if cfg.sparsity and cfg.sparsity.mode == "masked" else None
            gate_frac = jnp.ones(())

        params, opt_state, om = adamw_update(grads, params, opt_state, hp.opt, scale)

        # --- DSST connectivity event (masked N:M configs): the stacked
        # prune/regrow path shared with the SNN topology epoch; the
        # mask-change fraction surfaces in metrics instead of being dropped
        metrics = {"loss": loss, "ce": ce, "gate_frac": gate_frac,
                   "moe_dropped": aux["moe_dropped"], **om}
        if hp.dsst_every and cfg.sparsity and cfg.sparsity.mode == "masked":
            def ev(p):
                newp, stats = lm_dsst_event(p, grads, cfg.sparsity)
                return newp, stats["dsst_mask_change"]
            params, mask_change = jax.lax.cond(
                opt_state.step % hp.dsst_every == 0, ev,
                lambda p: (p, jnp.zeros(())), params)
            metrics["dsst_mask_change"] = mask_change

        return params, opt_state, sparse_state, metrics

    return train_step


def init_train_state(rng, cfg: ModelConfig, hp: TrainHParams):
    params = T.init_params(rng, cfg, local_heads=(hp.mode == "local"))
    opt_state = adamw_init(params)
    sparse_state = SparseTrainState.init(cfg.n_layers, cfg.d_model)
    return params, opt_state, sparse_state


def run_training(cfg: ModelConfig, hp: TrainHParams, pipeline, n_steps: int,
                 seed: int = 0, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, log_every: int = 10,
                 callback=None) -> Tuple[Any, Dict[str, Any]]:
    """Single-host training loop. Returns (final (params, opt, sparse), history)."""
    from repro import checkpoint as ckpt

    params, opt_state, sparse_state = init_train_state(
        jax.random.PRNGKey(seed), cfg, hp)
    step_fn = jax.jit(make_train_step(cfg, hp), donate_argnums=(0, 1))

    start = 0
    if ckpt_dir:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            start, (params, opt_state, sparse_state), extra = ckpt.restore(
                ckpt_dir, (params, opt_state, sparse_state))
            start += 1

    history: Dict[str, list] = {"loss": [], "step": [], "step_time": []}
    for step in range(start, n_steps):
        _, batch = next(pipeline) if hasattr(pipeline, "__next__") else (None, pipeline(step))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        params, opt_state, sparse_state, m = step_fn(
            params, opt_state, sparse_state, batch)
        m["loss"].block_until_ready()
        dt = time.perf_counter() - t0
        if step % log_every == 0 or step == n_steps - 1:
            history["loss"].append(float(m["loss"]))
            history["step"].append(step)
            history["step_time"].append(dt)
        if callback:
            callback(step, m)
        if ckpt_dir and step % ckpt_every == ckpt_every - 1:
            ckpt.save(ckpt_dir, step, (params, opt_state, sparse_state),
                      extra=pipeline.state() if hasattr(pipeline, "state") else {})
    return (params, opt_state, sparse_state), history
