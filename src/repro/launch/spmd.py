"""SPMD context: opt-in mesh-aware optimizations for the model code.

The model functions are mesh-agnostic by default (tests run them on one
device). The launcher/dry-run activates an ``SpmdCtx`` so the forward pass
can apply distribution optimizations that need axis names:

* ``seq_shard``  — sequence-parallel layer boundaries (Megatron SP): the
  residual stream is sharding-constrained to P(dp, "model", None) between
  blocks, cutting stored-activation memory by the TP width. XLA inserts the
  all-gather before attention/MLP and the reduce-scatter after — the same
  bytes the TP all-reduce already paid, but the *saved* tensors are 16×
  smaller. [§Perf hillclimb, deepseek-67b train_4k]
* ``shardmap_moe`` — dispatch MoE token scatter/gather inside shard_map so
  it stays local to each data shard instead of tripping the SPMD
  partitioner into replicating the dispatch buffer (the mixtral train
  collective-term pathology). [§Perf, mixtral/moonshot]
* ``loss_chunk``  — sequence-chunked cross entropy: logits are produced and
  consumed in [B, chunk, V] slabs under remat, never materialised whole.

Used as:

    with spmd.activate(mesh, seq_shard=True, ...):
        lowered = jit(step).lower(...)
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass
class SpmdCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...]
    tp_axis: str = "model"
    seq_shard: bool = False
    shardmap_moe: bool = False
    loss_chunk: int = 0            # 0 = off; else tokens per chunk
    flash_attn: bool = False       # route attention through the Pallas kernel


_state = threading.local()


def current() -> Optional[SpmdCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def activate(mesh: Mesh, *, seq_shard: bool = False, shardmap_moe: bool = False,
             loss_chunk: int = 0, flash_attn: bool = False):
    from .mesh import dp_axes
    ctx = SpmdCtx(mesh=mesh, dp_axes=dp_axes(mesh), seq_shard=seq_shard,
                  shardmap_moe=shardmap_moe, loss_chunk=loss_chunk,
                  flash_attn=flash_attn)
    prev = current()
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev


def constrain_seq(h: jax.Array) -> jax.Array:
    """Residual stream [B, S, D] -> sequence-sharded on the TP axis."""
    ctx = current()
    if ctx is None or not ctx.seq_shard:
        return h
    b, s, d = h.shape
    if s % ctx.mesh.shape[ctx.tp_axis]:
        return h
    dp = ctx.dp_axes if (b % _dp_size(ctx) == 0 and _dp_size(ctx) > 1) else None
    return jax.lax.with_sharding_constraint(
        h, jax.sharding.NamedSharding(ctx.mesh, P(dp, ctx.tp_axis, None)))


def _dp_size(ctx: SpmdCtx) -> int:
    n = 1
    for a in ctx.dp_axes:
        n *= ctx.mesh.shape[a]
    return n
