"""Serving: batched prefill + decode with KV/SSM caches.

``make_serve_step`` is the jit-able one-token step the decode dry-run cells
lower (``decode_32k``, ``long_500k``). ``generate`` is the local loop used
by examples (greedy or temperature sampling).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def make_serve_step(cfg: ModelConfig, probe: bool = False):
    def serve_step(params, cache, tokens):
        """tokens [B] int32 -> (logits [B, V], new cache)."""
        return T.decode_step(params, cache, tokens, cfg, probe=probe)
    return serve_step


def sample_key_chain(rng: jax.Array, n_new: int) -> jax.Array:
    """Per-position sampling keys: one split of the root into ``n_new`` keys.

    The root itself is never used to sample — consuming it for position 0
    and then re-splitting it for later positions would make the first
    sample share lineage with every subsequent key.
    """
    return jax.random.split(rng, max(n_new, 1))


def generate(params, cfg: ModelConfig, prompt: jax.Array, n_new: int,
             max_seq: Optional[int] = None, temperature: float = 0.0,
             rng: Optional[jax.Array] = None) -> jax.Array:
    """prompt [B, S] -> [B, S + n_new] (greedy when temperature == 0)."""
    b, s = prompt.shape
    max_seq = max_seq or (s + n_new)
    last_logits, cache = T.prefill(params, cfg, prompt, max_seq)
    step = jax.jit(make_serve_step(cfg))

    def pick(logits, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature).astype(jnp.int32)

    rng = rng if rng is not None else jax.random.PRNGKey(0)
    keys = sample_key_chain(rng, n_new)
    toks = [pick(last_logits, keys[0])]
    out_cache = cache
    for i in range(1, n_new):
        logits, out_cache = step(params, out_cache, toks[-1])
        toks.append(pick(logits, keys[i]))
    return jnp.concatenate([prompt, jnp.stack(toks, 1)], axis=1)
