"""Continuous batching for the serving path.

Real serving doesn't get aligned batches: requests arrive at different
times with different prompt/output lengths. ``ContinuousBatcher`` runs the
jit'ted one-token ``serve_step`` over a fixed slot grid (static shapes — no
recompilation) and multiplexes requests onto slots:

* admit: a free slot is claimed, the prompt is replayed token-by-token into
  that slot's cache lane (slot-local prefill — cheap at our scale; a fused
  per-slot prefill is the production upgrade and slots into the same API);
* step: one decode step advances *all* active slots; finished/empty slots
  are masked out of sampling;
* retire: EOS or max-tokens frees the slot.

``SlotGrid`` is the family-agnostic bookkeeping half — slot occupancy,
admit queue, utilization stats — shared with the SNN event-stream scheduler
(``repro.serving.scheduler``), which multiplexes stateful spiking sessions
onto the same fixed-grid pattern. Per-slot position bookkeeping lives in
the batcher; the cache itself is the model's stacked cache with batch =
n_slots. Decode caches are per-slot independent (batch-dim separable) for
every family — attention K/V, SSD state, conv state — which is what makes
slot multiplexing sound; asserted in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Generic, List, Optional, TypeVar

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

Item = TypeVar("Item")


class SlotGrid(Generic[Item]):
    """Fixed-slot occupancy bookkeeping: admit queue, occupancy, stats.

    The grid knows nothing about what lives in a slot — token-decode
    requests and stateful SNN sessions both multiplex through it.
    """

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.occupant: List[Optional[Item]] = [None] * n_slots
        self.queue: List[Item] = []
        self.stats = {"steps": 0, "slot_busy": 0, "admitted": 0, "retired": 0}

    def submit(self, item: Item) -> None:
        self.queue.append(item)

    def free_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupant) if o is None]

    def active_slots(self) -> List[int]:
        return [i for i, o in enumerate(self.occupant) if o is not None]

    def admit(self, on_admit: Optional[Callable[[int, Item], None]] = None):
        """Pop queued items into free slots; returns [(slot, item), ...]."""
        admitted = []
        for slot in self.free_slots():
            if not self.queue:
                break
            item = self.queue.pop(0)
            self.occupant[slot] = item
            self.stats["admitted"] += 1
            if on_admit is not None:
                on_admit(slot, item)
            admitted.append((slot, item))
        return admitted

    def retire(self, slot: int) -> Item:
        item = self.occupant[slot]
        self.occupant[slot] = None
        self.stats["retired"] += 1
        return item

    def tick(self) -> None:
        self.stats["steps"] += 1
        self.stats["slot_busy"] += len(self.active_slots())

    @property
    def drained(self) -> bool:
        return not self.queue and not self.active_slots()

    @property
    def utilization(self) -> float:
        denom = self.stats["steps"] * self.n_slots
        return self.stats["slot_busy"] / denom if denom else 0.0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Fixed-slot continuous batching over the jitted one-token decode.

    ``tracer`` (an ``obs.trace.Tracer``; the no-op ``NULL_TRACER`` by
    default) records ``batch.admit`` and ``batch.decode_step`` spans —
    the latter tagged with how many slots were prefilling vs decoding, so
    a Chrome trace shows prefill replay stealing decode steps. Spans wrap
    host phases only: tracing never changes what the device computes.
    """

    def __init__(self, params, cfg: ModelConfig, n_slots: int, max_seq: int,
                 eos_id: Optional[int] = None, tracer=None):
        from repro.obs.trace import NULL_TRACER
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_seq = n_slots, max_seq
        self.eos_id = eos_id
        self.tracer = tracer or NULL_TRACER
        self.cache = T.init_cache(cfg, n_slots, max_seq)
        # cache["pos"] is global; per-slot positions are ours
        self.grid: SlotGrid[Request] = SlotGrid(n_slots)
        self.slot_pos = np.zeros(n_slots, np.int64)
        self.finished: List[Request] = []
        self.stats = {"tokens_out": 0}

        def _step(params, cache, tokens):
            return T.decode_step(params, cache, tokens, cfg)
        self._step = jax.jit(_step)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request):
        self.grid.submit(req)

    def _admit(self):
        """Slot-local prefill: replay prompt tokens through decode steps.

        The model cache position is global (scalar); slots are kept in
        lock-step by feeding a pad token into inactive slots and ignoring
        their logits. Admission therefore replays prompts in lock-step too —
        simple and correct; per-slot position offsets are bookkept here.
        """
        with self.tracer.span("batch.admit",
                              grid_step=self.grid.stats["steps"] + 1) as sp:
            def on_admit(slot, req):
                self.slot_pos[slot] = 0
                req._fed = 0          # prompt tokens already fed
            sp.set(admitted=len(self.grid.admit(on_admit)))

    def _feed_tokens(self) -> np.ndarray:
        toks = np.zeros(self.n_slots, np.int32)
        for i, req in enumerate(self.grid.occupant):
            if req is None:
                continue
            if req._fed < len(req.prompt):
                toks[i] = req.prompt[req._fed]
            elif req.out:
                toks[i] = req.out[-1]
            else:
                toks[i] = req.prompt[-1]
        return toks

    def _maybe_retire(self, slot: int, req: Request) -> None:
        """Done/EOS check after every emitted token — including the first
        one emitted by the prefill-completion branch (a ``max_new=1``
        request must emit exactly 1 token, and an EOS first token must
        retire immediately, not decode one extra step)."""
        if (len(req.out) >= req.max_new
                or (self.eos_id is not None and req.out[-1] == self.eos_id)):
            req.done = True
            self.finished.append(self.grid.retire(slot))

    def step(self, rng: Optional[jax.Array] = None):
        """One global decode step across all slots."""
        self._admit()
        prefilling = sum(1 for r in self.grid.occupant
                         if r is not None and r._fed < len(r.prompt))
        decoding = len(self.grid.active_slots()) - prefilling
        with self.tracer.span("batch.decode_step",
                              grid_step=self.grid.stats["steps"] + 1,
                              prefill_slots=prefilling,
                              decode_slots=decoding):
            toks = self._feed_tokens()
            logits, self.cache = self._step(self.params, self.cache,
                                            jnp.asarray(toks))
            # the next-token fetch is the decode loop's retire point;
            # lint: ok SYNC01 — autoregressive feedback is synchronous
            nxt = np.asarray(jnp.argmax(logits, -1))
        self.grid.tick()
        for i, req in enumerate(self.grid.occupant):
            if req is None:
                continue
            if req._fed < len(req.prompt):
                req._fed += 1     # still prefilling: logits discarded
                if req._fed == len(req.prompt):
                    req.out.append(int(nxt[i]))   # first generated token
                    self.stats["tokens_out"] += 1
                    self._maybe_retire(i, req)
                continue
            req.out.append(int(nxt[i]))
            self.stats["tokens_out"] += 1
            self._maybe_retire(i, req)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        while not self.grid.drained:
            self.step()
            if self.grid.stats["steps"] >= max_steps:
                break
        return self.finished

    @property
    def utilization(self) -> float:
        return self.grid.utilization
