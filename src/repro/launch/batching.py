"""Continuous batching for the serving path.

Real serving doesn't get aligned batches: requests arrive at different
times with different prompt/output lengths. ``ContinuousBatcher`` runs the
jit'ted one-token ``serve_step`` over a fixed slot grid (static shapes — no
recompilation) and multiplexes requests onto slots:

* admit: a free slot is claimed, the prompt is replayed token-by-token into
  that slot's cache lane (slot-local prefill — cheap at our scale; a fused
  per-slot prefill is the production upgrade and slots into the same API);
* step: one decode step advances *all* active slots; finished/empty slots
  are masked out of sampling;
* retire: EOS or max-tokens frees the slot.

Per-slot position bookkeeping lives in the batcher; the cache itself is the
model's stacked cache with batch = n_slots. Throughput/fairness stats are
exposed for the serving benchmark. Decode caches are per-slot independent
(batch-dim separable) for every family — attention K/V, SSD state, conv
state — which is what makes slot multiplexing sound; asserted in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, params, cfg: ModelConfig, n_slots: int, max_seq: int,
                 eos_id: Optional[int] = None):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_seq = n_slots, max_seq
        self.eos_id = eos_id
        self.cache = T.init_cache(cfg, n_slots, max_seq)
        # cache["pos"] is global; per-slot positions are ours
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.stats = {"steps": 0, "tokens_out": 0, "slot_busy": 0}

        def _step(params, cache, tokens):
            return T.decode_step(params, cache, tokens, cfg)
        self._step = jax.jit(_step)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Slot-local prefill: replay prompt tokens through decode steps.

        The model cache position is global (scalar); slots are kept in
        lock-step by feeding a pad token into inactive slots and ignoring
        their logits. Admission therefore replays prompts in lock-step too —
        simple and correct; per-slot position offsets are bookkept here.
        """
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self.slot_req[slot] = req
            self.slot_pos[slot] = 0
            req._fed = 0          # prompt tokens already fed

    def _feed_tokens(self) -> np.ndarray:
        toks = np.zeros(self.n_slots, np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            if req._fed < len(req.prompt):
                toks[i] = req.prompt[req._fed]
            elif req.out:
                toks[i] = req.out[-1]
            else:
                toks[i] = req.prompt[-1]
        return toks

    def step(self, rng: Optional[jax.Array] = None):
        """One global decode step across all slots."""
        self._admit()
        toks = self._feed_tokens()
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits, -1))
        self.stats["steps"] += 1
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.stats["slot_busy"] += 1
            if req._fed < len(req.prompt):
                req._fed += 1     # still prefilling: logits discarded
                if req._fed == len(req.prompt):
                    req.out.append(int(nxt[i]))   # first generated token
                    self.stats["tokens_out"] += 1
                continue
            req.out.append(int(nxt[i]))
            self.stats["tokens_out"] += 1
            if (len(req.out) >= req.max_new
                    or (self.eos_id is not None and req.out[-1] == self.eos_id)):
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or any(r is not None for r in self.slot_req)):
            self.step()
            if self.stats["steps"] >= max_steps:
                break
        return self.finished

    @property
    def utilization(self) -> float:
        denom = self.stats["steps"] * self.n_slots
        return self.stats["slot_busy"] / denom if denom else 0.0
