from .checkpoint import (save, restore, peek, latest_step,  # noqa: F401
                         list_steps)
