from .checkpoint import save, restore, latest_step, list_steps  # noqa: F401
