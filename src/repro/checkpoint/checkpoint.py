"""Fault-tolerant checkpointing: atomic, step-tagged, keep-K, resumable.

Layout::

    <dir>/step_000123/
        manifest.json        # tree structure, leaf dtypes/shapes, extra state
        arrays.npz           # flattened leaves, key = path string
    <dir>/step_000123.tmp... # staging dir, renamed atomically on completion

Properties the fleet relies on (tested in tests/test_checkpoint.py):

* **atomicity** — a crash mid-save never corrupts the latest checkpoint:
  writes go to a ``.tmp`` dir, ``os.rename`` commits;
* **self-validating restore** — a truncated/corrupt step directory is
  skipped and the previous valid one is used;
* **keep-K** — old steps are pruned after a successful commit;
* **resume determinism** — restore returns the exact pytree (bitwise) plus
  the auxiliary state dict (data-pipeline position, RNG key, gate stats).

At multi-pod scale each host would write its own array shards (the manifest
format already keys leaves by path); single-host write is what this
container can exercise.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:09d}")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(base: str, step: int, tree: Any, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(base, exist_ok=True)
    final = _step_dir(base, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat.keys()),
        "extra": extra or {},
        "complete": True,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(base, keep)
    return final


def _prune(base: str, keep: int):
    steps = list_steps(base)
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


def list_steps(base: str) -> List[int]:
    if not os.path.isdir(base):
        return []
    out = []
    for name in os.listdir(base):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def _valid(base: str, step: int) -> bool:
    d = _step_dir(base, step)
    mf = os.path.join(d, "manifest.json")
    az = os.path.join(d, "arrays.npz")
    if not (os.path.isfile(mf) and os.path.isfile(az)):
        return False
    try:
        with open(mf) as f:
            man = json.load(f)
        if not man.get("complete"):
            return False
        with np.load(az) as z:
            return sorted(z.files) == man["keys"]
    except Exception:
        return False


def latest_step(base: str) -> Optional[int]:
    for s in reversed(list_steps(base)):
        if _valid(base, s):
            return s
    return None


def peek(base: str, step: Optional[int] = None
         ) -> Tuple[int, Dict[str, Tuple[Tuple[int, ...], str]], Dict]:
    """Shapes/dtypes of a checkpoint's leaves without building a template.

    Returns ``(step, {path_key: (shape, dtype_str)}, extra)``. What a
    layout-migrating restore (e.g. the serving fleet's dense→compact delta
    shim) reads first to decide which template to restore into.
    """
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {base}")
    d = _step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        shapes = {k: (tuple(z[k].shape), str(z[k].dtype)) for k in z.files}
    return step, shapes, man["extra"]


def restore(base: str, template: Any, step: Optional[int] = None
            ) -> Tuple[int, Any, Dict]:
    """Restore into the structure of ``template``. Returns (step, tree, extra)."""
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {base}")
    d = _step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        leaves.append(jax.numpy.asarray(arr, dtype=np.asarray(leaf).dtype))
    return step, jax.tree_util.tree_unflatten(treedef, leaves), man["extra"]
