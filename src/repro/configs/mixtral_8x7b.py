"""Mixtral-8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf]. Experts are big (d_ff=14336): TP *inside* each
expert (F on "model"), not EP — see models/moe.py."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=32000, act="swiglu", rope_theta=1e6,
    swa_window=4096,
    moe_experts=8, moe_top_k=2, moe_shard_experts=False,
)
