"""Qwen2-VL-2B backbone — M-RoPE, dynamic-resolution vision [arXiv:2409.12191].

The vision encoder is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings (14×14×3×2 = 1176-dim) which the backbone
projects into d_model. M-RoPE carries 3-axis (t, h, w) positions.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab=151936, act="swiglu",
    rope_mode="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    frontend="vision_stub", frontend_dim=1176,
)
