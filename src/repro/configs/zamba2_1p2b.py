"""Zamba2-1.2B — Mamba2 trunk + ONE shared attention+MLP block applied every
6 layers (weights reused across invocations) [arXiv:2411.15242; hf].
``long_500k`` runs here (SSM state + periodically-refreshed shared-attn ring
caches)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000, act="swiglu", rope_theta=1e4,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    hybrid_attn_every=6,
    swa_window=4096,   # shared-block ring cache bound for long-context decode
)
