"""MusicGen-large backbone — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. The EnCodec tokenizer is a STUB per the assignment:
``input_specs`` supplies precomputed 128-dim frame embeddings for the train
shape; decode shapes run on the 2048-entry codebook vocabulary."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048, act="gelu", rope_theta=1e4,
    frontend="audio_stub", frontend_dim=128,
)
