"""Moonlight-16B-A3B (moonshot) — 64-expert top-6 fine-grained MoE
[hf:moonshotai/Moonlight-16B-A3B]. Experts are small (d_ff=1408): EP —
experts sharded over "model" (4 per chip at model=16)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=163840, act="swiglu", rope_theta=5e4,
    moe_experts=64, moe_top_k=6, moe_shard_experts=True,
)
