"""Config schema for every architecture the framework can instantiate.

One ``ModelConfig`` describes any member of the assigned pool (dense / MoE /
SSM / hybrid / VLM / audio LM families) plus the paper's add-ons (block-N:M
sparsity via ``SparsityConfig``, OSSL local-update mode, gated optimizer
updates). ``src/repro/configs/<arch>.py`` files hold the exact published
numbers; ``reduced()`` shrinks any config to a CPU-smoke size of the same
family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Block-N:M sparsity on the big projection matrices (DESIGN.md §2).

    ``targets``: which weight families are sparse ("mlp", "attn", "expert").
    ``mode``: "masked" (dense storage + mask — simple, CPU-friendly) or
    "compact" (values+indices storage — the paper's memory cut; what the
    dry-run/roofline sees).
    """
    n: int = 2
    m: int = 8
    block: int = 128
    targets: Tuple[str, ...] = ("mlp",)
    mode: str = "compact"

    @property
    def density(self) -> float:
        return self.n / self.m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None   # defaults to d_model // n_heads
    act: str = "swiglu"            # swiglu | relu2 | gelu
    rope_theta: float = 1e4
    rope_mode: str = "rope"        # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    swa_window: Optional[int] = None
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_shard_experts: bool = False   # True: EP (experts on model axis); False: TP inside experts
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # --- hybrid (Zamba2-style shared attention block) ---
    hybrid_attn_every: int = 0     # apply the shared attn block after every k-th layer
    # --- modality frontend stubs ---
    frontend: Optional[str] = None     # "vision_stub" | "audio_stub"
    frontend_dim: int = 0              # precomputed patch/frame embedding width
    # --- paper technique ---
    sparsity: Optional[SparsityConfig] = None
    # --- numerics / training ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode with O(1)-ish state at 500k context?"""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    def with_sparsity(self, sp: SparsityConfig) -> "ModelConfig":
        return dataclasses.replace(self, sparsity=sp)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.head_dim
        n = 0
        n += self.vocab * d                      # embed
        if not self.tie_embeddings:
            n += d * self.vocab                  # lm head
        if self.frontend:
            n += self.frontend_dim * d
        per_layer = 0
        if self.family in ("dense", "vlm", "audio", "moe"):
            per_layer += d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                + self.n_heads * dh * d          # qkvo
            per_layer += 2 * d                   # norms
            if self.family == "moe":
                e = self.moe_experts
                per_layer += d * e               # router
                ff = 3 if self.act == "swiglu" else 2
                per_layer += e * ff * d * self.d_ff
            else:
                ff = 3 if self.act == "swiglu" else 2
                per_layer += ff * d * self.d_ff
        elif self.family in ("ssm", "hybrid"):
            di, ns = self.d_inner, self.ssm_state
            # in_proj -> (z, x, B, C, dt), conv, A/D/dt_bias, norm, out_proj
            per_layer += d * (2 * di + 2 * ns + self.ssm_heads)
            per_layer += self.ssm_conv * (di + 2 * ns)
            per_layer += 3 * self.ssm_heads + di   # A, D, dt_bias, gated-norm
            per_layer += di * d
            per_layer += d                        # norm
        total = n + self.n_layers * per_layer
        if self.family == "hybrid" and self.hybrid_attn_every:
            dh_ = self.head_dim
            shared = (self.d_model * self.n_heads * dh_
                      + 2 * self.d_model * self.n_kv_heads * dh_
                      + self.n_heads * dh_ * self.d_model
                      + 3 * self.d_model * self.d_ff + 2 * self.d_model)
            total += shared
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts) — for 6·N_active·D."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        e, k = self.moe_experts, self.moe_top_k
        ff = 3 if self.act == "swiglu" else 2
        expert_p = self.n_layers * e * ff * self.d_model * self.d_ff
        return int(full - expert_p + expert_p * k / e)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs; reason recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 512k dense KV decode is the "
                       "quadratic-memory case long_500k excludes (DESIGN.md §6)")
    return True, ""
