"""Architecture registry: the 10 assigned configs (+ the paper's own SNN).

``get_config(name)`` returns the exact published full-scale config;
``get_reduced(name)`` returns a same-family CPU-smoke shrink.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from .base import ModelConfig, ShapeConfig, SparsityConfig, SHAPES, shape_applicable  # noqa: F401

ARCH_IDS: List[str] = [
    "deepseek_67b",
    "nemotron_4_15b",
    "stablelm_12b",
    "phi3_medium_14b",
    "qwen2_vl_2b",
    "mixtral_8x7b",
    "moonshot_v1_16b_a3b",
    "mamba2_2p7b",
    "musicgen_large",
    "zamba2_1p2b",
]


def normalize(name: str) -> str:
    return name.replace("-", "_").replace(".", "p")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.CONFIG


def make_reduced(cfg: ModelConfig) -> ModelConfig:
    """Shrink any config to a CPU-runnable smoke size of the same family."""
    kv_ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_heads = 4
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=4 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=max(1, n_heads // kv_ratio),
        d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        dtype="float32",
        remat=False,
    )
    if cfg.rope_mode == "mrope":
        kw["mrope_sections"] = (2, 3, 3)
    if cfg.swa_window:
        kw["swa_window"] = 8
    if cfg.family == "moe":
        kw.update(moe_experts=4, moe_top_k=min(2, cfg.moe_top_k))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.family == "hybrid":
        kw.update(hybrid_attn_every=2)
    if cfg.frontend:
        kw.update(frontend_dim=24)
    if cfg.sparsity:
        kw["sparsity"] = dataclasses.replace(cfg.sparsity, block=8, n=1, m=2)
    return dataclasses.replace(cfg, **kw)


def get_reduced(name: str) -> ModelConfig:
    return make_reduced(get_config(name))


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
