"""Mamba2-2.7B — attention-free SSD [arXiv:2405.21060].

d_inner = 2·2560 = 5120, head_dim 64 → 80 SSD heads, state N=128.
``long_500k`` runs here (recurrent decode, O(state) memory).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, rope_mode="none",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
    d_head=64,
)
