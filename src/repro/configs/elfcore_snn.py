"""The paper's own network: (512)-512-512-16 SNN at 80 % N:M sparsity,
4 groups per fan-in, OSSL hidden layers + SL readout (core/snn.py)."""
from repro.core.dsst import DSSTConfig
from repro.core.gating import GatingConfig
from repro.core.snn import SNNConfig

CONFIG = SNNConfig(
    n_in=512, n_hidden=512, n_layers=2, n_out=16,
    t_steps=50, sparsity=0.8,
    dsst=DSSTConfig(period=40, prune_frac=0.25),
    gating=GatingConfig(enabled=True),
)


def reduced(t_steps: int = 16) -> SNNConfig:
    import dataclasses
    return dataclasses.replace(CONFIG, n_in=64, n_hidden=64, n_out=4,
                               t_steps=t_steps,
                               dsst=DSSTConfig(period=8, prune_frac=0.25))
