"""Public gated sparse-WU op (padding + dispatch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kernel import wu_outer_pallas


def wu_outer(pre, mod, idx, scale, *, bk: int, bo: int,
             interpret: bool = False, force_pallas: bool = False):
    """ΔW_compact = scale · gatherᵢ(pre) ⊗ mod, compact layout only."""
    scale = jnp.asarray(scale, pre.dtype)
    if not (force_pallas or jax.default_backend() == "tpu"):
        return ref.wu_outer(pre, mod, idx, scale, bk, bo)
    b = pre.shape[0]
    bb = min(128, b)
    pad = (-b) % bb
    if pad:
        pre = jnp.pad(pre, ((0, pad), (0, 0)))
        mod = jnp.pad(mod, ((0, pad), (0, 0)))
    return wu_outer_pallas(pre, mod, idx, scale, bk=bk, bo=bo, bb=bb,
                           interpret=interpret or jax.default_backend() != "tpu")


def wu_outer_slots(pre, mod, idx, scale, *, bk: int, bo: int,
                   interpret: bool = False, force_pallas: bool = False):
    """Per-slot compact WU: each slot keeps its own ``[J, T, bk, bo]`` update.

    jnp-only for now — the per-slot variant has no batch reduction so it is
    bandwidth-bound; a TPU mapping would vmap the WU kernel over slots.
    ``interpret``/``force_pallas`` are accepted for signature parity with
    ``wu_outer`` and ignored.
    """
    del interpret, force_pallas
    scale = jnp.asarray(scale, pre.dtype)
    return ref.wu_outer_slots(pre, mod, idx, scale, bk, bo)
