"""Gated three-factor sparse weight-update Pallas kernel.

The ElfCore WU engine computes, concurrently with spike integration,
``ΔW = gate · lr · pre_trace ⊗ modulator`` for the *materialised* N:M
connections only. On TPU this is a batched outer product per kept block:

* grid = (out-tiles J, kept-blocks T, row-chunks R) with row chunks innermost
  so partial outer products accumulate in an f32 VMEM scratch tile;
* the same scalar-prefetched ``idx`` table as nm_spmm gathers the presynaptic
  trace block (the two engines share one index SRAM on the chip);
* the gate (already folded with the learning rate into ``scale``) arrives as
  a [1,1] SMEM operand — a gated-off layer multiplies by 0.0, which XLA's
  scheduler can elide entirely when the gate is a compile-time constant; at
  runtime the energy model counts it as a skipped WU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, scale_ref, pre_ref, mod_ref, dw_ref, acc_ref, *, n_rows: int):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [bk, bb] @ [bb, bo] outer-product chunk on the MXU
    acc_ref[...] += jnp.dot(pre_ref[...].T, mod_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(r == n_rows - 1)
    def _flush():
        dw_ref[0, 0] = (scale_ref[0, 0] * acc_ref[...]).astype(dw_ref.dtype)


def wu_outer_pallas(pre, mod, idx, scale, *, bk: int, bo: int, bb: int = 128,
                    interpret: bool = False):
    b, k = pre.shape
    j, t = idx.shape
    assert b % bb == 0, (b, bb)
    grid = (j, t, b // bb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda jj, tt, r, idx_ref: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bb, bk), lambda jj, tt, r, idx_ref: (r, idx_ref[jj, tt])),
            pl.BlockSpec((bb, bo), lambda jj, tt, r, idx_ref: (r, jj)),
        ],
        out_specs=pl.BlockSpec((1, 1, bk, bo), lambda jj, tt, r, idx_ref: (jj, tt, 0, 0)),
        scratch_shapes=[pltpu.VMEM((bk, bo), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_rows=b // bb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((j, t, bk, bo), pre.dtype),
        interpret=interpret,
    )(idx, scale.reshape(1, 1), pre, mod)
