"""Oracle for the gated three-factor sparse weight update (WU engine).

``dw_compact[j, t] = scale · pre[:, idx[j,t]].T @ mod[:, j·bo:(j+1)·bo]``

i.e. the outer-product update is computed **only for materialised blocks**,
on the compact layout — the chip never touches pruned synapses. ``scale``
folds the learning rate and the IA/SS gate (0 when gated off: the whole WU
is skipped, which is where the 52–65 % power cut comes from).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wu_outer(pre: jax.Array, mod: jax.Array, idx: jax.Array, scale: jax.Array,
             bk: int, bo: int) -> jax.Array:
    b, k = pre.shape
    j, t = idx.shape
    preb = pre.reshape(b, k // bk, bk)
    pg = preb[:, idx, :]                                    # [B, J, T, bk]
    modt = mod.reshape(b, j, bo)
    return scale * jnp.einsum("bjtk,bjo->jtko", pg, modt)


def wu_outer_slots(pre: jax.Array, mod: jax.Array, idx: jax.Array,
                   scale: jax.Array, bk: int, bo: int) -> jax.Array:
    """Per-slot compact outer-product update ``[S, J, T, bk, bo]``.

    Unlike ``wu_outer`` (which batch-sums into one shared ``dw_compact``,
    the training shape), every slot keeps its own update — the serving
    per-stream delta rule. ``scale [S]`` carries the per-slot gate×lr.

    The multiply association mirrors the dense serving rule
    ``(scale · pre) · mod`` elementwise, so at every kept coordinate the
    update is **bitwise identical** to the dense-delta path's
    ``scale[:,None,None] * pre[:,:,None] * mod[:,None,:]``.
    """
    s, k = pre.shape
    j, t = idx.shape
    preb = pre.reshape(s, k // bk, bk)
    pg = preb[:, idx, :]                                    # [S, J, T, bk]
    modt = mod.reshape(s, j, bo)
    return ((scale[:, None, None, None] * pg)[..., None]
            * modt[:, :, None, None, :])
