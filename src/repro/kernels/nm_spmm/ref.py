"""Pure-jnp oracle for the block-N:M sparse matmul.

Layouts (shared with kernel.py / ops.py):

* ``x``         : [B, K] activations (B = flattened batch·seq rows).
* ``w_compact`` : [J, T, bk, bo] — for each of J output tiles (bo columns),
                  the T = G·n kept K-blocks of bk rows each.
* ``idx``       : [J, T] int32 — *global* K-block index of each kept block
                  (row block ``idx[j, t]`` spans x[:, idx*bk : (idx+1)*bk]).

``y[:, j·bo:(j+1)·bo] = Σ_t x[:, idx[j,t]] @ w_compact[j, t]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def densify(w_compact: jax.Array, idx: jax.Array, k: int) -> jax.Array:
    """Compact [J, T, bk, bo] + idx [J, T] -> dense [K, O] with zeros."""
    j, t, bk, bo = w_compact.shape
    dense = jnp.zeros((k // bk, bk, j, bo), w_compact.dtype)
    for_j = jnp.repeat(jnp.arange(j), t)
    for_t = jnp.tile(jnp.arange(t), j)
    blocks = w_compact[for_j, for_t]                       # [J*T, bk, bo]
    dense = dense.at[idx[for_j, for_t], :, for_j, :].add(blocks)
    return dense.reshape(k, j * bo)


def nm_spmm(x: jax.Array, w_compact: jax.Array, idx: jax.Array) -> jax.Array:
    """Reference forward: gather x blocks, per-tile dense matmul."""
    j, t, bk, bo = w_compact.shape
    b, k = x.shape
    xb = x.reshape(b, k // bk, bk)
    xg = xb[:, idx, :]                                     # [B, J, T, bk]
    y = jnp.einsum("bjtk,jtko->bjo", xg, w_compact)
    return y.reshape(b, j * bo)


def nm_spmm_dense_ref(x: jax.Array, w_compact: jax.Array, idx: jax.Array) -> jax.Array:
    """Second, independent oracle via densify (used in tests)."""
    k = x.shape[1]
    return x @ densify(w_compact, idx, k)
