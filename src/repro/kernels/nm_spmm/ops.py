"""Public op: block-N:M sparse matmul with sparse-to-sparse gradients.

``nm_spmm(x, w_compact, idx)`` dispatches to the Pallas kernel (TPU, or
interpret mode when forced) or to the jnp reference (CPU default — interpret
mode is a correctness tool, not a fast path), wrapped in a ``custom_vjp``
whose backward pass **never materialises the dense weight matrix**:

* ``dx``        — transposed sparse matmul, assembled block-wise;
* ``dw_compact``— gradient *only for materialised blocks* (gather x blocks,
  per-block outer product). This is the chip's sparse WU philosophy: pruned
  connections receive no gradient storage; DSST's regrow scoring instead
  uses the factorized |pre|·|post| statistics (core/dsst.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import nm_spmm_pallas


def _use_pallas(force_pallas: bool) -> bool:
    if force_pallas:
        return True
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def nm_spmm(x, w_compact, idx, interpret=False, force_pallas=False):
    return _fwd_impl(x, w_compact, idx, interpret, force_pallas)


def _fwd_impl(x, w_compact, idx, interpret, force_pallas):
    if _use_pallas(force_pallas):
        return nm_spmm_pallas(x, w_compact, idx,
                              interpret=interpret or jax.default_backend() != "tpu")
    return ref.nm_spmm(x, w_compact, idx)


def _fwd(x, w_compact, idx, interpret, force_pallas):
    return _fwd_impl(x, w_compact, idx, interpret, force_pallas), (x, w_compact, idx)


def _bwd(interpret, force_pallas, res, dy):
    x, w_compact, idx = res
    j, t, bk, bo = w_compact.shape
    b, k = x.shape
    dyt = dy.reshape(b, j, bo)

    # dx: scatter-add transposed block matmuls into the kept rows only.
    dxg = jnp.einsum("bjo,jtko->bjtk", dyt, w_compact)      # [B, J, T, bk]
    dxb = jnp.zeros((b, k // bk, bk), x.dtype)
    dxb = dxb.at[:, idx, :].add(dxg.astype(x.dtype))
    dx = dxb.reshape(b, k)

    # dw_compact: gradient only where a block is materialised.
    xb = x.reshape(b, k // bk, bk)
    xg = xb[:, idx, :]                                      # [B, J, T, bk]
    dwc = jnp.einsum("bjtk,bjo->jtko", xg, dyt).astype(w_compact.dtype)
    return dx, dwc, None


nm_spmm.defvjp(_fwd, _bwd)


def make_compact(w_dense: jax.Array, unit_mask: jax.Array, bk: int, bo: int,
                 n_kept: int | None = None):
    """Dense [K, O] + unit mask [K/bk, O/bo] -> (w_compact [J,T,bk,bo], idx [J,T]).

    Every out tile must keep the same *count* of blocks (N:M guarantees it).
    Pass ``n_kept`` (= G·n, known statically from the spec) when the mask is
    a tracer — e.g. building the compact carry inside a jitted step.
    """
    k, o = w_dense.shape
    kb, j = unit_mask.shape
    assert kb == k // bk and j == o // bo
    if n_kept is None:
        if isinstance(unit_mask, jax.core.Tracer):
            raise ValueError(
                "make_compact: unit_mask is a traced value, so the kept-block "
                "count cannot be read off it at trace time. Pass n_kept=... "
                "explicitly — it is static from the N:M spec "
                "(G·n, i.e. engine.compact_kept(cfg)).")
        t = int(unit_mask[:, 0].sum())
    else:
        t = n_kept
    idx = jnp.argsort(~unit_mask, axis=0, stable=True)[:t].T.astype(jnp.int32)  # [J, T]
    wb = w_dense.reshape(kb, bk, j, bo).transpose(2, 0, 1, 3)  # [J, KB, bk, bo]
    w_compact = jnp.take_along_axis(wb, idx[:, :, None, None], axis=1)
    return w_compact, idx


def nm_spmm_deltas(x, delta_compact, idx):
    """Per-slot compact delta matmul: ``y[s] = x[s] @ densify(delta[s])``.

    ``x [S, K]`` with per-slot compact deltas ``[S, J, T, bk, bo]`` sharing
    one ``idx [J, T]`` (every stream lives on the fleet's topology). The
    gather mirrors ``ref.nm_spmm``; only the batch axis rides along on the
    weight operand. Used by the serving hot path so the per-stream delta
    current never round-trips through a dense ``[K, N]`` tensor.
    """
    s, k = x.shape
    _, j, t, bk, bo = delta_compact.shape
    xb = x.reshape(s, k // bk, bk)
    xg = xb[:, idx, :]                                      # [S, J, T, bk]
    y = jnp.einsum("sjtk,sjtko->sjo", xg, delta_compact)
    return y.reshape(s, j * bo)


def nm_spmm_batched(x, w_compact, idx, *, interpret: bool = False,
                    force_pallas: bool = False):
    """Row-count-agnostic forward dispatch (no custom VJP).

    The engine's local learning rules never backprop through the forward
    matmul, so this skips the ``custom_vjp`` wrapper and simply pads the
    row dimension to the kernel's ``bm`` tile before dispatching.
    """
    if not _use_pallas(force_pallas):
        return ref.nm_spmm(x, w_compact, idx)
    b = x.shape[0]
    bm = 128 if b >= 128 else 8
    pad = (-b) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    y = nm_spmm_pallas(x, w_compact, idx, bm=bm,
                       interpret=interpret or jax.default_backend() != "tpu")
    return y[:b]
