"""Block-N:M sparse matmul Pallas kernel — ElfCore's forward path on the MXU.

TPU adaptation of the chip's input-stationary sparse datapath (Fig. 6):

* The dense contraction dimension K is split into ``bk``-row blocks; an N:M
  pattern keeps T = G·n blocks per ``bo``-wide output tile. Kept-block ids
  live in a small int32 table ``idx[J, T]`` that is **scalar-prefetched**
  (PrefetchScalarGridSpec) so the x-block ``index_map`` can gather the right
  activation block while the previous tile is still computing — Pallas'
  analogue of the chip streaming sparse indices one SRAM port ahead of the
  MACs.
* Grid = (rows, out-tiles, kept-blocks), kept-blocks innermost: the gathered
  x block and the compact weight block meet in VMEM, accumulate into an f32
  VMEM scratch tile, and the output is written once per (row, tile) — the
  input-stationary reuse that makes sparse *and* dense tiles the same MXU
  shape (128-aligned, no element-granular gather anywhere).
* Zero-skipping of the chip maps to *not iterating* pruned blocks at all:
  FLOPs and HBM traffic both scale with n/m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref, *, n_kept: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # one MXU tile: gathered activation block @ compact weight block
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[0, 0], preferred_element_type=jnp.float32
    )

    @pl.when(t == n_kept - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def nm_spmm_pallas(
    x: jax.Array,          # [B, K]
    w_compact: jax.Array,  # [J, T, bk, bo]
    idx: jax.Array,        # [J, T] int32 global K-block ids
    *,
    bm: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, k = x.shape
    j, t, bk, bo = w_compact.shape
    assert b % bm == 0, (b, bm)
    assert k % bk == 0, (k, bk)

    grid = (b // bm, j, t)

    def x_map(i, jj, tt, idx_ref):
        return (i, idx_ref[jj, tt])

    def w_map(i, jj, tt, idx_ref):
        return (jj, tt, 0, 0)

    def o_map(i, jj, tt, idx_ref):
        return (i, jj)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((1, 1, bk, bo), w_map),
        ],
        out_specs=pl.BlockSpec((bm, bo), o_map),
        scratch_shapes=[pltpu.VMEM((bm, bo), jnp.float32)],
    )
    kwargs = {}
    if not interpret:
        # rows/tiles parallel; kept-block accumulation revisits the out tile.
        try:
            kwargs["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
        except AttributeError:  # older pallas API
            kwargs["compiler_params"] = pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        functools.partial(_kernel, n_kept=t),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, j * bo), x.dtype),
        interpret=interpret,
        **kwargs,
    )(idx, x, w_compact)
