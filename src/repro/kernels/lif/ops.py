"""Public fused-LIF op with automatic padding + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kernel import lif_pallas


def _pad_to(x, bm, bn):
    b, n = x.shape
    pb, pn = (-b) % bm, (-n) % bn
    if pb or pn:
        x = jnp.pad(x, ((0, pb), (0, pn)))
    return x


def lif_step(v, tr, current, *, alpha: float, beta: float, theta: float,
             interpret: bool = False, force_pallas: bool = False):
    """Fused LIF update. Pallas on TPU (or when forced), jnp ref otherwise."""
    if not (force_pallas or jax.default_backend() == "tpu"):
        return ref.lif_step(v, tr, current, alpha=alpha, beta=beta, theta=theta)
    b, n = v.shape
    bm, bn = 8, 128
    vp, trp, ip = (_pad_to(a, bm, bn) for a in (v, tr, current))
    vo, tro, s = lif_pallas(vp, trp, ip, alpha=alpha, beta=beta, theta=theta,
                            bm=bm, bn=bn,
                            interpret=interpret or jax.default_backend() != "tpu")
    return vo[:b, :n], tro[:b, :n], s[:b, :n]
