"""Pure-jnp oracle for the fused LIF + trace update (core/snn.py dynamics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lif_step(v, tr, current, *, alpha: float, beta: float, theta: float):
    """(v, tr, I) -> (v', tr', s): leaky integrate, fire, soft reset, trace."""
    v = alpha * v + current
    s = (v >= theta).astype(v.dtype)
    v = v - s * theta
    tr = beta * tr + s
    return v, tr, s
