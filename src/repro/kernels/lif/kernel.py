"""Fused LIF neuron-update Pallas kernel.

The chip's neuron pipeline touches each neuron word once per TS: membrane
decay + integrate, threshold, soft reset, trace decay + spike add. Done
naively in jnp that is four elementwise HBM round-trips over [B, N]; fused
here it is a single VMEM pass (VPU only, no MXU) producing all three outputs
from one load of (v, tr, I).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, tr_ref, i_ref, vo_ref, tro_ref, s_ref, *,
            alpha: float, beta: float, theta: float):
    v = alpha * v_ref[...] + i_ref[...]
    s = (v >= theta).astype(v.dtype)
    vo_ref[...] = v - s * theta
    tro_ref[...] = beta * tr_ref[...] + s
    s_ref[...] = s


def lif_pallas(v, tr, current, *, alpha: float, beta: float, theta: float,
               bm: int = 8, bn: int = 128, interpret: bool = False):
    b, n = v.shape
    assert b % bm == 0 and n % bn == 0, (v.shape, bm, bn)
    grid = (b // bm, n // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_shape = [jax.ShapeDtypeStruct((b, n), v.dtype)] * 3
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta, theta=theta),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(v, tr, current)
