"""Pallas TPU kernels for ElfCore's compute hot-spots.

Each kernel ships as a triple (DESIGN.md §7):

* ``<name>/kernel.py`` — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling,
  written for TPU (MXU-aligned tiles, scalar-prefetched index tables) and
  validated in ``interpret=True`` mode on CPU;
* ``<name>/ops.py``    — the jit'd public wrapper (padding, custom_vjp,
  interpret/TPU dispatch);
* ``<name>/ref.py``    — the pure-jnp oracle the tests sweep against.

Kernels:

* :mod:`repro.kernels.nm_spmm`  — block-N:M sparse matmul (input-stationary
  forward path of Fig. 6, adapted from the chip's 4 parallel PEs to MXU
  tiles gathered by a scalar-prefetched block-index table).
* :mod:`repro.kernels.lif`      — fused LIF membrane + threshold/reset +
  trace decay (one HBM round-trip for the whole neuron update).
* :mod:`repro.kernels.wu_outer` — gated three-factor sparse weight update on
  the compact N:M layout (the WU engine of Fig. 2).
"""
