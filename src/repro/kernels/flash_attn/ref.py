"""jnp oracle for the flash-attention kernel: plain causal (optionally
sliding-window) GQA attention, f32 softmax."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              window: Optional[int] = None) -> jax.Array:
    """q [B,S,H,dh], k/v [B,S,KV,dh] -> [B,S,H,dh]. Causal."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / (dh ** 0.5)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    ok = j <= i
    if window is not None:
        ok &= (i - j) < window
    scores = jnp.where(ok[None, None, None], scores.astype(jnp.float32), -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)
