"""Flash attention (causal GQA, optional sliding window) as Pallas TPU
kernels — forward AND backward.

Why it matters here: the dry-run's memory roofline term for train/prefill
cells is dominated by S² score traffic (softmax materialised in HBM by the
XLA path). Flash keeps the [bq, bk] score tile in VMEM with online-softmax
accumulators — HBM traffic returns to O(S·d), which on the roofline moves
deepseek-67b train_4k from memory-bound toward the MXU bound (§Perf).

Layout: q [N, S, dh], k/v [N, T, dh] with N = B·KV·G flattened outside (the
wrapper repeats K/V per GQA group view — zero-copy broadcast). Grid
(N, nq, nk), kv innermost; per-(row-tile) VMEM scratch: acc [bq, dh], and
m/l running max/sum [bq] carried across kv steps.

Backward: the standard two-kernel flash backward —
  * dkv kernel: grid (N, nk, nq): recompute p tile, accumulate dk, dv;
  * dq  kernel: grid (N, nq, nk): recompute p tile, accumulate dq;
both use the saved forward logsumexp ``l`` and the precomputed row dot
``delta = rowsum(dout * out)``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_tile(iq, ik, bq, bk, window):
    qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = kj <= qi
    if window is not None:
        ok &= (qi - kj) < window
    return ok


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, bq, bk, nk, scale, window):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s = jnp.dot(q_ref[0], k_ref[0].T,
                preferred_element_type=jnp.float32) * scale      # [bq, bk]
    s = jnp.where(_mask_tile(iq, ik, bq, bk, window), s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l)).astype(lse_ref.dtype)


def flash_fwd(q, k, v, *, bq=128, bk=128, window=None, interpret=False):
    """q [N,S,dh], k/v [N,T,dh] -> (out [N,S,dh], lse [N,S])."""
    n, s, dh = q.shape
    t = k.shape[1]
    bq, bk = min(bq, s), min(bk, t)
    assert s % bq == 0 and t % bk == 0
    grid = (n, s // bq, t // bk)
    scale = dh ** -0.5
    kernel = functools.partial(_fwd_kernel, bq=bq, bk=bk, nk=t // bk,
                               scale=scale, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
                  pl.BlockSpec((1, bk, dh), lambda h, i, j: (h, j, 0)),
                  pl.BlockSpec((1, bk, dh), lambda h, i, j: (h, j, 0))],
        out_specs=[pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
                   pl.BlockSpec((1, bq), lambda h, i, j: (h, i))],
        out_shape=[jax.ShapeDtypeStruct((n, s, dh), q.dtype),
                   jax.ShapeDtypeStruct((n, s), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, bq, bk, nq, scale, window):
    ik, iq = pl.program_id(1), pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    s = jnp.dot(q_ref[0], k_ref[0].T,
                preferred_element_type=jnp.float32) * scale      # [bq, bk]
    s = jnp.where(_mask_tile(iq, ik, bq, bk, window), s, NEG_INF)
    p = jnp.exp(s - lse_ref[0][:, None])                         # [bq, bk]
    do = do_ref[0].astype(jnp.float32)
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v_ref[0].T.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None]) * scale                # [bq, bk]
    dk_acc[...] += jnp.dot(ds.T, q_ref[0].astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _flush():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, dq_acc, *, bq, bk, nk, scale, window):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    s = jnp.dot(q_ref[0], k_ref[0].T,
                preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask_tile(iq, ik, bq, bk, window), s, NEG_INF)
    p = jnp.exp(s - lse_ref[0][:, None])
    do = do_ref[0].astype(jnp.float32)
    dp = jnp.dot(do, v_ref[0].T.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None]) * scale
    dq_acc[...] += jnp.dot(ds, k_ref[0].astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _flush():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def flash_bwd(q, k, v, out, lse, dout, *, bq=128, bk=128, window=None,
              interpret=False):
    n, s, dh = q.shape
    t = k.shape[1]
    bq, bk = min(bq, s), min(bk, t)
    grid_kv = (n, t // bk, s // bq)
    grid_q = (n, s // bq, t // bk)
    scale = dh ** -0.5
    delta = (dout.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)  # [N,S]

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, bq=bq, bk=bk, nq=s // bq,
                          scale=scale, window=window),
        grid=grid_kv,
        in_specs=[pl.BlockSpec((1, bq, dh), lambda h, j, i: (h, i, 0)),   # q
                  pl.BlockSpec((1, bk, dh), lambda h, j, i: (h, j, 0)),   # k
                  pl.BlockSpec((1, bk, dh), lambda h, j, i: (h, j, 0)),   # v
                  pl.BlockSpec((1, bq, dh), lambda h, j, i: (h, i, 0)),   # do
                  pl.BlockSpec((1, bq), lambda h, j, i: (h, i)),          # lse
                  pl.BlockSpec((1, bq), lambda h, j, i: (h, i))],         # delta
        out_specs=[pl.BlockSpec((1, bk, dh), lambda h, j, i: (h, j, 0)),
                   pl.BlockSpec((1, bk, dh), lambda h, j, i: (h, j, 0))],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, dh), jnp.float32),
                        pltpu.VMEM((bk, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bq=bq, bk=bk, nk=t // bk,
                          scale=scale, window=window),
        grid=grid_q,
        in_specs=[pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
                  pl.BlockSpec((1, bk, dh), lambda h, i, j: (h, j, 0)),
                  pl.BlockSpec((1, bk, dh), lambda h, i, j: (h, j, 0)),
                  pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
                  pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
                  pl.BlockSpec((1, bq), lambda h, i, j: (h, i))],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk, dv
