"""Public flash-attention op: GQA layout handling + custom_vjp.

``flash_attention(q, k, v, window)`` takes model-layout tensors
(q [B,S,H,dh], k/v [B,S,KV,dh]); GQA groups are flattened into the kernel's
N axis with k/v broadcast per group (zero-copy view). On non-TPU backends
(unless forced) it falls back to the jnp reference — interpret-mode flash is
a correctness tool. Custom VJP runs the flash backward kernels with the
saved forward logsumexp.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_bwd, flash_fwd


def _use_pallas(force):
    return force or jax.default_backend() == "tpu"


def _to_kernel_layout(q, k, v):
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    t = k.shape[1]
    kk = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kv, g, t, dh)).reshape(b * h, t, dh)
    vk = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kv, g, t, dh)).reshape(b * h, t, dh)
    return qk, kk, vk


def _from_kernel_layout(o, b, s, h, dh):
    return o.reshape(b, h, s, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, window: Optional[int] = None,
                    interpret: bool = False, force_pallas: bool = False):
    out, _ = _fwd(q, k, v, window, interpret, force_pallas)
    return out


def _fwd(q, k, v, window, interpret, force_pallas):
    if not _use_pallas(force_pallas):
        return ref.attention(q, k, v, window), None
    b, s, h, dh = q.shape
    qk, kk, vk = _to_kernel_layout(q, k, v)
    o, lse = flash_fwd(qk, kk, vk, window=window,
                       interpret=interpret or jax.default_backend() != "tpu")
    return _from_kernel_layout(o, b, s, h, dh), (q, k, v, o, lse)


def _vjp_fwd(q, k, v, window, interpret, force_pallas):
    out, res = _fwd(q, k, v, window, interpret, force_pallas)
    if res is None:  # ref path: fall back to autodiff-able residuals
        return out, (q, k, v, None, None)
    return out, res


def _vjp_bwd(window, interpret, force_pallas, res, dout):
    q, k, v, o, lse = res
    if o is None:  # ref path
        f = lambda q_, k_, v_: ref.attention(q_, k_, v_, window)
        _, pullback = jax.vjp(f, q, k, v)
        return pullback(dout)
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qk, kk, vk = _to_kernel_layout(q, k, v)
    dok = dout.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    dq, dk, dv = flash_bwd(qk, kk, vk, o, lse, dok, window=window,
                           interpret=interpret or jax.default_backend() != "tpu")
    dq = _from_kernel_layout(dq, b, s, h, dh)
    t = k.shape[1]
    # sum GQA group contributions back into the kv heads
    dk = dk.reshape(b, kv, g, t, dh).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dv.reshape(b, kv, g, t, dh).sum(axis=2).transpose(0, 2, 1, 3)
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


def hbm_bytes(b: int, s: int, h: int, dh: int, *, bq: int = 128, bk: int = 128,
              dtype_bytes: int = 2, causal: bool = True,
              with_backward: bool = True) -> int:
    """Exact HBM traffic of the flash kernels from their BlockSpec schedule.

    Pallas loads each input block once per grid step (revisited blocks stay
    in VMEM across the innermost axis): per (n, i) the q block loads once;
    k/v blocks load per (i, j) pair. Causal masking visits only j ≤ i tiles.
    This is the number the §Perf roofline uses for the flash path — the
    kernel cannot execute on this CPU container, but its memory behaviour is
    fully determined by the tiling schedule.
    """
    n = b * h
    nq, nk = s // bq, s // bk
    tiles = (nq * (nq + 1)) // 2 if causal and nq == nk else nq * nk
    f32 = 4
    fwd = (n * s * dh * dtype_bytes                 # q once
           + 2 * n * tiles * bk * dh * dtype_bytes  # k, v per visited tile
           + n * s * dh * dtype_bytes               # out
           + n * s * f32)                           # lse
    if not with_backward:
        return fwd
    # dkv kernel: k/v/dk/dv once per (n, j); q/do/lse/delta per visited tile
    dkv = (4 * n * s * dh * dtype_bytes
           + 2 * n * tiles * bq * dh * dtype_bytes
           + 2 * n * tiles * bq * f32)
    # dq kernel: q/do/dq once per (n, i); k/v per visited tile
    dq = (3 * n * s * dh * dtype_bytes
          + 2 * n * tiles * bk * dh * dtype_bytes
          + 2 * n * s * f32)
    return fwd + dkv + dq


def xla_score_path_bytes(b: int, s: int, h: int, dh: int,
                         dtype_bytes: int = 2) -> int:
    """HBM traffic of the unfused score path the dry-run artifacts count:
    scores f32 write+read, probs write+read (fwd), and the backward's
    recompute + dprobs/dscores round trips — what flash removes."""
    n = b * h
    f32 = 4
    s2 = n * s * s
    fwd = s2 * (f32 + f32 + dtype_bytes + dtype_bytes)
    bwd = 2 * fwd + s2 * 2 * f32
    return fwd + bwd
