"""Synthetic event-stream tasks mirroring ElfCore's five benchmarks.

The paper's datasets (IBM DVS gesture, NMNIST, SHD, DEAP, delayed-cue) are
not available offline; these generators reproduce their *structure* —
spatiotemporal spike patterns with per-class templates, Poisson noise and
timing jitter — so the paper's relative claims (sparse-vs-dense accuracy,
gating skip rates, depth scaling) can be validated end-to-end
(DESIGN.md §8). Channel count defaults to the chip's 512 inputs.

Also here: the functional stand-in for the async SerDes front-end —
``pack_events`` / ``unpack_events`` frame spike vectors into 30-bit-payload
serial packets, and ``DelayBuffer`` is the 4-slot spatiotemporal buffer that
emulates axonal delays (Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

TASK_NAMES = ("gesture", "nmnist", "shd_kws", "eeg_emotion", "nav_cue")


@dataclasses.dataclass
class EventTask:
    name: str
    n_classes: int
    n_in: int
    t_steps: int
    _template_fn: Callable[[int], np.ndarray]          # class -> [T, n_in] rates

    def __post_init__(self):
        self._templates = np.stack(
            [self._template_fn(c) for c in range(self.n_classes)])

    def sample(self, rng: np.random.Generator, batch: int,
               labels: np.ndarray | None = None) -> Tuple[np.ndarray, np.ndarray]:
        """-> (events [T, B, n_in] float {0,1}, labels [B] int32)."""
        if labels is None:
            labels = rng.integers(0, self.n_classes, size=(batch,))
        rates = self._templates[labels]                        # [B, T, n_in]
        jitter = rng.integers(-2, 3, size=(batch,))
        rates = np.stack([np.roll(r, j, axis=0) for r, j in zip(rates, jitter)])
        ev = (rng.random(rates.shape) < rates).astype(np.float32)
        return np.transpose(ev, (1, 0, 2)), labels.astype(np.int32)

    def sample_stream(self, rng: np.random.Generator, n_windows: int):
        """Yield ``n_windows`` back-to-back samples as a continuous stream:
        (events [T, n_in], label) per window — the serving-path view, where
        a "sample" is just a T-step window of an endless sensor stream."""
        for _ in range(n_windows):
            ev, lab = self.sample(rng, batch=1)
            yield ev[:, 0], int(lab[0])


def _grid(n_in: int) -> Tuple[int, int]:
    h = int(np.sqrt(n_in / 2))
    return h, n_in // h


def make_task(name: str, n_in: int = 512, t_steps: int = 50, seed: int = 0) -> EventTask:
    rng = np.random.default_rng([seed, hash(name) % (2 ** 31)])
    h, w = _grid(n_in)
    t = np.arange(t_steps)

    if name == "gesture":          # moving 2-D blob, direction per class
        n_classes = 10
        def tmpl(c):
            ang = 2 * np.pi * c / n_classes
            vx, vy = np.cos(ang), np.sin(ang)
            ys, xs = np.mgrid[0:h, 0:w]
            out = np.zeros((t_steps, h * w))
            for ti in t:
                cy = (h / 2 + vy * ti * h / t_steps) % h
                cx = (w / 2 + vx * ti * w / t_steps) % w
                d2 = (ys - cy) ** 2 + (xs - cx) ** 2
                out[ti] = (0.35 * np.exp(-d2 / 6.0)).reshape(-1)
            return _fit(out, n_in)
    elif name == "nmnist":         # static prototype + saccade shifts
        n_classes = 10
        protos = rng.random((n_classes, h * w)) ** 3 * 0.4
        def tmpl(c):
            out = np.zeros((t_steps, h * w))
            img = protos[c].reshape(h, w)
            for ti in t:
                sx, sy = int(2 * np.sin(ti / 5)), int(2 * np.cos(ti / 7))
                out[ti] = np.roll(np.roll(img, sx, 0), sy, 1).reshape(-1)
            return _fit(out, n_in)
    elif name == "shd_kws":        # spectro-temporal keyword sweeps
        n_classes = 10
        starts = rng.integers(0, n_in // 2, size=(n_classes,))
        slopes = rng.uniform(-4, 4, size=(n_classes,))
        def tmpl(c):
            out = np.zeros((t_steps, n_in))
            for ti in t:
                center = int(starts[c] + slopes[c] * ti) % n_in
                idx = (np.arange(-8, 9) + center) % n_in
                out[ti, idx] = 0.35 * np.exp(-np.arange(-8, 9) ** 2 / 12.0)
            return out
    elif name == "eeg_emotion":    # band-limited oscillation mixtures:
        # classes differ in band frequency AND scalp topography (like DEAP's
        # valence/arousal maps) — frequency alone is invisible to a
        # trace-integrating readout at these timescales.
        n_classes = 3
        freqs = [2.0, 5.0, 9.0]
        chan_phase = rng.uniform(0, 2 * np.pi, size=(n_in,))
        topo = rng.dirichlet(np.ones(3), size=n_in).T          # [3, n_in]
        def tmpl(c):
            osc = 0.5 * (1 + np.sin(2 * np.pi * freqs[c] * t[:, None] / t_steps
                                    + chan_phase[None, :]))
            return 0.45 * topo[c][None, :] * osc
    elif name == "nav_cue":        # delayed cue -> decision (temporal memory)
        n_classes = 2
        def tmpl(c):
            out = np.full((t_steps, n_in), 0.02)
            half = n_in // 2
            sl = slice(0, half) if c == 0 else slice(half, n_in)
            out[: t_steps // 5, sl] = 0.4          # cue
            out[-t_steps // 5:, :] = 0.1           # report period (both sides)
            return out
    else:
        raise ValueError(name)

    return EventTask(name, n_classes, n_in, t_steps, tmpl)


def _fit(x: np.ndarray, n_in: int) -> np.ndarray:
    if x.shape[1] == n_in:
        return x
    out = np.zeros((x.shape[0], n_in))
    out[:, : x.shape[1]] = x
    return out


# ---------------------------------------------------------------------------
# SerDes functional stand-in (DESIGN.md §9: circuits don't transfer; framing does)
# ---------------------------------------------------------------------------

PAYLOAD_BITS = 30


def pack_events(spikes: np.ndarray) -> np.ndarray:
    """[T, n_in] {0,1} -> serial packets [T, ceil(n_in/30)] uint32 (30-bit payload)."""
    t_steps, n_in = spikes.shape
    n_words = -(-n_in // PAYLOAD_BITS)
    padded = np.zeros((t_steps, n_words * PAYLOAD_BITS), np.uint32)
    padded[:, :n_in] = spikes.astype(np.uint32)
    words = padded.reshape(t_steps, n_words, PAYLOAD_BITS)
    weights = (1 << np.arange(PAYLOAD_BITS, dtype=np.uint64))
    return (words.astype(np.uint64) * weights).sum(-1).astype(np.uint32)


def unpack_events(packets: np.ndarray, n_in: int) -> np.ndarray:
    t_steps, n_words = packets.shape
    bits = (packets[..., None].astype(np.uint64)
            >> np.arange(PAYLOAD_BITS, dtype=np.uint64)) & 1
    return bits.reshape(t_steps, -1)[:, :n_in].astype(np.float32)


class DelayBuffer:
    """4-slot spatiotemporal buffer emulating axonal delays (Fig. 3)."""

    def __init__(self, n_in: int, depth: int = 4):
        self.buf = np.zeros((depth, n_in), np.float32)

    def push(self, spikes: np.ndarray, delay_taps=(0, 1, 2, 3),
             weights=(1.0, 0.5, 0.25, 0.125)) -> np.ndarray:
        self.buf = np.roll(self.buf, 1, axis=0)
        self.buf[0] = spikes
        return sum(w * self.buf[d] for d, w in zip(delay_taps, weights))
