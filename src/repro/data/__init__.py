from .pipeline import TokenPipeline, synthetic_lm_batch  # noqa: F401
from .events import EventTask, make_task, TASK_NAMES  # noqa: F401
