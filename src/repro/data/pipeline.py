"""Deterministic synthetic LM data pipeline, sharded per host.

No external datasets ship offline, so the token stream is generated: a
mixture of per-sequence affine recurrences (``t_{i+1} = a·t_i + c (mod V)``)
with occasional noise tokens. The structure is learnable (loss drops well
below ``log V`` within tens of steps on a small model) yet has no files to
load — the pipeline still exercises the real at-scale concerns:

* determinism: batch ``k`` is a pure function of (seed, step, host) — a
  restart resumes bit-identically (tests/test_data.py);
* host sharding: each host generates a disjoint slice of the global batch
  (``host_id``/``n_hosts``), exactly how a 1000-node fleet feeds itself;
* prefetch: a depth-2 buffer overlaps generation with compute.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05
    n_patterns: int = 64


def _batch_rng(cfg: PipelineConfig, step: int, host_id: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id, 0xE1FC0DE]))


def synthetic_lm_batch(cfg: PipelineConfig, step: int, host_id: int = 0,
                       n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """Returns {"tokens": [B_local, S], "labels": [B_local, S]} int32."""
    assert cfg.global_batch % n_hosts == 0
    b_local = cfg.global_batch // n_hosts
    rng = _batch_rng(cfg, step, host_id)
    v = cfg.vocab
    # per-sequence affine recurrence parameters from a small pattern pool
    pat = rng.integers(0, cfg.n_patterns, size=(b_local,))
    pool = np.random.default_rng(cfg.seed).integers(1, v, size=(cfg.n_patterns, 2))
    a, c = pool[pat, 0], pool[pat, 1]
    t0 = rng.integers(0, v, size=(b_local,))
    toks = np.empty((b_local, cfg.seq_len + 1), np.int64)
    toks[:, 0] = t0
    for i in range(cfg.seq_len):
        toks[:, i + 1] = (a * toks[:, i] + c) % v
    noise_mask = rng.random((b_local, cfg.seq_len + 1)) < cfg.noise
    toks = np.where(noise_mask, rng.integers(0, v, size=toks.shape), toks)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


class TokenPipeline:
    """Step-indexed iterator with a small prefetch buffer."""

    def __init__(self, cfg: PipelineConfig, host_id: int = 0, n_hosts: int = 1,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg, self.host_id, self.n_hosts = cfg, host_id, n_hosts
        self.step = start_step
        self._buf: collections.deque = collections.deque()
        self._prefetch = prefetch

    def _fill(self):
        while len(self._buf) < self._prefetch:
            self._buf.append(
                (self.step, synthetic_lm_batch(self.cfg, self.step,
                                               self.host_id, self.n_hosts)))
            self.step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        self._fill()
        return self._buf.popleft()

    def state(self) -> Dict[str, int]:
        """Checkpointable position (buffered batches are regenerated)."""
        return {"next_step": self.step - len(self._buf)}

    @staticmethod
    def restore(cfg: PipelineConfig, state: Dict[str, int], **kw) -> "TokenPipeline":
        return TokenPipeline(cfg, start_step=state["next_step"], **kw)
