"""Per-stream online OSSL adaptation under serving load.

Parameter layout: a **frozen shared base** (the trained weights every
stream serves from) plus ONE stacked **per-stream delta** tensor, slot
axis leading, layer axis stacked. The hot-path layout is the compact N:M
tensor ``[n_slots, n_layers, J, T, bk, bo]`` (only kept blocks are
stored — delta memory scales with density); the dense
``[n_slots, n_layers, Kmax, n_hidden]`` layout remains as the A/B
baseline, selected by the rank of whatever ``deltas`` the caller passes.
Each slot's effective weights are
``w_base + delta[slot]``; the activity-dependent gating engine (per-stream
IA/SS thresholds inside ``core.snn.run_chunk``) decides when a stream's
delta absorbs a three-factor OSSL update. A silent or repetitive stream
never pays weight-update energy and never drifts.

This module owns everything *around* the jitted step:

* ``make_chunk_fn`` — jit the chunk step once per (chunk_len, n_slots)
  geometry; the returned callable is the single compiled artifact the
  scheduler drives (compilation-count checked in the serving benchmark);
* per-stream adapt on/off (``adapt_mask``) applied by freezing a lane's
  delta across the step — exactly equivalent to gating the update off,
  while trace/threshold state keeps tracking the stream;
* delta hygiene: multiplicative decay toward the base and a hard clip,
  applied only to lanes that actually processed valid timesteps this chunk
  (an idle slot keeps its delta bit-identical — the scheduler's "empty slot
  costs exactly zero" invariant), so hours-long streams cannot diverge;
* slot-axis sharding: pass a ``("slots",)`` mesh
  (``launch.mesh.make_serving_mesh``) and the chunk step runs under
  ``shard_map`` with slot-leading ``NamedSharding`` on every per-stream
  tensor — each device advances only its slot shard, with zero
  cross-device collectives (the step is per-slot separable by
  construction; asserted in ``core/engine.scan_chunk``);
* ``merge_lane_into_base`` — promote one stream's adaptation into the
  shared base (fleet learning; the hook for DSST-under-traffic later).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.snn import ChunkMetrics, SNNConfig, StreamState, run_chunk


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    enabled: bool = True
    delta_decay: float = 1.0     # per-chunk multiplicative decay (1.0 = off)
    delta_clip: float = 0.5      # hard |delta| bound (0 = off)
    lr_scale: float = 1.0        # scales cfg.lr for the serving path


def make_chunk_fn(cfg: SNNConfig, adapt: AdaptConfig | None = None,
                  mesh: Optional[jax.sharding.Mesh] = None,
                  want_factors: bool = True):
    """Build the jitted slot-grid step.

    Returns ``fn(params, deltas, state, events, valid, adapt_mask)`` ->
    ``(deltas, state, metrics)`` with static shapes: ``events`` [C, S, n_in],
    ``valid`` [C, S] bool, ``adapt_mask`` [S] bool. One compilation serves
    any number of streams multiplexed through the S slots.

    With ``mesh`` (a 1-D ``("slots",)`` mesh), the step runs under
    ``shard_map`` with explicit slot-leading in/out shardings: ``deltas``,
    every ``StreamState`` leaf and ``adapt_mask`` shard their slot axis,
    the ``[C, S, ...]`` event/valid buffers shard axis 1, params replicate.
    Each device advances only its slot shard — no collectives — so the
    result is bit-identical to the single-device path. S must divide by the
    mesh's device count (``launch.sharding.check_slot_divisible``).

    ``want_factors`` (static) controls the DSST activity factors the live
    topology service consumes:

    * ``True`` (default) — the engine accumulates per-slot ``pre_mag``/
      ``post_mag`` over the chunk and this wrapper slot-reduces them **on
      device** with the order-fixed ``engine.ordered_slot_sum`` before they
      leave the jit: the metrics carry ``[L, Kmax]`` / ``[L, N]`` (a few
      KB) instead of a per-step ``[S, L, ·]`` device→host transfer, and the
      fixed reduction tree keeps 1-device and slot-sharded fleets'
      epoch decisions bit-identical.
    * ``False`` — the accumulators are compiled out of the chunk scan
      entirely (``metrics.pre_mag is None``); the O(S·(K+N))-per-timestep
      in-scan cost disappears. Use for fleets with a frozen topology.
    """
    adapt = adapt or AdaptConfig()
    scfg = cfg if adapt.lr_scale == 1.0 else dataclasses.replace(
        cfg, lr=cfg.lr * adapt.lr_scale)
    traces = {"n": 0}   # bumps once per (re)trace — public-API compile count

    def step(params, deltas, state: StreamState, events, valid, adapt_mask
             ) -> Tuple[jax.Array, StreamState, ChunkMetrics]:
        new_deltas, new_state, metrics = run_chunk(
            params, deltas, state, events, valid, scfg, learn=adapt.enabled,
            want_factors=want_factors)
        d = new_deltas                           # [S, L, ...] either layout
        if adapt.delta_decay < 1.0:
            d = d * adapt.delta_decay
        if adapt.delta_clip > 0.0:
            d = jnp.clip(d, -adapt.delta_clip, adapt.delta_clip)
        # decay/clip only touch lanes that processed valid timesteps this
        # chunk; frozen AND idle lanes keep their old delta bit-exactly
        live = adapt_mask & valid.any(0)         # [S]
        out = jnp.where(live.reshape((-1,) + (1,) * (d.ndim - 1)), d, deltas)
        # a frozen lane is not billed for weight updates — and is not
        # *offered* any either, or its wu_skip_rate reads a fake 100%
        metrics = metrics._replace(
            sop_wu=metrics.sop_wu * adapt_mask,
            sop_wu_offered=metrics.sop_wu_offered * adapt_mask,
            gate_opened=metrics.gate_opened * adapt_mask[:, None],
            gate_offered=metrics.gate_offered * adapt_mask[:, None])
        return out, new_state, metrics

    if mesh is None:
        body, jit_kw = step, {}
        validate = lambda n_slots: None
    else:
        from jax.experimental.shard_map import shard_map
        from repro.launch import sharding as SH
        in_specs, out_specs = SH.chunk_step_specs(want_factors)
        body = shard_map(step, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
        in_sh, out_sh = SH.chunk_step_shardings(mesh, want_factors)
        jit_kw = {"in_shardings": in_sh, "out_shardings": out_sh}
        validate = lambda n_slots: SH.check_slot_divisible(n_slots, mesh)

    @functools.partial(jax.jit, **jit_kw)
    def chunk_fn(params, deltas, state, events, valid, adapt_mask):
        traces["n"] += 1
        validate(events.shape[1])   # trace-time: clean error, not XLA's
        deltas, state, metrics = body(params, deltas, state, events, valid,
                                      adapt_mask)
        if want_factors:
            # order-fixed slot reduction OUTSIDE the shard-mapped step (the
            # step itself stays collective-free) but still on device: the
            # topology service fetches O(L·(K+N)), not O(S·L·(K+N))
            metrics = metrics._replace(
                pre_mag=engine.ordered_slot_sum(metrics.pre_mag),
                post_mag=engine.ordered_slot_sum(metrics.post_mag))
        return deltas, state, metrics

    chunk_fn.n_traces = lambda: traces["n"]
    chunk_fn.mesh = mesh
    chunk_fn.want_factors = want_factors
    return chunk_fn


def delta_norms(deltas: jax.Array) -> jax.Array:
    """Per-slot L2 norm of the adaptation, summed over layers. [S].

    ``deltas``: the stacked slot-leading per-stream tensor, compact
    ``[S, L, J, T, bk, bo]`` or dense ``[S, L, Kmax, N]``. Compact storage
    holds only kept coordinates and dense deltas are zero off-mask, so the
    two layouts report the same norms.
    """
    sq = (deltas * deltas).sum(axis=tuple(range(2, deltas.ndim)))
    return jnp.sqrt(sq).sum(1)


def merge_lane_into_base(params: Dict[str, Any], deltas: jax.Array, slot: int,
                         cfg: SNNConfig, weight: float = 1.0) -> Dict[str, Any]:
    """Fold stream ``slot``'s delta into the shared base weights — mask-free.

    No dense mask is rebuilt: a compact lane scatters its kept blocks into
    the base (pruned coordinates untouched — the base is exactly zero there
    by the topology invariant), and a dense lane is zero off-mask by the
    same invariant, so a plain add preserves base sparsity bit-exactly
    (the TopologyService fold-exactness property). Only ``hidden/w`` is
    rebuilt — every other key in ``params`` (present or added by a future
    PR) rides through the generic dict update untouched. The serving
    topology service reuses this as its fold-hot-streams step.
    """
    lane = deltas[slot]
    if lane.ndim == 5:               # compact [L, J, T, bk, bo]
        from repro.core import topology as topology_lib
        idx = topology_lib.stacked_kept_ids(params["hidden"]["mask"], cfg)
        lane = engine.densify_deltas(lane[None], idx, cfg)[0]
    w = params["hidden"]["w"] + weight * lane
    return {**params, "hidden": {**params["hidden"], "w": w}}
