"""Per-stream online OSSL adaptation under serving load.

Parameter layout: a **frozen shared base** (the trained weights every
stream serves from) plus a **per-stream delta** tensor per hidden layer,
``[n_slots, fan_in, n_hidden]``. Each slot's effective weights are
``w_base + delta[slot]``; the activity-dependent gating engine (per-stream
IA/SS thresholds inside ``core.snn.run_chunk``) decides when a stream's
delta absorbs a three-factor OSSL update. A silent or repetitive stream
never pays weight-update energy and never drifts.

This module owns everything *around* the jitted step:

* ``make_chunk_fn`` — jit the chunk step once per (chunk_len, n_slots)
  geometry; the returned callable is the single compiled artifact the
  scheduler drives (compilation-count checked in the serving benchmark);
* per-stream adapt on/off (``adapt_mask``) applied by freezing a lane's
  delta across the step — exactly equivalent to gating the update off,
  while trace/threshold state keeps tracking the stream;
* delta hygiene: multiplicative decay toward the base and a hard clip, so
  hours-long streams cannot diverge;
* ``merge_lane_into_base`` — promote one stream's adaptation into the
  shared base (fleet learning; the hook for DSST-under-traffic later).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.snn import ChunkMetrics, SNNConfig, StreamState, run_chunk


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    enabled: bool = True
    delta_decay: float = 1.0     # per-chunk multiplicative decay (1.0 = off)
    delta_clip: float = 0.5      # hard |delta| bound (0 = off)
    lr_scale: float = 1.0        # scales cfg.lr for the serving path


def make_chunk_fn(cfg: SNNConfig, adapt: AdaptConfig | None = None):
    """Build the jitted slot-grid step.

    Returns ``fn(params, deltas, state, events, valid, adapt_mask)`` ->
    ``(deltas, state, metrics)`` with static shapes: ``events`` [C, S, n_in],
    ``valid`` [C, S] bool, ``adapt_mask`` [S] bool. One compilation serves
    any number of streams multiplexed through the S slots.
    """
    adapt = adapt or AdaptConfig()
    scfg = cfg if adapt.lr_scale == 1.0 else dataclasses.replace(
        cfg, lr=cfg.lr * adapt.lr_scale)
    traces = {"n": 0}   # bumps once per (re)trace — public-API compile count

    @jax.jit
    def chunk_fn(params, deltas, state: StreamState, events, valid, adapt_mask
                 ) -> Tuple[jax.Array, StreamState, ChunkMetrics]:
        traces["n"] += 1
        new_deltas, new_state, metrics = run_chunk(
            params, deltas, state, events, valid, scfg, learn=adapt.enabled)
        d = new_deltas                           # [S, L, Kmax, N]
        if adapt.delta_decay < 1.0:
            d = d * adapt.delta_decay
        if adapt.delta_clip > 0.0:
            d = jnp.clip(d, -adapt.delta_clip, adapt.delta_clip)
        # frozen lanes keep their old delta exactly (no decay/clip drift)
        out = jnp.where(adapt_mask[:, None, None, None], d, deltas)
        # a frozen lane must not be billed for weight updates either
        metrics = metrics._replace(
            sop_wu=metrics.sop_wu * adapt_mask,
            gate_opened=metrics.gate_opened * adapt_mask[:, None])
        return out, new_state, metrics

    chunk_fn.n_traces = lambda: traces["n"]
    return chunk_fn


def delta_norms(deltas: jax.Array) -> jax.Array:
    """Per-slot L2 norm of the adaptation, summed over layers. [S].

    ``deltas``: the stacked ``[S, L, Kmax, N]`` per-stream tensor.
    """
    return jnp.sqrt((deltas * deltas).sum((2, 3))).sum(1)


def merge_lane_into_base(params: Dict[str, Any], deltas: jax.Array, slot: int,
                         cfg: SNNConfig, weight: float = 1.0) -> Dict[str, Any]:
    """Fold stream ``slot``'s delta into the shared base weights.

    The N:M mask is re-applied so the base stays sparse (deltas are already
    mask-projected at update time; this re-asserts the invariant exactly).
    """
    masks_f = engine.dense_masks(params["hidden"]["mask"], cfg)
    w = (params["hidden"]["w"] + weight * deltas[slot]) * masks_f
    return {"hidden": {"w": w, "mask": params["hidden"]["mask"]},
            "readout": params["readout"]}
