"""Fleet checkpointing in the compact delta layout, with migration.

``save_fleet`` snapshots one serving fleet's weight state — canonical dense
``params`` plus the per-stream delta tensor in whatever layout the fleet
runs (compact ``[S, L, J, T, bk, bo]`` on the default hot path, dense
``[S, L, Kmax, N]`` for the baseline) and the carried ``StreamState`` —
through the atomic keep-K ``repro.checkpoint`` layer.

``restore_fleet`` is layout-migrating: it ``checkpoint.peek``\\ s the stored
delta leaf's rank first, restores into a matching template, and — when a
pre-compact checkpoint (dense rank-4 deltas) is restored into a compact
fleet — gathers the kept blocks through the restored mask's own
``stacked_kept_ids``. The gather is the same one the live projection uses,
so migrated deltas are bit-exact at every kept coordinate (off-mask dense
entries are zero by the topology invariant and carry no information).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.checkpoint import checkpoint
from repro.core import engine
from repro.core import topology as topology_lib
from repro.core.snn import (SNNConfig, StreamState, init_stream_deltas,
                            init_stream_state)

_DENSE_DELTA_RANK = 4      # [S, L, Kmax, N] — the pre-compact layout


def _fleet_tree(params, deltas, state: StreamState):
    return {"params": params, "deltas": deltas, "state": state}


def save_fleet(base: str, step: int, params: Dict[str, Any],
               deltas: jax.Array, state: StreamState,
               extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Checkpoint one fleet's ``(params, deltas, state)`` at ``step``.

    ``deltas`` are stored in their native layout — compact fleets persist
    compact tensors (the on-disk footprint scales with density too).
    """
    extra = dict(extra or {})
    extra["n_slots"] = int(deltas.shape[0])
    extra["delta_layout"] = "compact" if deltas.ndim == 6 else "dense"
    return checkpoint.save(base, step, _fleet_tree(params, deltas, state),
                           extra=extra, keep=keep)


def restore_fleet(base: str, cfg: SNNConfig, step: Optional[int] = None,
                  compact: Optional[bool] = None
                  ) -> Tuple[int, Dict[str, Any], jax.Array, StreamState,
                             Dict]:
    """Restore ``(step, params, deltas, state, extra)``, migrating layout.

    ``compact`` picks the layout the *caller's fleet* runs (None = the
    ``init_stream_deltas`` auto default). A dense-stored checkpoint
    restored into a compact fleet is migrated by ``engine.compact_deltas``
    over the restored mask's kept-block ids; a compact-stored checkpoint
    restored into a dense fleet densifies the same way. Same-layout
    restores are the checkpoint layer's usual bitwise round trip.
    """
    step, shapes, _ = checkpoint.peek(base, step)
    stored_rank = len(shapes["deltas"][0])
    n_slots = shapes["deltas"][0][0]
    stored_compact = stored_rank != _DENSE_DELTA_RANK

    from repro.core.snn import init_params
    template = _fleet_tree(
        init_params(jax.random.PRNGKey(0), cfg),
        init_stream_deltas(cfg, n_slots, compact=stored_compact),
        init_stream_state(cfg, n_slots))
    step, tree, extra = checkpoint.restore(base, template, step=step)
    params, deltas = tree["params"], tree["deltas"]

    want_compact = engine.geometry(cfg).uniform if compact is None \
        else compact
    if want_compact != stored_compact:
        idx = topology_lib.stacked_kept_ids(params["hidden"]["mask"], cfg)
        if want_compact:
            deltas = engine.compact_deltas(deltas, idx, cfg)
        else:
            deltas = engine.densify_deltas(deltas, idx, cfg)
    return step, params, deltas, tree["state"], extra
