"""Live DSST topology evolution under serving traffic.

PR 1–3 froze the N:M topology the moment a fleet started serving: the base
weights and mask were whatever offline training left behind, and only the
per-stream deltas moved.  ElfCore's claim is stronger — dynamic structured
sparse training, online self-supervised learning and activity-dependent
updates run *together* — so this service closes the loop: the connectivity
itself keeps evolving from live activity, without draining a single session.

The cycle, driven by ``StreamScheduler.maybe_evolve_topology()``:

1. **Accumulate** — every grid step the chunk metrics carry DSST factors
   (summed |pre trace| and |OSSL modulator|, computed valid-masked and
   per-slot inside the engine scan, then slot-reduced **on device** by the
   jitted chunk fn with the order-fixed ``engine.ordered_slot_sum`` — the
   host fetches ``pre_mag [L, Kmax]`` / ``post_mag [L, N]``, a few KB,
   instead of a per-step ``[S, L, ·]`` transfer).
   :meth:`TopologyService.observe` folds them into one decaying
   ``DSSTAccumulator`` per layer, stacked — O(K + N) per layer, the chip's
   factorized write-back.
2. **Fold** — hot streams' adaptations are promoted into the shared base
   (``adapt.merge_lane_into_base``, the generic pytree update): the lanes
   with the largest delta norms among the active adaptive slots merge with
   ``merge_weight`` and their lane delta is scaled down by the same factor,
   so a fully-merged lane's *effective* weights are bit-identical across
   the fold.
3. **Evolve** — one stacked prune/regrow epoch via
   ``core.topology.topology_epoch`` — the *same* code path the offline
   train step runs — with ``k`` following the ``DSSTConfig`` decay schedule
   at the service's epoch index.
4. **Remap & swap** — weights keep surviving values bit-exactly (recycled
   coordinates restart at zero) and the slot-sharded delta tensor is
   projected through ``topology.project_deltas`` (survivors bit-exact,
   pruned zeroed).  Everything keeps its shape, dtype and sharding, so the
   scheduler swaps ``(params, deltas)`` between grid steps with **zero
   recompilation** of the chunk step — the exactly-N-per-group invariant is
   asserted after every epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import topology as topology_lib
from repro.core.snn import ChunkMetrics, SNNConfig

from .adapt import delta_norms, merge_lane_into_base


@dataclasses.dataclass(frozen=True)
class TopologyServiceConfig:
    epoch_every: int = 100       # grid steps between prune/regrow epochs
    accum_decay: float = 0.9     # per-grid-step decay of the pre/post factors
    min_observed_steps: float = 1.0   # valid timesteps required before an epoch
    merge_top: int = 0           # hot streams folded into the base per epoch
    merge_weight: float = 1.0    # fraction of a hot lane's delta promoted
    merge_min_norm: float = 1e-6  # lanes below this delta norm never merge


@dataclasses.dataclass(frozen=True)
class TopologyEpochEvent:
    """What one live prune/regrow epoch did (telemetry record)."""
    epoch: int                   # 0-based epoch index
    grid_step: int               # scheduler step the swap landed after
    pruned: int                  # connections recycled (sum over layers)
    regrown: int
    mask_change: float           # mean fraction of units flipped per layer
    merged_slots: Tuple[int, ...]  # hot lanes folded into the base first


class TopologyService:
    """Accumulates live DSST factors and evolves the fleet's topology.

    Host-side object: the accumulators are tiny (O(L·(K + N))) numpy
    buffers fed from already-fetched chunk metrics; the epoch itself runs
    as ordinary jax ops on the scheduler's (possibly slot-sharded) arrays.
    One service instance belongs to one scheduler/fleet.
    """

    def __init__(self, cfg: SNNConfig,
                 service: Optional[TopologyServiceConfig] = None):
        self.cfg = cfg
        self.service = service or TopologyServiceConfig()
        kbs, js = [], []
        for fan_in in cfg.layer_fanins:
            kb, j = cfg.spec(fan_in).unit_counts(fan_in, cfg.n_hidden)
            kbs.append(kb)
            js.append(j)
        self._kbs, self._js = kbs, js
        self._kb_max = max(kbs)
        self._j_max = max(js)
        self.epoch_idx = 0
        self.observed_steps = 0.0
        self._last_epoch_step = 0
        self.events: List[TopologyEpochEvent] = []
        self._reset_accumulators()

    def _reset_accumulators(self) -> None:
        # Both factors are accumulated, as the chip writes both back. Note
        # that under the rank-1 factored regrow the within-group ranking
        # depends on |pre| alone (prune_regrow_factored discards the column
        # factor); |post| is carried for parity with the train-path
        # accumulator and for scorers that do consume it (dense-oracle
        # fallback, cross-group tie-breaking).
        L = self.cfg.n_layers
        self.pre = np.zeros((L, self._kb_max), np.float32)
        self.post = np.zeros((L, self._j_max), np.float32)
        self.observed_steps = 0.0

    # -- 1. accumulate --------------------------------------------------------
    def observe(self, metrics: ChunkMetrics) -> None:
        """Fold one grid step's chunk metrics into the decaying factors.

        ``metrics`` is the (host-fetched) ``ChunkMetrics`` of a chunk step;
        ``pre_mag``/``post_mag`` are valid-masked inside the engine, so idle
        slots and ragged tails contribute exactly zero.  The serving chunk
        fn (``adapt.make_chunk_fn(want_factors=True)``) hands them over
        already slot-reduced — ``[L, Kmax]`` / ``[L, N]`` — by the
        order-fixed device-side ``engine.ordered_slot_sum``, whose fixed
        reduction tree is what keeps epoch decisions bit-identical between
        the 1-device and slot-sharded fleets (a bare ``.sum(0)``'s order
        may not match across shardings).  Raw per-slot ``[S, L, ·]``
        factors straight out of ``snn.run_chunk`` are also accepted and
        reduced here on host (np's fixed sequential order).
        """
        if metrics.pre_mag is None:
            raise ValueError(
                "chunk metrics carry no DSST factors (want_factors=False); "
                "a live topology service needs a factor-bearing chunk fn")
        pre = np.asarray(metrics.pre_mag, np.float32)
        post = np.asarray(metrics.post_mag, np.float32)
        if pre.ndim == 3:                      # [S, L, ·]: raw run_chunk form
            pre, post = pre.sum(0), post.sum(0)
        d = self.service.accum_decay
        self.pre *= d
        self.post *= d
        for l, fan_in in enumerate(self.cfg.layer_fanins):
            kb, j = self._kbs[l], self._js[l]
            self.pre[l, :kb] += pre[l, :fan_in].reshape(kb, -1).sum(-1)
            self.post[l, :j] += post[l].reshape(j, -1).sum(-1)
        self.observed_steps += float(np.asarray(metrics.steps).sum())

    @property
    def virtual_step(self) -> int:
        """The host-int step the next epoch presents to the DSST schedule —
        epoch index mapped onto the config's period, so ``frac_decay``/
        ``start_step``/``stop_step`` mean the same thing they do offline."""
        dcfg = self.cfg.dsst
        return dcfg.start_step + self.epoch_idx * max(1, dcfg.period)

    @property
    def frozen(self) -> bool:
        """True when the config says connectivity must not evolve: DSST off,
        dense baseline, or past the RigL-style ``stop_step`` cool-down —
        serve honors the same freeze the train path enforces via
        ``is_update_step``."""
        return (not self.cfg.dsst_enabled or self.cfg.dense
                or self.virtual_step >= self.cfg.dsst.stop_step)

    def due(self, grid_step: int) -> bool:
        """True when the next prune/regrow epoch should run after this grid
        step: connectivity is not frozen, the cadence has elapsed AND enough
        valid traffic was observed (an idle fleet never churns its topology
        on all-zero scores)."""
        if self.frozen:
            return False
        if grid_step - self._last_epoch_step < self.service.epoch_every:
            return False
        return self.observed_steps >= self.service.min_observed_steps

    # -- 2. fold hot streams --------------------------------------------------
    def _fold_hot_streams(self, params: Dict[str, Any], deltas: jnp.ndarray,
                          merge_slots: Sequence[int]
                          ) -> Tuple[Dict[str, Any], jnp.ndarray, Tuple[int, ...]]:
        svc = self.service
        if svc.merge_top <= 0 or not merge_slots:
            return params, deltas, ()
        norms = np.asarray(delta_norms(deltas))
        eligible = [s for s in merge_slots if norms[s] > svc.merge_min_norm]
        hot = tuple(sorted(eligible, key=lambda s: -norms[s])[: svc.merge_top])
        for slot in hot:
            params = merge_lane_into_base(params, deltas, slot, self.cfg,
                                          weight=svc.merge_weight)
            if svc.merge_weight >= 1.0:
                # exact: the lane's effective weights are unchanged bits
                lane = jnp.zeros_like(deltas[slot])
            else:
                lane = deltas[slot] * (1.0 - svc.merge_weight)
            deltas = deltas.at[slot].set(lane)
        return params, deltas, hot

    # -- 3 & 4. evolve + remap ------------------------------------------------
    def evolve(self, params: Dict[str, Any], deltas: jnp.ndarray,
               merge_slots: Sequence[int] = (), grid_step: int = 0
               ) -> Tuple[Dict[str, Any], jnp.ndarray, TopologyEpochEvent]:
        """One live topology epoch. Returns ``(params', deltas', event)``.

        Shapes, dtypes and (slot-)shardings of both outputs match the
        inputs, so the caller installs them with a plain swap between grid
        steps — no session drains, no recompilation.
        """
        if self.frozen:
            raise ValueError(
                "topology is frozen (dsst disabled, dense baseline, or past "
                f"stop_step={self.cfg.dsst.stop_step}); refusing to evolve")
        params, deltas, merged = self._fold_hot_streams(
            params, deltas, merge_slots)

        old_mask = params["hidden"]["mask"]
        # host-int virtual step -> this epoch's k from the decay schedule
        new_params, stats = topology_lib.topology_epoch(
            params, jnp.asarray(self.pre), jnp.asarray(self.post),
            self.cfg, step=self.virtual_step)
        new_deltas = topology_lib.project_deltas(
            deltas, old_mask, new_params["hidden"]["mask"], self.cfg)

        assert topology_lib.check(new_params["hidden"]["mask"], self.cfg), \
            "topology epoch violated the exactly-N-per-group invariant"

        event = TopologyEpochEvent(
            epoch=self.epoch_idx, grid_step=int(grid_step),
            pruned=int(stats.total_pruned), regrown=int(stats.total_regrown),
            mask_change=float(np.asarray(stats.mask_change).mean()),
            merged_slots=merged)
        self.events.append(event)
        self.epoch_idx += 1
        self._last_epoch_step = int(grid_step)
        self._reset_accumulators()
        return new_params, new_deltas, event
