"""Per-stream and fleet-level serving telemetry, on the obs registry.

The chip's power story is counted events priced at measured constants
(core/energy.py); the serving runtime keeps that bookkeeping per stream so
a fleet operator can answer "which streams are hot, which are coasting on
the gate, what does a slot-second cost". Counters are monotone by
construction — every update adds a non-negative per-chunk quantity (the
``obs.metrics.Counter`` underneath *raises* on a negative increment) —
and per-stream separable: a slot's counters only ever receive that slot's
lane of the chunk metrics.

Since the observability PR, ``FleetTelemetry`` is a facade over a
:class:`repro.obs.metrics.MetricsRegistry`: stream counters are labeled
``serving_stream_*_total{sid=...}`` counter families, step/phase wall
times land in **bounded fixed-bucket histograms** (the old unbounded
``step_latencies_s`` list is gone — memory is O(1) in steps, p50/p99 are
interpolated within ~10% bucket width), and the whole registry exports as
Prometheus text / JSONL / a benchmark artifact via ``repro.obs.export``.

Beyond whole-step wall time the telemetry now attributes **per-phase**
wall (stage / dispatch / retire / flush — fed by the scheduler's spans,
each tagged with the grid step it belongs to even when pipelining blurs
their wall-clock order) and the per-step **host/device overlap ratio**:
``hidden / (hidden + wait)`` where *hidden* is the time an in-flight step
spent computing while the host staged the next one, and *wait* is the
retire-phase device block. ~1 means the fleet is host-bound (a deeper
pipeline buys nothing); ~0 means device-bound (staging hides nothing).
This is the occupancy signal adaptive ``pipeline_depth`` control needs.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.core.energy import OperatingPoint, report
from repro.obs.metrics import (LATENCY_BUCKETS_S, QUEUE_DEPTH_BUCKETS,
                               RATIO_BUCKETS, MetricsRegistry)

# every per-stream counter family: attribute name -> (metric name, help)
STREAM_COUNTER_FAMILIES = {
    "timesteps": ("serving_stream_timesteps_total",
                  "valid timesteps advanced"),
    "events_in": ("serving_stream_events_in_total",
                  "input spikes consumed"),
    "sop_forward": ("serving_stream_sop_forward_total",
                    "forward synaptic ops"),
    "sop_wu": ("serving_stream_sop_wu_total",
               "weight-update MACs actually paid"),
    "sop_wu_offered": ("serving_stream_sop_wu_offered_total",
                       "weight-update MACs offered to the gate"),
    "gate_opened": ("serving_stream_gate_opened_total",
                    "gate-open decisions"),
    "gate_offered": ("serving_stream_gate_offered_total",
                     "gate decisions offered"),
    "windows": ("serving_stream_windows_total",
                "completed T-step windows (predictions)"),
}

# cumulative but NOT monotone (a local loss can be negative) — gauge-backed
STREAM_GAUGE_FAMILIES = {
    "local_loss": ("serving_stream_local_loss_sum",
                   "summed local OSSL loss"),
}

PHASES = ("stage", "dispatch", "retire", "flush")

# per-tier QoS counter families: attribute name -> (metric name, help).
# These are *additive* next to the per-stream families above — the stream
# families keep their single ``sid`` label (exporter goldens and the
# phase-percentile keying depend on it); tier rollups get their own
# ``tier``-labeled families instead of a second label on the old ones.
TIER_COUNTER_FAMILIES = {
    "timesteps": ("serving_tier_timesteps_total",
                  "valid timesteps advanced, by QoS tier"),
    "events_in": ("serving_tier_events_in_total",
                  "input spikes consumed, by QoS tier"),
    "sop_forward": ("serving_tier_sop_forward_total",
                    "forward synaptic ops, by QoS tier"),
    "sop_wu": ("serving_tier_sop_wu_total",
               "weight-update MACs actually paid, by QoS tier"),
    "sop_wu_offered": ("serving_tier_sop_wu_offered_total",
                       "weight-update MACs offered to the gate, by QoS tier"),
    "windows": ("serving_tier_windows_total",
                "completed T-step windows (predictions), by QoS tier"),
}


class StreamCounters:
    """Monotone per-stream event counters (energy-model inputs).

    A view over one ``sid``'s children of the registry's labeled counter
    families: reads (``c.timesteps`` etc.) pull the live counter values,
    :meth:`add_chunk` increments them. Negative increments raise in the
    counter itself — monotonicity is enforced, not just asserted.
    """

    def __init__(self, sid: int, registry: Optional[MetricsRegistry] = None):
        self.sid = sid
        registry = registry or MetricsRegistry()
        self._c = {
            attr: registry.counter(name, help, labels=("sid",))
                          .labels(sid=str(sid))
            for attr, (name, help) in STREAM_COUNTER_FAMILIES.items()}
        self._c.update({
            attr: registry.gauge(name, help, labels=("sid",))
                          .labels(sid=str(sid))
            for attr, (name, help) in STREAM_GAUGE_FAMILIES.items()})

    def __getattr__(self, attr):
        try:
            child = self.__dict__["_c"][attr]
        except KeyError:
            raise AttributeError(attr) from None
        return int(child.value) if attr == "windows" else child.value

    def add_chunk(self, *, steps, events_in, sop_forward, sop_wu,
                  sop_wu_offered, gate_opened, gate_offered, windows,
                  local_loss) -> None:
        """Fold one grid step's slice of the chunk metrics into this
        stream's counters (all non-negative scalars — a negative one is a
        bug upstream and raises here)."""
        self._c["timesteps"].inc(float(steps))
        self._c["events_in"].inc(float(events_in))
        self._c["sop_forward"].inc(float(sop_forward))
        self._c["sop_wu"].inc(float(sop_wu))
        self._c["sop_wu_offered"].inc(float(sop_wu_offered))
        self._c["gate_opened"].inc(float(gate_opened))
        self._c["gate_offered"].inc(float(gate_offered))
        self._c["windows"].inc(int(windows))
        self._c["local_loss"].inc(float(local_loss))

    @property
    def wu_skip_rate(self) -> float:
        """Fraction of offered WU MACs the activity gate skipped (0.0 when
        nothing was offered — e.g. an adapt=False stream)."""
        if self.sop_wu_offered <= 0:
            return 0.0
        return 1.0 - self.sop_wu / self.sop_wu_offered

    def energy(self, op: Optional[OperatingPoint] = None) -> dict:
        """This stream's counters priced at operating point ``op`` (the
        chip's 0.6 V low-power point by default): the ``core.energy``
        report dict + ``sid``/``timesteps``/``windows``."""
        rep = report(self.sop_forward, self.sop_wu, self.sop_wu_offered,
                     self.timesteps, op=op)
        out = rep.as_dict()
        out["sid"] = self.sid
        out["timesteps"] = self.timesteps
        out["windows"] = self.windows
        return out


class FleetTelemetry:
    """Rollup across streams + host-side step/phase latency + overlap.

    Pass (or read) ``registry`` to share one :class:`MetricsRegistry`
    across subsystems and export everything in one Prometheus scrape.
    """

    def __init__(self, op: Optional[OperatingPoint] = None,
                 registry: Optional[MetricsRegistry] = None,
                 max_epoch_events: int = 256):
        self.op = op or OperatingPoint.low_power()
        self.registry = registry or MetricsRegistry()
        self.streams: Dict[int, StreamCounters] = {}
        self._lock = threading.Lock()
        self._steps = self.registry.counter(
            "serving_grid_steps_total", "scheduler grid steps dispatched")
        self._step_hist = self.registry.histogram(
            "serving_step_latency_seconds",
            "host wall time of one StreamScheduler.step() call",
            buckets=LATENCY_BUCKETS_S)
        self._phase_hist = self.registry.histogram(
            "serving_phase_seconds",
            "per-phase host wall time, attributed to the owning grid step",
            labels=("phase",), buckets=LATENCY_BUCKETS_S)
        self._flush_wall = self.registry.counter(
            "serving_flush_seconds_total",
            "pipeline-flush wall (retires after the last grid step)")
        self._overlap_hist = self.registry.histogram(
            "serving_overlap_ratio",
            "per-step host/device overlap: hidden / (hidden + wait)",
            buckets=RATIO_BUCKETS)
        self._hidden_s = self.registry.counter(
            "serving_overlap_hidden_seconds_total",
            "device compute hidden behind host staging")
        self._wait_s = self.registry.counter(
            "serving_device_wait_seconds_total",
            "retire-phase blocks on device results")
        self._topo_epochs = self.registry.counter(
            "serving_topology_epochs_total", "live DSST prune/regrow epochs")
        self._topo_pruned = self.registry.counter(
            "serving_topology_pruned_total", "connections pruned by epochs")
        self._topo_regrown = self.registry.counter(
            "serving_topology_regrown_total", "connections regrown by epochs")
        self._topo_merged = self.registry.counter(
            "serving_streams_merged_total", "hot streams folded into base")
        self._topo_mask_change = self.registry.gauge(
            "serving_topology_mask_change", "last epoch's mask-change frac")
        self._topo_mask_change_sum = self.registry.counter(
            "serving_topology_mask_change_sum",
            "summed per-epoch mask-change fractions (mean = sum / epochs)")
        self._bytes_held = self.registry.gauge(
            "serving_bytes_held",
            "resident bytes of serving weight state (params = the exec "
            "weight rep the chunk fn consumes, deltas = the per-stream "
            "adaptation tensor) — the memory-accounting A/B signal for the "
            "compact vs dense layout", labels=("kind",))
        # -- QoS tiers / adaptive depth / async ingest ------------------------
        self._tier_step_hist = self.registry.histogram(
            "serving_tier_step_seconds",
            "host wall of one tier's slice of a grid step",
            labels=("tier",), buckets=LATENCY_BUCKETS_S)
        self._tier_phase_hist = self.registry.histogram(
            "serving_tier_phase_seconds",
            "per-tier per-phase host wall time",
            labels=("tier", "phase"), buckets=LATENCY_BUCKETS_S)
        self._tier_counters = {
            attr: self.registry.counter(name, help, labels=("tier",))
            for attr, (name, help) in TIER_COUNTER_FAMILIES.items()}
        self._depth_gauge = self.registry.gauge(
            "serving_pipeline_depth",
            "current staging pipeline depth (autopilot-set or fixed)")
        self._depth_changes = self.registry.counter(
            "serving_pipeline_depth_changes_total",
            "adaptive depth changes applied at drain-safe boundaries")
        self._overlap_ema = self.registry.gauge(
            "serving_overlap_ema",
            "the depth autopilot's EMA of the per-step overlap ratio")
        self._ingest_chunks = self.registry.counter(
            "serving_ingest_chunks_total",
            "source chunks drained from the async ingest queues")
        self._ingest_queue_peak = self.registry.gauge(
            "serving_ingest_queue_peak_chunks",
            "high-water per-stream ingest queue depth (backpressure caps "
            "it at the configured capacity)")
        self._ingest_drain_hist = self.registry.histogram(
            "serving_ingest_drained_chunks",
            "chunks released to session buffers per poll-window drain",
            buckets=QUEUE_DEPTH_BUCKETS)
        # recent-events ring: the per-epoch *log* is bounded (a long-lived
        # fleet otherwise grows it forever — the lint's OBS01 class), while
        # the exact aggregates live in the registry counters above and
        # topology_rollup() reads those, so truncation loses no totals.
        self.topology_epochs: Deque[dict] = deque(maxlen=max_epoch_events)

    @property
    def steps(self) -> int:
        """Grid steps recorded (dispatches; flush retires excluded)."""
        return int(self._steps.value)

    def stream(self, sid: int) -> StreamCounters:
        """The (created-on-first-use) per-stream counter record for ``sid``.
        Creation is locked so concurrent sources racing on a new sid get
        the same record (never two counter children for one stream)."""
        with self._lock:
            if sid not in self.streams:
                self.streams[sid] = StreamCounters(sid, self.registry)
            return self.streams[sid]

    def record_step(self, latency_s: float) -> None:
        """Log one grid step's host wall time (one ``step()`` call — under
        a staging pipeline the retire inside belongs to an earlier grid
        step, but the *sum* over steps still accounts every phase exactly
        once; per-phase attribution lives in ``record_phase``)."""
        self._steps.inc()
        self._step_hist.observe(float(latency_s))

    def record_flush(self, latency_s: float) -> None:
        """Log pipeline-flush wall time (retiring in-flight steps after the
        last grid step). Not a grid step — excluded from the latency
        percentiles, but included in the throughput wall so pipelined
        events/s never get a free final step."""
        self._flush_wall.inc(float(latency_s))

    def record_phase(self, phase: str, latency_s: float) -> None:
        """Log one phase's host wall time (stage/dispatch/retire/flush).
        The scheduler calls this from the span that also carries the
        owning ``grid_step`` — so phase sums reconcile with step walls
        regardless of pipeline reordering (pinned in tests)."""
        self._phase_hist.labels(phase=phase).observe(float(latency_s))

    def record_overlap(self, hidden_s: float, wait_s: float) -> float:
        """Log one retired step's host/device overlap; returns the ratio.

        ``hidden_s``: how long the step was in flight while the host did
        useful work (dispatch → retire-start). ``wait_s``: how long retire
        then blocked on the device. Serial (unpipelined) steps record
        hidden=0 → ratio 0.
        """
        hidden_s, wait_s = max(0.0, float(hidden_s)), max(0.0, float(wait_s))
        denom = hidden_s + wait_s
        ratio = hidden_s / denom if denom > 0 else 0.0
        self._hidden_s.inc(hidden_s)
        self._wait_s.inc(wait_s)
        self._overlap_hist.observe(ratio)
        return ratio

    def record_tier_step(self, tier: str, latency_s: float) -> None:
        """Log one tier's slice of a grid step's host wall (the per-tier
        stage→dispatch[→retire] block inside ``step()``) — the histogram
        behind the per-tier p50/p99 the QoS bench rows report."""
        self._tier_step_hist.labels(tier=tier).observe(float(latency_s))

    def record_tier_phase(self, tier: str, phase: str,
                          latency_s: float) -> None:
        """Per-tier per-phase host wall (the ``tier``-labeled companion of
        ``record_phase`` — that family keeps its single ``phase`` label)."""
        self._tier_phase_hist.labels(tier=tier, phase=phase).observe(
            float(latency_s))

    def record_tier_chunk(self, tier: str, *, timesteps, events_in,
                          sop_forward, sop_wu, sop_wu_offered,
                          windows) -> None:
        """Fold one retired grid step's tier-summed metrics into the
        ``tier``-labeled counter families (the per-stream counters record
        the same quantities per sid; these are the QoS rollup view)."""
        c = self._tier_counters
        c["timesteps"].labels(tier=tier).inc(float(timesteps))
        c["events_in"].labels(tier=tier).inc(float(events_in))
        c["sop_forward"].labels(tier=tier).inc(float(sop_forward))
        c["sop_wu"].labels(tier=tier).inc(float(sop_wu))
        c["sop_wu_offered"].labels(tier=tier).inc(float(sop_wu_offered))
        c["windows"].labels(tier=tier).inc(int(windows))

    def record_depth(self, depth: int, changed: bool = False) -> None:
        """Log the pipeline depth now in force; ``changed=True`` counts an
        autopilot change applied at a drain-safe boundary."""
        self._depth_gauge.set(float(depth))
        if changed:
            self._depth_changes.inc()

    def record_overlap_ema(self, ema: float) -> None:
        """Export the autopilot's overlap-ratio EMA (the control signal —
        next to the raw per-step ``serving_overlap_ratio`` histogram)."""
        self._overlap_ema.set(float(ema))

    def record_ingest(self, chunks: int, queue_peak: int) -> None:
        """Log one poll-window drain of the async ingest queues: chunks
        released to session buffers this tick, plus the worker's lifetime
        high-water per-stream queue depth (bounded by the configured
        capacity — the backpressure invariant the QoS tests assert)."""
        self._ingest_chunks.inc(int(chunks))
        self._ingest_queue_peak.set(float(queue_peak))
        self._ingest_drain_hist.observe(float(chunks))

    def record_bytes_held(self, params_bytes: int, delta_bytes: int) -> None:
        """Log the resident serving weight-state bytes (scheduler-measured
        ``.nbytes`` of the exec weight rep and the delta tensor). Gauges,
        not counters: re-recorded every grid step, they track the *current*
        layout — a topology swap or layout change moves them."""
        self._bytes_held.labels(kind="params").set(float(params_bytes))
        self._bytes_held.labels(kind="deltas").set(float(delta_bytes))
        self._bytes_held.labels(kind="total").set(
            float(params_bytes + delta_bytes))

    def bytes_held(self) -> dict:
        """Last-recorded resident bytes {params, deltas, total} (0 before
        the first grid step)."""
        fam = self.registry.get("serving_bytes_held")
        out = {"params": 0.0, "deltas": 0.0, "total": 0.0}
        if fam is not None:
            for values, child in fam.samples():
                kind = dict(zip(fam.labelnames, values)).get("kind", "total")
                out[kind] = float(child.value)
        return out

    def record_topology_epoch(self, *, grid_step: int, pruned: int,
                              regrown: int, mask_change: float,
                              merged_streams: int) -> None:
        """Log one live DSST prune/regrow epoch (topology_service.py)."""
        self._topo_epochs.inc()
        self._topo_pruned.inc(int(pruned))
        self._topo_regrown.inc(int(regrown))
        self._topo_merged.inc(int(merged_streams))
        self._topo_mask_change.set(float(mask_change))
        self._topo_mask_change_sum.inc(float(mask_change))
        with self._lock:
            self.topology_epochs.append({
                "grid_step": int(grid_step), "pruned": int(pruned),
                "regrown": int(regrown), "mask_change": float(mask_change),
                "merged_streams": int(merged_streams)})

    # -- rollup --------------------------------------------------------------
    def latency_percentiles(self) -> dict:
        """p50/p99 of recorded grid-step wall times, in milliseconds
        (interpolated from the bounded histogram — within one ~10% bucket
        of the exact list-based values the old telemetry computed)."""
        if self._step_hist.count == 0:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        return {"p50_ms": self._step_hist.percentile(50) * 1e3,
                "p99_ms": self._step_hist.percentile(99) * 1e3}

    def phase_percentiles(self) -> dict:
        """Per-phase ``{phase: {"p50_ms", "p99_ms", "total_s"}}`` for every
        phase that recorded at least one observation."""
        out = {}
        for values, child in self._phase_hist.samples():
            if child.count:
                out[values[0]] = {"p50_ms": child.percentile(50) * 1e3,
                                  "p99_ms": child.percentile(99) * 1e3,
                                  "total_s": child.sum}
        return out

    def tier_percentiles(self) -> dict:
        """Per-tier ``{tier: {"p50_ms", "p99_ms", "total_s"}}`` of the
        tier-step wall histogram — the per-tier latency view the QoS
        bench rows record (interactive p99 vs bulk p99)."""
        out = {}
        for values, child in self._tier_step_hist.samples():
            if child.count:
                out[values[0]] = {"p50_ms": child.percentile(50) * 1e3,
                                  "p99_ms": child.percentile(99) * 1e3,
                                  "total_s": child.sum}
        return out

    def per_tier(self) -> dict:
        """Per-tier counter rollup + energy: ``{tier: {timesteps,
        events_in, windows, wu_skip_rate, energy}}`` for every tier that
        retired at least one chunk (empty on a pre-tier fleet)."""
        acc: Dict[str, dict] = {}
        for attr, (name, _help) in TIER_COUNTER_FAMILIES.items():
            fam = self.registry.get(name)
            if fam is None:
                continue
            for values, child in fam.samples():
                acc.setdefault(values[0], {})[attr] = float(child.value)
        out = {}
        for tier, c in sorted(acc.items()):
            offered = c.get("sop_wu_offered", 0.0)
            out[tier] = {
                "timesteps": c.get("timesteps", 0.0),
                "events_in": c.get("events_in", 0.0),
                "windows": int(c.get("windows", 0)),
                "wu_skip_rate": (1.0 - c.get("sop_wu", 0.0) / offered
                                 if offered > 0 else 0.0),
                "energy": report(c.get("sop_forward", 0.0),
                                 c.get("sop_wu", 0.0), offered,
                                 c.get("timesteps", 0.0),
                                 op=self.op).as_dict(),
            }
        return out

    def tier_rollup(self) -> dict:
        """The QoS additions to :meth:`rollup`: per-tier counters/energy,
        per-tier latency percentiles, the depth/ingest state."""
        return {
            "tiers": self.per_tier(),
            "tier_latency": self.tier_percentiles(),
            "pipeline_depth": float(self._depth_gauge.value),
            "depth_changes": int(self._depth_changes.value),
            "ingest_chunks": int(self._ingest_chunks.value),
            "ingest_queue_peak": int(self._ingest_queue_peak.value),
        }

    def overlap_ratio(self) -> float:
        """Aggregate host/device overlap over the whole run:
        ``hidden_total / (hidden_total + wait_total)`` (0.0 serial)."""
        denom = self._hidden_s.value + self._wait_s.value
        return self._hidden_s.value / denom if denom > 0 else 0.0

    def rollup(self) -> dict:
        """Fleet-level summary: summed stream counters, throughput rates
        (events/s, timesteps/s over the recorded step + flush wall),
        latency percentiles, overlap ratio, fleet energy, and the topology
        rollup. See docs/SERVING.md / docs/OBSERVABILITY.md for the field
        glossary."""
        def fam_total(attr):
            fam = self.registry.get(STREAM_COUNTER_FAMILIES[attr][0])
            return fam.total() if fam is not None else 0.0

        timesteps = fam_total("timesteps")
        events_in = fam_total("events_in")
        sop_forward = fam_total("sop_forward")
        sop_wu = fam_total("sop_wu")
        sop_wu_offered = fam_total("sop_wu_offered")
        wall = self._step_hist.sum + self._flush_wall.value
        out = {
            "n_streams": len(self.streams),
            "grid_steps": self.steps,
            "timesteps": timesteps,
            "events_in": events_in,
            "windows": int(fam_total("windows")),
            "wu_skip_rate": (1.0 - sop_wu / sop_wu_offered
                             if sop_wu_offered > 0 else 0.0),
            "fleet_energy": report(sop_forward, sop_wu, sop_wu_offered,
                                   timesteps, op=self.op).as_dict(),
            "events_per_s": events_in / wall if wall > 0 else 0.0,
            "timesteps_per_s": timesteps / wall if wall > 0 else 0.0,
            "overlap_ratio": self.overlap_ratio(),
            "bytes_held": self.bytes_held(),
            **self.latency_percentiles(),
            **self.topology_rollup(),
            **self.tier_rollup(),
        }
        return out

    def topology_rollup(self) -> dict:
        """Aggregate topology-epoch stats (counts, mask-change mean, streams
        merged); all zeros for a frozen fleet. Read from the registry
        counters, not the event log — ``topology_epochs`` is a bounded
        recent-events ring, so these totals stay exact past its horizon."""
        epochs = int(self._topo_epochs.value)
        return {
            "topology_epochs": epochs,
            "topology_pruned": int(self._topo_pruned.value),
            "topology_regrown": int(self._topo_regrown.value),
            "topology_mask_change_mean":
                (float(self._topo_mask_change_sum.value) / epochs
                 if epochs else 0.0),
            "streams_merged": int(self._topo_merged.value),
        }

    def per_stream(self) -> List[dict]:
        """Each stream's energy report (sid-sorted) at the fleet's
        operating point."""
        return [c.energy(self.op) for _, c in sorted(self.streams.items())]
