"""Per-stream and fleet-level serving telemetry.

The chip's power story is counted events priced at measured constants
(core/energy.py); the serving runtime keeps that bookkeeping per stream so
a fleet operator can answer "which streams are hot, which are coasting on
the gate, what does a slot-second cost". Counters are monotone by
construction — every update adds a non-negative per-chunk quantity — and
per-stream separable: a slot's counters only ever receive that slot's lane
of the chunk metrics.

``FleetTelemetry`` also tracks host-side step latencies (the wall time of
one full ``StreamScheduler.step()`` — stage + dispatch + retire phases)
for the p50/p99 numbers in the serving benchmark, and — when a ``TopologyService`` drives live DSST epochs — a
log of topology events (per-epoch pruned/regrown counts, mask-change
fraction, hot-stream merges) so an operator can see connectivity churn
next to the energy counters it is supposed to pay for.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.energy import OperatingPoint, report


@dataclasses.dataclass
class StreamCounters:
    """Monotone per-stream event counters (energy-model inputs)."""
    sid: int
    timesteps: float = 0.0
    events_in: float = 0.0          # input spikes consumed
    sop_forward: float = 0.0
    sop_wu: float = 0.0
    sop_wu_offered: float = 0.0
    gate_opened: float = 0.0
    gate_offered: float = 0.0
    windows: int = 0                # completed T-step windows (predictions)
    local_loss: float = 0.0

    def add_chunk(self, *, steps, events_in, sop_forward, sop_wu,
                  sop_wu_offered, gate_opened, gate_offered, windows,
                  local_loss) -> None:
        """Fold one grid step's slice of the chunk metrics into this
        stream's counters (all non-negative scalars — monotonicity is by
        construction, pinned in tests)."""
        self.timesteps += float(steps)
        self.events_in += float(events_in)
        self.sop_forward += float(sop_forward)
        self.sop_wu += float(sop_wu)
        self.sop_wu_offered += float(sop_wu_offered)
        self.gate_opened += float(gate_opened)
        self.gate_offered += float(gate_offered)
        self.windows += int(windows)
        self.local_loss += float(local_loss)

    @property
    def wu_skip_rate(self) -> float:
        """Fraction of offered WU MACs the activity gate skipped (0.0 when
        nothing was offered — e.g. an adapt=False stream)."""
        if self.sop_wu_offered <= 0:
            return 0.0
        return 1.0 - self.sop_wu / self.sop_wu_offered

    def energy(self, op: Optional[OperatingPoint] = None) -> dict:
        """This stream's counters priced at operating point ``op`` (the
        chip's 0.6 V low-power point by default): the ``core.energy``
        report dict + ``sid``/``timesteps``/``windows``."""
        rep = report(self.sop_forward, self.sop_wu, self.sop_wu_offered,
                     self.timesteps, op=op)
        out = rep.as_dict()
        out["sid"] = self.sid
        out["timesteps"] = self.timesteps
        out["windows"] = self.windows
        return out


class FleetTelemetry:
    """Rollup across streams + host-side step-latency percentiles."""

    def __init__(self, op: Optional[OperatingPoint] = None):
        self.op = op or OperatingPoint.low_power()
        self.streams: Dict[int, StreamCounters] = {}
        self.step_latencies_s: List[float] = []
        self.steps = 0
        self.flush_wall_s = 0.0
        self.topology_epochs: List[dict] = []

    def stream(self, sid: int) -> StreamCounters:
        """The (created-on-first-use) per-stream counter record for ``sid``."""
        if sid not in self.streams:
            self.streams[sid] = StreamCounters(sid)
        return self.streams[sid]

    def record_step(self, latency_s: float) -> None:
        """Log one grid step's host wall time (stage+dispatch+retire of a
        ``StreamScheduler.step()`` call — under a staging pipeline the
        retire inside belongs to an earlier step, but the *sum* over steps
        still accounts every phase exactly once)."""
        self.steps += 1
        self.step_latencies_s.append(float(latency_s))

    def record_flush(self, latency_s: float) -> None:
        """Log pipeline-flush wall time (retiring in-flight steps after the
        last grid step). Not a grid step — excluded from the latency
        percentiles, but included in the throughput wall so pipelined
        events/s never get a free final step."""
        self.flush_wall_s += float(latency_s)

    def record_topology_epoch(self, *, grid_step: int, pruned: int,
                              regrown: int, mask_change: float,
                              merged_streams: int) -> None:
        """Log one live DSST prune/regrow epoch (topology_service.py)."""
        self.topology_epochs.append({
            "grid_step": int(grid_step), "pruned": int(pruned),
            "regrown": int(regrown), "mask_change": float(mask_change),
            "merged_streams": int(merged_streams)})

    # -- rollup --------------------------------------------------------------
    def latency_percentiles(self) -> dict:
        """p50/p99 of recorded grid-step wall times, in milliseconds."""
        if not self.step_latencies_s:
            return {"p50_ms": 0.0, "p99_ms": 0.0}
        lat = np.asarray(self.step_latencies_s) * 1e3
        return {"p50_ms": float(np.percentile(lat, 50)),
                "p99_ms": float(np.percentile(lat, 99))}

    def rollup(self) -> dict:
        """Fleet-level summary: summed stream counters, throughput rates
        (events/s, timesteps/s over the recorded step + flush wall),
        latency percentiles, fleet energy, and the topology rollup. See
        docs/SERVING.md for the field glossary."""
        tot = StreamCounters(sid=-1)
        for c in self.streams.values():
            tot.add_chunk(steps=c.timesteps, events_in=c.events_in,
                          sop_forward=c.sop_forward, sop_wu=c.sop_wu,
                          sop_wu_offered=c.sop_wu_offered,
                          gate_opened=c.gate_opened,
                          gate_offered=c.gate_offered, windows=c.windows,
                          local_loss=c.local_loss)
        wall = sum(self.step_latencies_s) + self.flush_wall_s
        out = {
            "n_streams": len(self.streams),
            "grid_steps": self.steps,
            "timesteps": tot.timesteps,
            "events_in": tot.events_in,
            "windows": tot.windows,
            "wu_skip_rate": tot.wu_skip_rate,
            "fleet_energy": tot.energy(self.op),
            "events_per_s": tot.events_in / wall if wall > 0 else 0.0,
            "timesteps_per_s": tot.timesteps / wall if wall > 0 else 0.0,
            **self.latency_percentiles(),
            **self.topology_rollup(),
        }
        return out

    def topology_rollup(self) -> dict:
        """Aggregate of the topology-epoch event log (counts, mask-change
        mean, streams merged); all zeros for a frozen fleet."""
        ep = self.topology_epochs
        return {
            "topology_epochs": len(ep),
            "topology_pruned": sum(e["pruned"] for e in ep),
            "topology_regrown": sum(e["regrown"] for e in ep),
            "topology_mask_change_mean":
                float(np.mean([e["mask_change"] for e in ep])) if ep else 0.0,
            "streams_merged": sum(e["merged_streams"] for e in ep),
        }

    def per_stream(self) -> List[dict]:
        """Each stream's energy report (sid-sorted) at the fleet's
        operating point."""
        return [c.energy(self.op) for _, c in sorted(self.streams.items())]
