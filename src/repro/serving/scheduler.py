"""Slot-multiplexed micro-batching for stateful SNN streams.

``StreamScheduler`` generalizes the continuous batcher's fixed slot grid
(``launch.batching.SlotGrid``) from token decode to SNN timesteps. One
jitted chunk step with static shapes — events ``[chunk_len, n_slots,
n_in]``, valid ``[chunk_len, n_slots]`` — advances every active stream by
up to ``chunk_len`` timesteps; admitted streams claim a lane (reset in
place), retired streams free it. Idle or ragged tails are masked invalid,
so they neither perturb state nor accrue telemetry: an empty slot costs
exactly zero counted events.

Each grid step runs through three explicit phases (see serving/staging.py):

1. **stage** — advance the virtual clock, drain newly arrived chunks into
   session buffers (from the async ingest queues, or by polling sources
   inline), admit queued sessions into free lanes, pack up to
   ``chunk_len`` buffered timesteps per active slot, and mark sessions
   that exhaust after this step;
2. **dispatch** — enqueue the single compiled chunk fn on the staged
   buffers (asynchronous — the host does not wait) and free the lanes of
   marked sessions so the next stage phase can re-admit into them;
3. **retire** — fetch the step's metrics (the only device wait), route
   window-end logits back to sessions as predictions, fold per-lane
   metrics into per-stream telemetry, finalize retiring sessions, and
   feed/drive the topology service.

With ``pipeline_depth=0`` (default) the phases run back-to-back — the
serial reference behavior. With ``pipeline_depth=1`` the scheduler
double-buffers: step ``t+1`` is staged while the device computes step
``t``, hiding host event assembly behind compute; lane surgery and
telemetry reads no longer force a device sync per step. Both modes
produce bit-identical per-stream trajectories (pinned in
``tests/test_serving_pipeline.py``) — call :meth:`flush` (or use
:meth:`run_until_drained`, which does) to drain in-flight bookkeeping.

**QoS tiers.** Passing ``tiers=[TierConfig(...), ...]`` splits the fleet
into per-tier slot grids — an ``interactive`` tier with a small
``chunk_len`` (windows close, and predictions land, after fewer staged
timesteps) next to a ``bulk`` tier with a long one (fewer dispatches per
timestep) — each tier owning its own grid, lane-batched device state and
jitted chunk fn over the *same* shared exec params. Tier assignment
happens at admission (``submit(session, tier=...)`` or
``session.tier``); per-tier wall/energy rollups land under a ``tier``
label in telemetry. Every tier's chunk fn compiles once at warmup and
never again (``n_compiles`` stays 1). Single-tier construction (the
default) is exactly the old scheduler: one tier named "default" built
from ``n_slots``/``chunk_len``.

**Async ingestion.** With ``ingest=True`` (or an ``IngestConfig`` /
``IngestWorker``), source polling moves off the grid-step critical path
to a dedicated worker thread (serving/ingest.py); ``_poll_sources``
becomes a lock-protected queue drain. Bit-identical to inline polling by
construction — the worker replays the virtual clock exactly. Call
:meth:`close` when done to stop the thread.

**Adaptive pipelining.** With ``autopilot=True`` (or an
``AutopilotConfig`` / ``DepthAutopilot``), a host-side controller
(serving/autopilot.py) retunes ``pipeline_depth`` from the EMA of the
measured per-step host/device overlap ratio — host-bound fleets deepen,
device-bound fleets hold — with hysteresis and a bounded range. Depth
changes land only at drain-safe boundaries (flush, then resize the empty
pipelines), so adaptive trajectories stay bit-identical to every fixed
depth they visited.

With a ``("slots",)`` mesh (``launch.mesh.make_serving_mesh``) the grid
shards over devices: each tier's slot allocation pads to the device
count (``launch.sharding.tier_slot_allocation``), the chunk step runs
under slot-axis ``shard_map`` (bit-identical to 1-device — see
serving/adapt.py), and lane surgery re-places its result so the slot
sharding survives admit/retire.

With a ``TopologyService`` attached (single-tier fleets only — an epoch
folds the whole fleet's deltas into one base), the chunk fn is built
with ``want_factors=True``: every retire phase feeds the service's DSST
accumulators and ``maybe_evolve_topology()`` runs due prune/regrow
epochs *between* grid steps; the evolved ``(params, deltas)`` keep their
shapes and slot shardings, so the swap is atomic from the streams' point
of view and the chunk step never recompiles.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import engine
from repro.core.snn import (SNNConfig, init_stream_deltas, init_stream_state,
                            serving_params)
from repro.launch import sharding
from repro.launch.batching import SlotGrid
from repro.obs.trace import NULL_TRACER, Tracer

from .adapt import AdaptConfig, make_chunk_fn
from .autopilot import AutopilotConfig, DepthAutopilot
from .ingest import IngestConfig, IngestWorker
from .session import (SessionStatus, StreamSession, WindowPrediction,
                      reset_lane)
from .staging import InFlight, LaneRecord, StagedChunk, StagingPipeline
from .telemetry import FleetTelemetry


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """One QoS tier's grid geometry.

    ``chunk_len`` is the latency/throughput knob: a small chunk means
    window-end predictions surface after fewer staged timesteps
    (interactive), a large one amortizes dispatch overhead over more
    timesteps per step (bulk).  ``n_slots`` is the tier's lane count
    (rounded up per device under a mesh).
    """
    name: str
    chunk_len: int
    n_slots: int

    def __post_init__(self):
        if not self.name:
            raise ValueError("tier name must be non-empty")
        if self.chunk_len < 1 or self.n_slots < 1:
            raise ValueError(
                f"tier {self.name!r} needs chunk_len >= 1 and n_slots >= 1, "
                f"got {self.chunk_len}/{self.n_slots}")


class _Tier:
    """Runtime state of one tier: its slot grid, lane-batched device
    state/deltas (+ shardings), compiled chunk fn, and staging pipeline.
    ``slot0`` is the tier's offset in the fleet-global slot numbering
    (``step()`` returns global slot ids; everything internal is local)."""

    __slots__ = ("name", "chunk_len", "n_slots", "slot0", "grid", "state",
                 "deltas", "chunk_fn", "pipeline", "state_sh")

    def __init__(self, name: str, chunk_len: int, n_slots: int, slot0: int):
        self.name, self.chunk_len = name, chunk_len
        self.n_slots, self.slot0 = n_slots, slot0
        self.state_sh = None


class StreamScheduler:
    """Drives a fleet of :class:`StreamSession`\\ s over per-tier slot grids.

    Args:
      params:   frozen shared base params (stacked layout, ``core.snn``).
      cfg:      the fleet's :class:`SNNConfig`.
      n_slots:  grid width of the default tier (ignored when ``tiers`` is
        given; rounded up / floored per device with ``mesh``).
      chunk_len: timesteps per grid step of the default tier (static
        chunk-fn shape).
      adapt:    per-stream delta hygiene (:class:`AdaptConfig`).
      clock_dt_s: virtual seconds per grid step (drives source arrivals).
      telemetry: a :class:`FleetTelemetry` to fill (fresh one by default).
      mesh:     optional 1-D ``("slots",)`` mesh — shard every tier's grid.
      topology: optional :class:`TopologyService` — live DSST epochs
        (single-tier fleets only).
      pipeline_depth: 0 = serial phases (reference), 1 = double-buffered
        staging (overlap host packing with device compute), >1 = deeper
        queue (clamped to 1 while a live topology service is attached, so
        epochs land between the same grid steps as the serial path).
      want_factors: override the chunk fn's static DSST-factor mode; by
        default inferred — True iff a non-frozen topology service is
        attached. Note the mode is baked at compile time: a service that
        *becomes* frozen later stops paying the host transfer but keeps
        the (tiny) in-scan accumulators until the scheduler is rebuilt.
      compact: delta/weight layout of the hot path. ``None`` (default)
        auto-selects the compact N:M layout whenever the layer geometry is
        uniform: per-stream deltas are stored ``[S, L, J, T, bk, bo]``
        (memory scales with density, not ``K·N``) and the chunk step
        consumes the mask-free ``{"wc", "idx", "readout"}`` weight rep —
        no dense mask or dense ``[S, L, K, N]`` leaf exists in the serving
        jaxpr. ``False`` forces the dense baseline layout (the A/B
        reference). ``self.params`` stays the canonical dense layout
        either way; the compact exec rep is re-derived on the host at
        construction and after every topology swap.
      tracer: an ``obs.trace.Tracer`` recording phase-level spans
        (``sched.step/stage/poll_sources/admit/dispatch/retire/
        device_wait``, ``topology.epoch``, ``autopilot.decision/apply``);
        the shared no-op ``NULL_TRACER`` by default. Spans wrap host
        phases at already-synchronous points only — tracing on vs. off is
        bit-identical and leaves the serving jaxpr unchanged (pinned in
        ``tests/test_obs_serving.py``).
      tiers: optional QoS tier geometries (:class:`TierConfig` list,
        unique names). ``None`` = one tier named "default" built from
        ``n_slots``/``chunk_len`` — the exact pre-tier scheduler.
      ingest: async source ingestion — ``True`` (defaults), an
        :class:`IngestConfig`, or a prebuilt :class:`IngestWorker`.
        ``None``/``False`` polls sources inline in stage (the serial
        reference; bit-identical either way).
      autopilot: adaptive pipeline depth — ``True`` (defaults), an
        :class:`AutopilotConfig`, or a prebuilt :class:`DepthAutopilot`.
        ``None``/``False`` keeps ``pipeline_depth`` fixed. With a live
        topology service the controller's range is clamped to depth <= 1.
    """

    def __init__(self, params, cfg: SNNConfig, n_slots: int,
                 chunk_len: int = 8, adapt: Optional[AdaptConfig] = None,
                 clock_dt_s: float = 0.002,
                 telemetry: Optional[FleetTelemetry] = None,
                 mesh=None, topology=None, pipeline_depth: int = 0,
                 want_factors: Optional[bool] = None,
                 compact: Optional[bool] = None,
                 tracer: Optional[Tracer] = None,
                 tiers: Optional[Sequence[TierConfig]] = None,
                 ingest=None, autopilot=None):
        self.params, self.cfg = params, cfg
        if compact is None:
            compact = engine.geometry(cfg).uniform
        self.compact = compact
        self.mesh = mesh
        self.topology = topology          # Optional[TopologyService]
        if topology is not None and topology.cfg != cfg:
            # fail here, not at the first epoch with a half-evolved fleet
            raise ValueError("topology service was built for a different "
                             "SNNConfig than this scheduler's")
        live_topology = topology is not None and not topology.frozen
        if want_factors is None:
            want_factors = live_topology
        if live_topology and not want_factors:
            raise ValueError(
                "a live topology service consumes the chunk step's DSST "
                "factors; want_factors=False would starve it — drop the "
                "service or keep factors on")
        self.want_factors = want_factors
        if topology is not None:
            # an epoch due after step t must land before step t+1 is
            # dispatched; depth 1 preserves that, deeper queues would not
            pipeline_depth = min(pipeline_depth, 1)

        # -- tier geometry ----------------------------------------------------
        if tiers is None:
            tier_cfgs = [TierConfig("default", chunk_len=chunk_len,
                                    n_slots=n_slots)]
        else:
            tier_cfgs = list(tiers)
            if not tier_cfgs:
                raise ValueError("tiers must be a non-empty TierConfig list")
            names = [t.name for t in tier_cfgs]
            if len(set(names)) != len(names):
                raise ValueError(f"duplicate tier names in {names}")
            if topology is not None and len(tier_cfgs) > 1:
                raise ValueError(
                    "a topology service folds one fleet-wide delta grid "
                    "into the shared base; attach it to a single-tier "
                    "scheduler")
        if mesh is not None:
            # device-count-aware slot allocation, per tier: padded to a
            # multiple of the slot-mesh size so every device owns an equal
            # slot shard (padding lanes just idle — an empty slot is free),
            # and floored at 2 slots per device: at a local batch of 1
            # XLA:CPU drops the slot matmuls to a gemv with a different
            # K-reduction order, costing bit-identity with 1-device
            widths = sharding.tier_slot_allocation(
                [t.n_slots for t in tier_cfgs], mesh)
            tier_cfgs = [dataclasses.replace(t, n_slots=w)
                         for t, w in zip(tier_cfgs, widths)]

        self._tiers: List[_Tier] = []
        slot0 = 0
        for tc in tier_cfgs:
            tier = _Tier(tc.name, tc.chunk_len, tc.n_slots, slot0)
            slot0 += tc.n_slots
            tier.grid = SlotGrid(tc.n_slots)
            tier.state = init_stream_state(cfg, tc.n_slots)
            tier.deltas = init_stream_deltas(cfg, tc.n_slots, compact=compact)
            if mesh is not None:
                tier.state_sh = sharding.stream_shardings(tier.state, mesh)
                tier.state = jax.device_put(tier.state, tier.state_sh)
                tier.deltas = jax.device_put(tier.deltas,
                                             sharding.slot_sharding(mesh))
            # one compiled chunk fn per tier (its own [C, S] static shape
            # and its own trace counter); all tiers share cfg/adapt/exec rep
            tier.chunk_fn = make_chunk_fn(cfg, adapt, mesh=mesh,
                                          want_factors=want_factors)
            tier.pipeline = StagingPipeline(depth=pipeline_depth)
            self._tiers.append(tier)
        self._by_name = {t.name: t for t in self._tiers}
        self.n_slots = slot0                    # fleet-wide lane count
        self.chunk_len = self._tiers[0].chunk_len
        self._delta_sh = (sharding.slot_sharding(mesh)
                          if mesh is not None else None)

        self.pipeline_depth = pipeline_depth
        self.clock = 0.0
        self.clock_dt_s = clock_dt_s
        self.telemetry = telemetry or FleetTelemetry()
        self.tracer = tracer or NULL_TRACER
        self.retired: List[StreamSession] = []

        # -- async ingestion --------------------------------------------------
        self.ingest: Optional[IngestWorker] = None
        if ingest:
            if isinstance(ingest, IngestWorker):
                self.ingest = ingest
            elif isinstance(ingest, IngestConfig):
                self.ingest = IngestWorker(clock_dt_s, ingest)
            else:
                self.ingest = IngestWorker(clock_dt_s)
            if self.ingest._dt != float(clock_dt_s):
                raise ValueError(
                    "ingest worker clock_dt_s disagrees with the "
                    "scheduler's — the virtual-clock replay would diverge")

        # -- adaptive pipeline depth ------------------------------------------
        self.autopilot: Optional[DepthAutopilot] = None
        if autopilot:
            if isinstance(autopilot, DepthAutopilot):
                ap = autopilot
            elif isinstance(autopilot, AutopilotConfig):
                ap = DepthAutopilot(autopilot, tracer=self.tracer)
            else:
                ap = DepthAutopilot(tracer=self.tracer)
            if topology is not None and ap.cfg.max_depth > 1:
                # same drain-safety rule as the constructor clamp above
                ap = DepthAutopilot(
                    dataclasses.replace(ap.cfg, max_depth=1),
                    tracer=ap.tracer)
            ap.note_depth(0, pipeline_depth)
            self.autopilot = ap

        self._refresh_exec_params()

    def _refresh_exec_params(self) -> None:
        """(Re)derive what the chunk fns actually consume from the canonical
        dense ``self.params`` — the mask-free compact rep in compact mode —
        and re-measure the resident serving bytes. Host-side; runs at
        construction and after every topology swap (the only times the base
        weights change)."""
        self._exec_params = (serving_params(self.params, self.cfg)
                             if self.compact else self.params)
        self._params_bytes = sum(
            int(leaf.nbytes)
            for leaf in jax.tree_util.tree_leaves(self._exec_params))
        self._delta_bytes = sum(int(t.deltas.nbytes) for t in self._tiers)

    # -- lifecycle -----------------------------------------------------------
    def submit(self, session: StreamSession,
               tier: Optional[str] = None) -> None:
        """Queue a session for admission at the next stage phase.

        Tier assignment happens here: an explicit ``tier`` argument wins,
        else the session's own ``tier`` attribute, else the first tier.
        An unknown tier name raises before the session touches a grid.
        """
        name = tier or session.tier or self._tiers[0].name
        if name not in self._by_name:
            raise ValueError(
                f"unknown tier {name!r}; have {sorted(self._by_name)}")
        session.tier = name
        session.status = SessionStatus.QUEUED
        if session.n_in is None:
            session.n_in = self.cfg.n_in
        elif session.n_in != self.cfg.n_in:
            # fail here, not mid-step with a half-mutated grid
            raise ValueError(
                f"session {session.sid} n_in={session.n_in} != "
                f"cfg.n_in={self.cfg.n_in}")
        if self.ingest is not None:
            self.ingest.attach(session)
        self._by_name[name].grid.submit(session)

    def close(self) -> None:
        """Stop the ingest worker thread (no-op without one). Safe to call
        more than once; a closed scheduler still drains correctly — the
        drain path falls back to inline steal-polling, which is the serial
        semantics."""
        if self.ingest is not None:
            self.ingest.stop()

    def _replace_lanes(self, tier: _Tier, state, deltas) -> None:
        """Install post-surgery state/deltas on ``tier``, restoring the
        slot sharding — eager ``.at[slot].set`` lane writes are
        single-lane-correct on sharded arrays but may leave the result
        unplaced."""
        if self.mesh is not None:
            state = jax.device_put(state, tier.state_sh)
            deltas = jax.device_put(deltas, self._delta_sh)
        tier.state, tier.deltas = state, deltas

    def _admit(self, tier: _Tier) -> None:
        with self.tracer.span("sched.admit", grid_step=self._staging_step,
                              tier=tier.name) as sp:
            n = 0

            def on_admit(slot: int, sess: StreamSession):
                nonlocal n
                n += 1
                sess.slot, sess.status = slot, SessionStatus.ACTIVE
                self._replace_lanes(tier, *reset_lane(
                    tier.state, tier.deltas, self.cfg, slot))
            tier.grid.admit(on_admit)
            sp.set(admitted=n)

    def _poll_sources(self) -> None:
        """Move newly arrived chunks into session buffers, fleet-wide.

        With an ingest worker this is a lock-protected queue drain — the
        only ingest work left on the critical path; decode/poll cost runs
        on the worker thread. Without one, sources are polled inline (the
        serial reference). Both paths push the same chunks in the same
        per-session order at the same tick (bit-identity pinned in
        tests/test_serving_qos.py)."""
        with self.tracer.span("sched.poll_sources",
                              grid_step=self._staging_step) as sp:
            if self.ingest is not None:
                n, peak = self.ingest.drain(self._staging_step)
                self.telemetry.record_ingest(n, peak)
            else:
                n = 0
                for tier in self._tiers:
                    for sess in (list(tier.grid.occupant)
                                 + list(tier.grid.queue)):
                        if sess is not None and sess.source is not None:
                            for chunk in sess.source.poll(self.clock):
                                sess.push_events(chunk)
                                n += 1
            sp.set(chunks=n)

    @property
    def _staging_step(self) -> int:
        """Grid-step number the *next dispatch* will get (``grid.tick``
        runs at dispatch) — what stage-side spans attribute to."""
        return self._tiers[0].grid.stats["steps"] + 1

    # -- phase 1: stage ------------------------------------------------------
    def _stage(self, tier: _Tier) -> StagedChunk:
        """Host-only assembly of one tier's grid step (no device
        interaction).

        Advances the clock and drains/polls sources (first tier only —
        both are fleet-wide facts), admits into the tier's free lanes,
        packs the event/valid/adapt-mask buffers, and records the step's
        scheduling decisions: which lanes were fed what, which sessions
        exhaust after this step, and which slots are epoch-merge
        eligible. Runs while the previous step's device compute is in
        flight when the pipeline is enabled — this is the overlapped
        phase.
        """
        t0 = time.perf_counter()
        with self.tracer.span("sched.stage", grid_step=self._staging_step,
                              tier=tier.name):
            staged = self._stage_body(tier)
        dt = time.perf_counter() - t0
        self.telemetry.record_phase("stage", dt)
        self.telemetry.record_tier_phase(tier.name, "stage", dt)
        return staged

    def _stage_body(self, tier: _Tier) -> StagedChunk:
        if tier is self._tiers[0]:
            # fleet-wide, once per grid step: the virtual clock and the
            # arrival drain are shared by every tier's stage
            self.clock += self.clock_dt_s
            self._poll_sources()
        self._admit(tier)

        C, S = tier.chunk_len, tier.n_slots
        events = np.zeros((C, S, self.cfg.n_in), np.float32)
        valid = np.zeros((C, S), bool)
        amask = np.zeros(S, bool)
        lanes: List[LaneRecord] = []
        retiring = []
        fed: Dict[int, int] = {}
        for slot, sess in enumerate(tier.grid.occupant):
            if sess is None:
                continue
            chunk = sess.pop_chunk(C)
            n = chunk.shape[0]
            if n:
                events[:n, slot] = chunk
                valid[:n, slot] = True
            amask[slot] = sess.adapt
            fed[slot] = n
            lanes.append(LaneRecord(slot=slot, session=sess, n_fed=n,
                                    events_in=float(chunk.sum())))
            if sess.exhausted:        # a host fact: source done, buffers empty
                retiring.append((slot, sess))
        gone = {slot for slot, _ in retiring}
        merge_slots = tuple(
            slot for slot, sess in enumerate(tier.grid.occupant)
            if sess is not None and sess.adapt and slot not in gone)
        return StagedChunk(events=events, valid=valid, adapt_mask=amask,
                           lanes=lanes, retiring=retiring,
                           merge_slots=merge_slots, fed=fed)

    # -- phase 2: dispatch ---------------------------------------------------
    def _dispatch(self, tier: _Tier, staged: StagedChunk) -> InFlight:
        """Enqueue the tier's chunk fn on the staged buffers —
        asynchronous, no host wait — then free retiring sessions' lanes so
        the *next* stage phase can re-admit into them (same admission
        timing as the serial path, where retire frees lanes before the
        next step's admits)."""
        t0 = time.perf_counter()
        with self.tracer.span("sched.dispatch",
                              grid_step=self._staging_step,
                              tier=tier.name) as sp:
            tier.deltas, tier.state, metrics = tier.chunk_fn(
                self._exec_params, tier.deltas, tier.state, staged.events,
                staged.valid, staged.adapt_mask)
            tier.grid.tick()
            for slot, _ in staged.retiring:
                tier.grid.retire(slot)
            sp.set(lanes=len(staged.lanes), retiring=len(staged.retiring))
            fl = InFlight(staged=staged, deltas=tier.deltas, metrics=metrics,
                          grid_step=tier.grid.stats["steps"])
        dt = time.perf_counter() - t0
        self.telemetry.record_phase("dispatch", dt)
        self.telemetry.record_tier_phase(tier.name, "dispatch", dt)
        return fl

    # -- phase 3: retire -----------------------------------------------------
    def _retire(self, tier: _Tier, fl: InFlight) -> None:
        """Consume one in-flight step: fetch metrics (the only device
        wait), route predictions, fold telemetry, finalize retiring
        sessions from the captured handles, drive the topology service.

        The retire span/phase is attributed to ``fl.grid_step`` — the step
        that *produced* these results — not the step currently staging:
        under pipelining the two differ, and whole-``step()`` wall alone
        cannot say which grid step a retire belonged to.
        """
        t0 = time.perf_counter()
        with self.tracer.span("sched.retire", grid_step=fl.grid_step,
                              tier=tier.name):
            with self.tracer.span("sched.device_wait",
                                  grid_step=fl.grid_step):
                tw0 = time.perf_counter()
                m = jax.device_get(fl.metrics)  # one transfer for all metrics
                wait_s = time.perf_counter() - tw0
            # fl.queued_s: host work done while this step was in flight
            # (stamped by StagingPipeline.push/pop; 0.0 on the serial path)
            ratio = self.telemetry.record_overlap(hidden_s=fl.queued_s,
                                                  wait_s=wait_s)
            if self.autopilot is not None:
                self.telemetry.record_overlap_ema(
                    self.autopilot.observe(ratio))
            self._retire_body(tier, fl, m)
        dt = time.perf_counter() - t0
        self.telemetry.record_phase("retire", dt)
        self.telemetry.record_tier_phase(tier.name, "retire", dt)

    def _retire_body(self, tier: _Tier, fl: InFlight, m) -> None:
        staged = fl.staged
        logits = m.logits                      # [C, S, n_out]
        wend = m.window_end                    # [C, S]
        tsum = {"steps": 0.0, "events_in": 0.0, "sop_forward": 0.0,
                "sop_wu": 0.0, "sop_wu_offered": 0.0, "windows": 0}
        for rec in staged.lanes:
            slot, sess = rec.slot, rec.session
            sess.timesteps_fed += rec.n_fed
            steps = float(m.steps[slot])
            sop_forward = float(m.sop_forward[slot])
            sop_wu = float(m.sop_wu[slot])
            sop_wu_offered = float(m.sop_wu_offered[slot])
            windows = int(wend[:, slot].sum())
            counters = self.telemetry.stream(sess.sid)
            counters.add_chunk(
                steps=steps,
                events_in=rec.events_in,
                sop_forward=sop_forward,
                sop_wu=sop_wu,
                sop_wu_offered=sop_wu_offered,
                gate_opened=float(m.gate_opened[slot].sum()),
                gate_offered=float(m.gate_offered[slot].sum()),
                windows=windows,
                local_loss=float(m.local_loss[slot]))
            tsum["steps"] += steps
            tsum["events_in"] += rec.events_in
            tsum["sop_forward"] += sop_forward
            tsum["sop_wu"] += sop_wu
            tsum["sop_wu_offered"] += sop_wu_offered
            tsum["windows"] += windows
            for t in np.nonzero(wend[:, slot])[0]:
                sess.predictions.append(WindowPrediction(
                    window_idx=len(sess.predictions),
                    logits=logits[t, slot].copy()))
        if staged.lanes:
            self.telemetry.record_tier_chunk(
                tier.name, timesteps=tsum["steps"],
                events_in=tsum["events_in"],
                sop_forward=tsum["sop_forward"], sop_wu=tsum["sop_wu"],
                sop_wu_offered=tsum["sop_wu_offered"],
                windows=tsum["windows"])
        for slot, sess in staged.retiring:
            # the captured post-step handle, NOT tier.deltas: a later stage
            # phase may already have re-admitted into this lane; layout is
            # the fleet's: compact [L, J, T, bk, bo] or dense [L, Kmax, N]
            sess.final_deltas = np.asarray(fl.deltas[slot])
            sess.status, sess.slot = SessionStatus.RETIRED, None
            if self.ingest is not None:
                self.ingest.detach(sess)
            self.retired.append(sess)
        svc = self.topology
        if svc is not None and not svc.frozen and m.pre_mag is not None:
            svc.observe(m)
            self.maybe_evolve_topology(merge_slots=staged.merge_slots,
                                       grid_step=fl.grid_step)

    # -- adaptive depth ------------------------------------------------------
    def _apply_autopilot(self) -> None:
        """Evaluate the depth controller and, on a change, apply it at a
        drain-safe boundary: flush every in-flight step, then resize the
        empty pipelines. Flushing preserves retire order, so the adaptive
        trajectory stays bit-identical to the fixed-depth references —
        only the wall-clock shape of the run changes."""
        step = self._staging_step
        new = self.autopilot.decide(step, self.pipeline_depth)
        if new == self.pipeline_depth:
            return
        with self.tracer.span("autopilot.apply", grid_step=step,
                              depth=self.pipeline_depth, new_depth=new):
            self.flush()
            for tier in self._tiers:
                tier.pipeline.set_depth(new)
        self.pipeline_depth = new
        self.autopilot.note_depth(step, new)
        self.telemetry.record_depth(new, changed=True)

    # -- the one grid step ---------------------------------------------------
    def step(self) -> Dict[int, int]:
        """One grid step across every tier; returns {global slot:
        timesteps fed} for the step staged (and dispatched) by this call
        (tier-local slots offset by the tier's ``slot0``; identical to
        the local ids on a single-tier fleet).

        Serial mode (``pipeline_depth=0``): stage → dispatch → retire per
        tier, all within this call. Pipelined: stage this step
        (overlapping the in-flight device compute), retire the tier's
        oldest in-flight step if its pipeline is full, then dispatch —
        bookkeeping for the staged step lands one ``step()`` later (or at
        :meth:`flush`). With an autopilot attached, depth proposals are
        applied first, at this step boundary.

        Note the whole-step wall time recorded here therefore mixes this
        step's stage/dispatch with an *earlier* step's retire under
        pipelining; per-phase spans and ``telemetry.record_phase`` carry
        the correct per-grid-step attribution (each span's ``grid_step``
        attr names the step that owns the work, and phase sums reconcile
        with step walls — pinned in ``tests/test_obs_serving.py``).
        """
        t0 = time.perf_counter()
        # cached host ints — survives callers swapping self.telemetry
        self.telemetry.record_bytes_held(self._params_bytes,
                                         self._delta_bytes)
        if self.autopilot is not None:
            self._apply_autopilot()
        fed: Dict[int, int] = {}
        with self.tracer.span("sched.step", grid_step=self._staging_step):
            for tier in self._tiers:
                tt0 = time.perf_counter()
                staged = self._stage(tier)
                if tier.pipeline.depth == 0:
                    self._retire(tier, self._dispatch(tier, staged))
                else:
                    while tier.pipeline.full:
                        self._retire(tier, tier.pipeline.pop())
                    tier.pipeline.push(self._dispatch(tier, staged))
                self.telemetry.record_tier_step(
                    tier.name, time.perf_counter() - tt0)
                for slot, n in staged.fed.items():
                    fed[tier.slot0 + slot] = n
        self.telemetry.record_step(time.perf_counter() - t0)
        return fed

    def flush(self) -> None:
        """Retire every in-flight step of every tier (no-op in serial
        mode). Call after the last ``step()`` — predictions, telemetry,
        final-delta snapshots and due topology epochs of in-flight steps
        land here."""
        for tier in self._tiers:
            while len(tier.pipeline):
                t0 = time.perf_counter()
                self._retire(tier, tier.pipeline.pop())
                self.telemetry.record_flush(time.perf_counter() - t0)

    # -- live topology evolution --------------------------------------------
    def maybe_evolve_topology(self, force: bool = False, merge_slots=None,
                              grid_step: Optional[int] = None):
        """Run a due DSST prune/regrow epoch between grid steps.

        The service returns ``(params, deltas)`` with identical shapes and
        slot shardings, so installing them is an atomic swap: active
        sessions keep their lanes and carried state, and the next grid step
        reuses the already-compiled chunk fn (``n_compiles`` stays 1).
        The retire phase passes the staged step's ``merge_slots`` snapshot
        and dispatch-time ``grid_step`` so a pipelined epoch sees exactly
        the fleet the serial scheduler would; manual calls may omit both
        (current occupants / current grid step). Returns the
        ``TopologyEpochEvent`` when an epoch ran, else None.
        """
        svc = self.topology
        tier = self._tiers[0]             # topology fleets are single-tier
        step = tier.grid.stats["steps"] if grid_step is None else grid_step
        if svc is None or not (force or svc.due(step)):
            return None
        if merge_slots is None:
            merge_slots = tuple(
                slot for slot, sess in enumerate(tier.grid.occupant)
                if sess is not None and sess.adapt)
        with self.tracer.span("topology.epoch", grid_step=step,
                              epoch=svc.epoch_idx) as sp:
            params, deltas, event = svc.evolve(
                self.params, tier.deltas, merge_slots=merge_slots,
                grid_step=step)
            sp.set(pruned=event.pruned, regrown=event.regrown,
                   merged=len(event.merged_slots))
        self.params = params
        self._replace_lanes(tier, tier.state, deltas)
        self._refresh_exec_params()   # new mask → new compact wc/idx
        self.telemetry.record_topology_epoch(
            grid_step=event.grid_step, pruned=event.pruned,
            regrown=event.regrown, mask_change=event.mask_change,
            merged_streams=len(event.merged_slots))
        return event

    def run_until_drained(self, max_steps: int = 100_000) -> List[StreamSession]:
        """Step until every submitted session is served, then flush the
        pipeline; returns the retired sessions (bookkeeping complete)."""
        while not all(t.grid.drained for t in self._tiers):
            self.step()
            if self._tiers[0].grid.stats["steps"] >= max_steps:
                break
        self.flush()
        return self.retired

    # -- introspection -------------------------------------------------------
    @property
    def grid(self) -> SlotGrid:
        """The first tier's slot grid (THE grid on a single-tier fleet —
        the long-standing external surface; multi-tier callers iterate
        :attr:`tiers`)."""
        return self._tiers[0].grid

    @property
    def pipeline(self) -> StagingPipeline:
        """The first tier's staging pipeline (every tier's pipeline runs
        at the same depth; this is the inspection handle)."""
        return self._tiers[0].pipeline

    @property
    def chunk_fn(self):
        """The first tier's compiled chunk step."""
        return self._tiers[0].chunk_fn

    @property
    def state(self):
        """The first tier's lane-batched StreamState (the fleet's, on a
        single-tier scheduler)."""
        return self._tiers[0].state

    @state.setter
    def state(self, value):
        self._tiers[0].state = value

    @property
    def deltas(self):
        """The first tier's slot-leading delta tensor."""
        return self._tiers[0].deltas

    @deltas.setter
    def deltas(self, value):
        self._tiers[0].deltas = value

    @property
    def tiers(self) -> Tuple[str, ...]:
        """Tier names, grid order (slot0 ascending)."""
        return tuple(t.name for t in self._tiers)

    def tier_grid(self, name: str) -> SlotGrid:
        """The named tier's slot grid."""
        return self._by_name[name].grid

    @property
    def drained(self) -> bool:
        """True when no session is queued/active on any tier AND no step
        is in flight (i.e. all bookkeeping has landed)."""
        return all(t.grid.drained and len(t.pipeline) == 0
                   for t in self._tiers)

    @property
    def n_compiles(self) -> int:
        """Max per-tier trace count of the slot-grid step (0 before
        warmup, must stay 1 after — the zero-recompilation guarantee,
        per tier). Counted by the chunk fns themselves rather than
        private jit cache internals."""
        return max(t.chunk_fn.n_traces() for t in self._tiers)

    @property
    def n_compiles_by_tier(self) -> Dict[str, int]:
        """Per-tier chunk-fn trace counts (each must be <= 1 after that
        tier's warmup)."""
        return {t.name: t.chunk_fn.n_traces() for t in self._tiers}

    @property
    def utilization(self) -> float:
        """Mean fraction of lanes occupied at dispatch, over all steps
        and tiers (slot-weighted — same formula as SlotGrid.utilization
        on a single-tier fleet)."""
        num = sum(t.grid.stats["slot_busy"] for t in self._tiers)
        den = sum(t.grid.stats["steps"] * t.n_slots for t in self._tiers)
        return num / den if den else 0.0
