"""Slot-multiplexed micro-batching for stateful SNN streams.

``StreamScheduler`` generalizes the continuous batcher's fixed slot grid
(``launch.batching.SlotGrid``) from token decode to SNN timesteps. One
jitted chunk step with static shapes — events ``[chunk_len, n_slots,
n_in]``, valid ``[chunk_len, n_slots]`` — advances every active stream by
up to ``chunk_len`` timesteps; admitted streams claim a lane (reset in
place), retired streams free it. Idle or ragged tails are masked invalid,
so they neither perturb state nor accrue telemetry: an empty slot costs
exactly zero counted events.

Each grid step runs through three explicit phases (see serving/staging.py):

1. **stage** — advance the virtual clock, poll every session's source for
   newly arrived chunks (Poisson arrivals → ragged per-slot backlogs),
   admit queued sessions into free lanes, pack up to ``chunk_len``
   buffered timesteps per active slot, and mark sessions that exhaust
   after this step;
2. **dispatch** — enqueue the single compiled chunk fn on the staged
   buffers (asynchronous — the host does not wait) and free the lanes of
   marked sessions so the next stage phase can re-admit into them;
3. **retire** — fetch the step's metrics (the only device wait), route
   window-end logits back to sessions as predictions, fold per-lane
   metrics into per-stream telemetry, finalize retiring sessions, and
   feed/drive the topology service.

With ``pipeline_depth=0`` (default) the phases run back-to-back — the
serial reference behavior. With ``pipeline_depth=1`` the scheduler
double-buffers: step ``t+1`` is staged while the device computes step
``t``, hiding host event assembly behind compute; lane surgery and
telemetry reads no longer force a device sync per step. Both modes
produce bit-identical per-stream trajectories (pinned in
``tests/test_serving_pipeline.py``) — call :meth:`flush` (or use
:meth:`run_until_drained`, which does) to drain in-flight bookkeeping.

With a ``("slots",)`` mesh (``launch.mesh.make_serving_mesh``) the grid
shards over devices: slot allocation pads to the device count, the chunk
step runs under slot-axis ``shard_map`` (bit-identical to 1-device — see
serving/adapt.py), and lane surgery re-places its result so the slot
sharding survives admit/retire.

With a ``TopologyService`` attached, the chunk fn is built with
``want_factors=True``: every retire phase feeds the service's DSST
accumulators (slot-reduced on device — a few-KB transfer) and
``maybe_evolve_topology()`` runs due prune/regrow epochs *between* grid
steps: the evolved ``(params, deltas)`` keep their shapes and slot
shardings, so the swap is atomic from the streams' point of view and the
chunk step never recompiles (see serving/topology_service.py). Without a
service, ``want_factors=False`` compiles the factor accumulators out of
the chunk scan entirely — a frozen fleet pays nothing for them.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import engine
from repro.core.snn import (SNNConfig, init_stream_deltas, init_stream_state,
                            serving_params)
from repro.launch import sharding
from repro.launch.batching import SlotGrid
from repro.obs.trace import NULL_TRACER, Tracer

from .adapt import AdaptConfig, make_chunk_fn
from .session import (SessionStatus, StreamSession, WindowPrediction,
                      reset_lane)
from .staging import InFlight, LaneRecord, StagedChunk, StagingPipeline
from .telemetry import FleetTelemetry


class StreamScheduler:
    """Drives a fleet of :class:`StreamSession`\\ s over one slot grid.

    Args:
      params:   frozen shared base params (stacked layout, ``core.snn``).
      cfg:      the fleet's :class:`SNNConfig`.
      n_slots:  grid width (rounded up / floored per device with ``mesh``).
      chunk_len: timesteps per grid step (static chunk-fn shape).
      adapt:    per-stream delta hygiene (:class:`AdaptConfig`).
      clock_dt_s: virtual seconds per grid step (drives source arrivals).
      telemetry: a :class:`FleetTelemetry` to fill (fresh one by default).
      mesh:     optional 1-D ``("slots",)`` mesh — shard the grid.
      topology: optional :class:`TopologyService` — live DSST epochs.
      pipeline_depth: 0 = serial phases (reference), 1 = double-buffered
        staging (overlap host packing with device compute), >1 = deeper
        queue (clamped to 1 while a live topology service is attached, so
        epochs land between the same grid steps as the serial path).
      want_factors: override the chunk fn's static DSST-factor mode; by
        default inferred — True iff a non-frozen topology service is
        attached. Note the mode is baked at compile time: a service that
        *becomes* frozen later stops paying the host transfer but keeps
        the (tiny) in-scan accumulators until the scheduler is rebuilt.
      compact: delta/weight layout of the hot path. ``None`` (default)
        auto-selects the compact N:M layout whenever the layer geometry is
        uniform: per-stream deltas are stored ``[S, L, J, T, bk, bo]``
        (memory scales with density, not ``K·N``) and the chunk step
        consumes the mask-free ``{"wc", "idx", "readout"}`` weight rep —
        no dense mask or dense ``[S, L, K, N]`` leaf exists in the serving
        jaxpr. ``False`` forces the dense baseline layout (the A/B
        reference). ``self.params`` stays the canonical dense layout
        either way; the compact exec rep is re-derived on the host at
        construction and after every topology swap.
      tracer: an ``obs.trace.Tracer`` recording phase-level spans
        (``sched.step/stage/poll_sources/admit/dispatch/retire/
        device_wait``, ``topology.epoch``); the shared no-op
        ``NULL_TRACER`` by default. Spans wrap host phases at
        already-synchronous points only — tracing on vs. off is
        bit-identical and leaves the serving jaxpr unchanged (pinned in
        ``tests/test_obs_serving.py``).
    """

    def __init__(self, params, cfg: SNNConfig, n_slots: int,
                 chunk_len: int = 8, adapt: Optional[AdaptConfig] = None,
                 clock_dt_s: float = 0.002,
                 telemetry: Optional[FleetTelemetry] = None,
                 mesh=None, topology=None, pipeline_depth: int = 0,
                 want_factors: Optional[bool] = None,
                 compact: Optional[bool] = None,
                 tracer: Optional[Tracer] = None):
        self.params, self.cfg = params, cfg
        if compact is None:
            compact = engine.geometry(cfg).uniform
        self.compact = compact
        self.mesh = mesh
        self.topology = topology          # Optional[TopologyService]
        if topology is not None and topology.cfg != cfg:
            # fail here, not at the first epoch with a half-evolved fleet
            raise ValueError("topology service was built for a different "
                             "SNNConfig than this scheduler's")
        live_topology = topology is not None and not topology.frozen
        if want_factors is None:
            want_factors = live_topology
        if live_topology and not want_factors:
            raise ValueError(
                "a live topology service consumes the chunk step's DSST "
                "factors; want_factors=False would starve it — drop the "
                "service or keep factors on")
        self.want_factors = want_factors
        if topology is not None:
            # an epoch due after step t must land before step t+1 is
            # dispatched; depth 1 preserves that, deeper queues would not
            pipeline_depth = min(pipeline_depth, 1)
        self.pipeline = StagingPipeline(depth=pipeline_depth)
        if mesh is not None:
            # device-count-aware slot allocation: the grid is padded to a
            # multiple of the slot-mesh size so every device owns an equal
            # slot shard (padding lanes just idle — an empty slot is free),
            # and to >= 2 slots per device: at a local batch of 1 XLA:CPU
            # drops the slot matmuls to a gemv with a different K-reduction
            # order, costing bit-identity with the single-device path
            n_slots = max(sharding.round_up_slots(n_slots, mesh),
                          2 * sharding.slot_devices(mesh))
        self.n_slots, self.chunk_len = n_slots, chunk_len
        self.clock = 0.0
        self.clock_dt_s = clock_dt_s
        self.grid: SlotGrid[StreamSession] = SlotGrid(n_slots)
        self.state = init_stream_state(cfg, n_slots)
        self.deltas = init_stream_deltas(cfg, n_slots, compact=compact)
        if mesh is not None:
            self._state_sh = sharding.stream_shardings(self.state, mesh)
            self._delta_sh = sharding.slot_sharding(mesh)
            self.state = jax.device_put(self.state, self._state_sh)
            self.deltas = jax.device_put(self.deltas, self._delta_sh)
        self.chunk_fn = make_chunk_fn(cfg, adapt, mesh=mesh,
                                      want_factors=want_factors)
        self.telemetry = telemetry or FleetTelemetry()
        self.tracer = tracer or NULL_TRACER
        self.retired: List[StreamSession] = []
        self._refresh_exec_params()

    def _refresh_exec_params(self) -> None:
        """(Re)derive what the chunk fn actually consumes from the canonical
        dense ``self.params`` — the mask-free compact rep in compact mode —
        and re-measure the resident serving bytes. Host-side; runs at
        construction and after every topology swap (the only times the base
        weights change)."""
        self._exec_params = (serving_params(self.params, self.cfg)
                             if self.compact else self.params)
        self._params_bytes = sum(
            int(leaf.nbytes)
            for leaf in jax.tree_util.tree_leaves(self._exec_params))
        self._delta_bytes = int(self.deltas.nbytes)

    # -- lifecycle -----------------------------------------------------------
    def submit(self, session: StreamSession) -> None:
        """Queue a session for admission at the next stage phase."""
        session.status = SessionStatus.QUEUED
        if session.n_in is None:
            session.n_in = self.cfg.n_in
        elif session.n_in != self.cfg.n_in:
            # fail here, not mid-step with a half-mutated grid
            raise ValueError(
                f"session {session.sid} n_in={session.n_in} != "
                f"cfg.n_in={self.cfg.n_in}")
        self.grid.submit(session)

    def _replace_lanes(self, state, deltas):
        """Install post-surgery state/deltas, restoring the slot sharding —
        eager ``.at[slot].set`` lane writes are single-lane-correct on
        sharded arrays but may leave the result unplaced."""
        if self.mesh is not None:
            state = jax.device_put(state, self._state_sh)
            deltas = jax.device_put(deltas, self._delta_sh)
        self.state, self.deltas = state, deltas

    def _admit(self) -> None:
        with self.tracer.span("sched.admit",
                              grid_step=self._staging_step) as sp:
            n = 0

            def on_admit(slot: int, sess: StreamSession):
                nonlocal n
                n += 1
                sess.slot, sess.status = slot, SessionStatus.ACTIVE
                self._replace_lanes(*reset_lane(
                    self.state, self.deltas, self.cfg, slot))
            self.grid.admit(on_admit)
            sp.set(admitted=n)

    def _poll_sources(self) -> None:
        with self.tracer.span("sched.poll_sources",
                              grid_step=self._staging_step) as sp:
            n = 0
            for sess in list(self.grid.occupant) + list(self.grid.queue):
                if sess is not None and sess.source is not None:
                    for chunk in sess.source.poll(self.clock):
                        sess.push_events(chunk)
                        n += 1
            sp.set(chunks=n)

    @property
    def _staging_step(self) -> int:
        """Grid-step number the *next dispatch* will get (``grid.tick``
        runs at dispatch) — what stage-side spans attribute to."""
        return self.grid.stats["steps"] + 1

    # -- phase 1: stage ------------------------------------------------------
    def _stage(self) -> StagedChunk:
        """Host-only assembly of one grid step (no device interaction).

        Advances the clock, polls sources, admits into free lanes, packs
        the event/valid/adapt-mask buffers, and records the step's
        scheduling decisions: which lanes were fed what, which sessions
        exhaust after this step, and which slots are epoch-merge eligible.
        Runs while the previous step's device compute is in flight when
        the pipeline is enabled — this is the overlapped phase.
        """
        t0 = time.perf_counter()
        with self.tracer.span("sched.stage", grid_step=self._staging_step):
            staged = self._stage_body()
        self.telemetry.record_phase("stage", time.perf_counter() - t0)
        return staged

    def _stage_body(self) -> StagedChunk:
        self.clock += self.clock_dt_s
        self._poll_sources()
        self._admit()

        C, S = self.chunk_len, self.n_slots
        events = np.zeros((C, S, self.cfg.n_in), np.float32)
        valid = np.zeros((C, S), bool)
        amask = np.zeros(S, bool)
        lanes: List[LaneRecord] = []
        retiring = []
        fed: Dict[int, int] = {}
        for slot, sess in enumerate(self.grid.occupant):
            if sess is None:
                continue
            chunk = sess.pop_chunk(C)
            n = chunk.shape[0]
            if n:
                events[:n, slot] = chunk
                valid[:n, slot] = True
            amask[slot] = sess.adapt
            fed[slot] = n
            lanes.append(LaneRecord(slot=slot, session=sess, n_fed=n,
                                    events_in=float(chunk.sum())))
            if sess.exhausted:        # a host fact: source done, buffer empty
                retiring.append((slot, sess))
        gone = {slot for slot, _ in retiring}
        merge_slots = tuple(
            slot for slot, sess in enumerate(self.grid.occupant)
            if sess is not None and sess.adapt and slot not in gone)
        return StagedChunk(events=events, valid=valid, adapt_mask=amask,
                           lanes=lanes, retiring=retiring,
                           merge_slots=merge_slots, fed=fed)

    # -- phase 2: dispatch ---------------------------------------------------
    def _dispatch(self, staged: StagedChunk) -> InFlight:
        """Enqueue the chunk fn on the staged buffers — asynchronous, no
        host wait — then free retiring sessions' lanes so the *next* stage
        phase can re-admit into them (same admission timing as the serial
        path, where retire frees lanes before the next step's admits)."""
        t0 = time.perf_counter()
        with self.tracer.span("sched.dispatch",
                              grid_step=self._staging_step) as sp:
            self.deltas, self.state, metrics = self.chunk_fn(
                self._exec_params, self.deltas, self.state, staged.events,
                staged.valid, staged.adapt_mask)
            self.grid.tick()
            for slot, _ in staged.retiring:
                self.grid.retire(slot)
            sp.set(lanes=len(staged.lanes), retiring=len(staged.retiring))
            fl = InFlight(staged=staged, deltas=self.deltas, metrics=metrics,
                          grid_step=self.grid.stats["steps"])
        self.telemetry.record_phase("dispatch", time.perf_counter() - t0)
        return fl

    # -- phase 3: retire -----------------------------------------------------
    def _retire(self, fl: InFlight) -> None:
        """Consume one in-flight step: fetch metrics (the only device
        wait), route predictions, fold telemetry, finalize retiring
        sessions from the captured handles, drive the topology service.

        The retire span/phase is attributed to ``fl.grid_step`` — the step
        that *produced* these results — not the step currently staging:
        under pipelining the two differ, and whole-``step()`` wall alone
        cannot say which grid step a retire belonged to.
        """
        t0 = time.perf_counter()
        with self.tracer.span("sched.retire", grid_step=fl.grid_step):
            with self.tracer.span("sched.device_wait",
                                  grid_step=fl.grid_step):
                tw0 = time.perf_counter()
                m = jax.device_get(fl.metrics)  # one transfer for all metrics
                wait_s = time.perf_counter() - tw0
            # fl.queued_s: host work done while this step was in flight
            # (stamped by StagingPipeline.push/pop; 0.0 on the serial path)
            self.telemetry.record_overlap(hidden_s=fl.queued_s,
                                          wait_s=wait_s)
            self._retire_body(fl, m)
        self.telemetry.record_phase("retire", time.perf_counter() - t0)

    def _retire_body(self, fl: InFlight, m) -> None:
        staged = fl.staged
        logits = m.logits                      # [C, S, n_out]
        wend = m.window_end                    # [C, S]
        for rec in staged.lanes:
            slot, sess = rec.slot, rec.session
            sess.timesteps_fed += rec.n_fed
            counters = self.telemetry.stream(sess.sid)
            counters.add_chunk(
                steps=float(m.steps[slot]),
                events_in=rec.events_in,
                sop_forward=float(m.sop_forward[slot]),
                sop_wu=float(m.sop_wu[slot]),
                sop_wu_offered=float(m.sop_wu_offered[slot]),
                gate_opened=float(m.gate_opened[slot].sum()),
                gate_offered=float(m.gate_offered[slot].sum()),
                windows=int(wend[:, slot].sum()),
                local_loss=float(m.local_loss[slot]))
            for t in np.nonzero(wend[:, slot])[0]:
                sess.predictions.append(WindowPrediction(
                    window_idx=len(sess.predictions),
                    logits=logits[t, slot].copy()))
        for slot, sess in staged.retiring:
            # the captured post-step handle, NOT self.deltas: a later stage
            # phase may already have re-admitted into this lane; layout is
            # the fleet's: compact [L, J, T, bk, bo] or dense [L, Kmax, N]
            sess.final_deltas = np.asarray(fl.deltas[slot])
            sess.status, sess.slot = SessionStatus.RETIRED, None
            self.retired.append(sess)
        svc = self.topology
        if svc is not None and not svc.frozen and m.pre_mag is not None:
            svc.observe(m)
            self.maybe_evolve_topology(merge_slots=staged.merge_slots,
                                       grid_step=fl.grid_step)

    # -- the one grid step ---------------------------------------------------
    def step(self) -> Dict[int, int]:
        """One slot-grid step; returns {slot: timesteps fed} for the step
        staged (and dispatched) by this call.

        Serial mode (``pipeline_depth=0``): stage → dispatch → retire, all
        within this call. Pipelined: stage this step (overlapping the
        in-flight device compute), retire the oldest in-flight step if the
        pipeline is full, then dispatch — bookkeeping for the staged step
        lands one ``step()`` later (or at :meth:`flush`).

        Note the whole-step wall time recorded here therefore mixes this
        step's stage/dispatch with an *earlier* step's retire under
        pipelining; per-phase spans and ``telemetry.record_phase`` carry
        the correct per-grid-step attribution (each span's ``grid_step``
        attr names the step that owns the work, and phase sums reconcile
        with step walls — pinned in ``tests/test_obs_serving.py``).
        """
        t0 = time.perf_counter()
        # cached host ints — survives callers swapping self.telemetry
        self.telemetry.record_bytes_held(self._params_bytes,
                                         self._delta_bytes)
        with self.tracer.span("sched.step", grid_step=self._staging_step):
            staged = self._stage()
            if self.pipeline.depth == 0:
                self._retire(self._dispatch(staged))
            else:
                while self.pipeline.full:
                    self._retire(self.pipeline.pop())
                self.pipeline.push(self._dispatch(staged))
        self.telemetry.record_step(time.perf_counter() - t0)
        return staged.fed

    def flush(self) -> None:
        """Retire every in-flight step (no-op in serial mode). Call after
        the last ``step()`` — predictions, telemetry, final-delta
        snapshots and due topology epochs of in-flight steps land here."""
        while len(self.pipeline):
            t0 = time.perf_counter()
            self._retire(self.pipeline.pop())
            self.telemetry.record_flush(time.perf_counter() - t0)

    # -- live topology evolution --------------------------------------------
    def maybe_evolve_topology(self, force: bool = False, merge_slots=None,
                              grid_step: Optional[int] = None):
        """Run a due DSST prune/regrow epoch between grid steps.

        The service returns ``(params, deltas)`` with identical shapes and
        slot shardings, so installing them is an atomic swap: active
        sessions keep their lanes and carried state, and the next grid step
        reuses the already-compiled chunk fn (``n_compiles`` stays 1).
        The retire phase passes the staged step's ``merge_slots`` snapshot
        and dispatch-time ``grid_step`` so a pipelined epoch sees exactly
        the fleet the serial scheduler would; manual calls may omit both
        (current occupants / current grid step). Returns the
        ``TopologyEpochEvent`` when an epoch ran, else None.
        """
        svc = self.topology
        step = self.grid.stats["steps"] if grid_step is None else grid_step
        if svc is None or not (force or svc.due(step)):
            return None
        if merge_slots is None:
            merge_slots = tuple(
                slot for slot, sess in enumerate(self.grid.occupant)
                if sess is not None and sess.adapt)
        with self.tracer.span("topology.epoch", grid_step=step,
                              epoch=svc.epoch_idx) as sp:
            params, deltas, event = svc.evolve(
                self.params, self.deltas, merge_slots=merge_slots,
                grid_step=step)
            sp.set(pruned=event.pruned, regrown=event.regrown,
                   merged=len(event.merged_slots))
        self.params = params
        self._replace_lanes(self.state, deltas)
        self._refresh_exec_params()   # new mask → new compact wc/idx
        self.telemetry.record_topology_epoch(
            grid_step=event.grid_step, pruned=event.pruned,
            regrown=event.regrown, mask_change=event.mask_change,
            merged_streams=len(event.merged_slots))
        return event

    def run_until_drained(self, max_steps: int = 100_000) -> List[StreamSession]:
        """Step until every submitted session is served, then flush the
        pipeline; returns the retired sessions (bookkeeping complete)."""
        while not self.grid.drained:
            self.step()
            if self.grid.stats["steps"] >= max_steps:
                break
        self.flush()
        return self.retired

    # -- introspection -------------------------------------------------------
    @property
    def drained(self) -> bool:
        """True when no session is queued/active AND no step is in flight
        (i.e. all bookkeeping has landed)."""
        return self.grid.drained and len(self.pipeline) == 0

    @property
    def n_compiles(self) -> int:
        """Trace count of the slot-grid step (0 before warmup, must stay 1
        after — the zero-recompilation guarantee). Counted by the chunk fn
        itself rather than private jit cache internals."""
        return self.chunk_fn.n_traces()

    @property
    def utilization(self) -> float:
        """Mean fraction of lanes occupied at dispatch, over all steps."""
        return self.grid.utilization
