"""Slot-multiplexed micro-batching for stateful SNN streams.

``StreamScheduler`` generalizes the continuous batcher's fixed slot grid
(``launch.batching.SlotGrid``) from token decode to SNN timesteps. One
jitted chunk step with static shapes — events ``[chunk_len, n_slots,
n_in]``, valid ``[chunk_len, n_slots]`` — advances every active stream by
up to ``chunk_len`` timesteps; admitted streams claim a lane (reset in
place), retired streams free it. Idle or ragged tails are masked invalid,
so they neither perturb state nor accrue telemetry: an empty slot costs
exactly zero counted events.

Per step:

1. advance the virtual clock and poll every session's source for newly
   arrived chunks (Poisson arrivals → ragged per-slot backlogs);
2. admit queued sessions into free lanes;
3. pack up to ``chunk_len`` buffered timesteps per active slot, run the
   single compiled chunk fn (zero recompilation after warmup — checked in
   the benchmark);
4. route window-end logits back to sessions as predictions, fold per-lane
   metrics into per-stream telemetry, retire exhausted streams.

With a ``("slots",)`` mesh (``launch.mesh.make_serving_mesh``) the grid
shards over devices: slot allocation pads to the device count, the chunk
step runs under slot-axis ``shard_map`` (bit-identical to 1-device — see
serving/adapt.py), and lane surgery re-places its result so the slot
sharding survives admit/retire.

With a ``TopologyService`` attached, every step also feeds the service's
DSST accumulators and ``maybe_evolve_topology()`` runs due prune/regrow
epochs *between* grid steps: the evolved ``(params, deltas)`` keep their
shapes and slot shardings, so the swap is atomic from the streams' point
of view and the chunk step never recompiles (see
serving/topology_service.py).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.snn import SNNConfig, init_stream_deltas, init_stream_state
from repro.launch import sharding
from repro.launch.batching import SlotGrid

from .adapt import AdaptConfig, make_chunk_fn
from .session import (SessionStatus, StreamSession, WindowPrediction,
                      reset_lane)
from .telemetry import FleetTelemetry


class StreamScheduler:
    def __init__(self, params, cfg: SNNConfig, n_slots: int,
                 chunk_len: int = 8, adapt: Optional[AdaptConfig] = None,
                 clock_dt_s: float = 0.002,
                 telemetry: Optional[FleetTelemetry] = None,
                 mesh=None, topology=None):
        self.params, self.cfg = params, cfg
        self.mesh = mesh
        self.topology = topology          # Optional[TopologyService]
        if topology is not None and topology.cfg != cfg:
            # fail here, not at the first epoch with a half-evolved fleet
            raise ValueError("topology service was built for a different "
                             "SNNConfig than this scheduler's")
        if mesh is not None:
            # device-count-aware slot allocation: the grid is padded to a
            # multiple of the slot-mesh size so every device owns an equal
            # slot shard (padding lanes just idle — an empty slot is free),
            # and to >= 2 slots per device: at a local batch of 1 XLA:CPU
            # drops the slot matmuls to a gemv with a different K-reduction
            # order, costing bit-identity with the single-device path
            n_slots = max(sharding.round_up_slots(n_slots, mesh),
                          2 * sharding.slot_devices(mesh))
        self.n_slots, self.chunk_len = n_slots, chunk_len
        self.clock = 0.0
        self.clock_dt_s = clock_dt_s
        self.grid: SlotGrid[StreamSession] = SlotGrid(n_slots)
        self.state = init_stream_state(cfg, n_slots)
        self.deltas = init_stream_deltas(cfg, n_slots)
        if mesh is not None:
            self._state_sh = sharding.stream_shardings(self.state, mesh)
            self._delta_sh = sharding.slot_sharding(mesh)
            self.state = jax.device_put(self.state, self._state_sh)
            self.deltas = jax.device_put(self.deltas, self._delta_sh)
        self.chunk_fn = make_chunk_fn(cfg, adapt, mesh=mesh)
        self.telemetry = telemetry or FleetTelemetry()
        self.retired: List[StreamSession] = []

    # -- lifecycle -----------------------------------------------------------
    def submit(self, session: StreamSession) -> None:
        session.status = SessionStatus.QUEUED
        if session.n_in is None:
            session.n_in = self.cfg.n_in
        elif session.n_in != self.cfg.n_in:
            # fail here, not mid-step with a half-mutated grid
            raise ValueError(
                f"session {session.sid} n_in={session.n_in} != "
                f"cfg.n_in={self.cfg.n_in}")
        self.grid.submit(session)

    def _replace_lanes(self, state, deltas):
        """Install post-surgery state/deltas, restoring the slot sharding —
        eager ``.at[slot].set`` lane writes are single-lane-correct on
        sharded arrays but may leave the result unplaced."""
        if self.mesh is not None:
            state = jax.device_put(state, self._state_sh)
            deltas = jax.device_put(deltas, self._delta_sh)
        self.state, self.deltas = state, deltas

    def _admit(self) -> None:
        def on_admit(slot: int, sess: StreamSession):
            sess.slot, sess.status = slot, SessionStatus.ACTIVE
            self._replace_lanes(*reset_lane(
                self.state, self.deltas, self.cfg, slot))
        self.grid.admit(on_admit)

    def _poll_sources(self) -> None:
        for sess in list(self.grid.occupant) + list(self.grid.queue):
            if sess is not None and sess.source is not None:
                for chunk in sess.source.poll(self.clock):
                    sess.push_events(chunk)

    def _retire(self, slot: int) -> None:
        sess = self.grid.occupant[slot]
        sess.final_deltas = np.asarray(self.deltas[slot])   # [L, Kmax, N]
        sess.status, sess.slot = SessionStatus.RETIRED, None
        self.retired.append(self.grid.retire(slot))

    # -- the one grid step ---------------------------------------------------
    def step(self) -> Dict[int, int]:
        """One slot-grid step; returns {slot: timesteps fed}."""
        self.clock += self.clock_dt_s
        self._poll_sources()
        self._admit()

        C, S = self.chunk_len, self.n_slots
        events = np.zeros((C, S, self.cfg.n_in), np.float32)
        valid = np.zeros((C, S), bool)
        amask = np.zeros(S, bool)
        fed: Dict[int, int] = {}
        for slot, sess in enumerate(self.grid.occupant):
            if sess is None:
                continue
            chunk = sess.pop_chunk(C)
            n = chunk.shape[0]
            if n:
                events[:n, slot] = chunk
                valid[:n, slot] = True
            amask[slot] = sess.adapt
            fed[slot] = n

        t0 = time.perf_counter()
        self.deltas, self.state, m = self.chunk_fn(
            self.params, self.deltas, self.state, events, valid, amask)
        jax.block_until_ready(m.logits)
        self.telemetry.record_step(time.perf_counter() - t0)
        self.grid.tick()

        want_factors = self.topology is not None and not self.topology.frozen
        if not want_factors:
            # only a live topology service consumes the DSST factors — don't
            # pay their device->host transfer (a frozen service included).
            # When wanted they cross per-slot, NOT pre-summed on device: the
            # service's host-side np reduction is what keeps the 1-device
            # and sharded fleets' epoch decisions bit-identical (an XLA /
            # cross-device reduction order may differ from np's).
            m = m._replace(pre_mag=None, post_mag=None)
        m = jax.device_get(m)                  # one transfer for all metrics
        logits = m.logits                      # [C, S, n_out]
        wend = m.window_end                    # [C, S]
        for slot, sess in enumerate(self.grid.occupant):
            if sess is None:
                continue
            n = fed[slot]
            sess.timesteps_fed += n
            counters = self.telemetry.stream(sess.sid)
            counters.add_chunk(
                steps=float(m.steps[slot]),
                events_in=float(events[:, slot].sum()),
                sop_forward=float(m.sop_forward[slot]),
                sop_wu=float(m.sop_wu[slot]),
                sop_wu_offered=float(m.sop_wu_offered[slot]),
                gate_opened=float(m.gate_opened[slot].sum()),
                gate_offered=float(m.gate_offered[slot].sum()),
                windows=int(wend[:, slot].sum()),
                local_loss=float(m.local_loss[slot]))
            for t in np.nonzero(wend[:, slot])[0]:
                sess.predictions.append(WindowPrediction(
                    window_idx=len(sess.predictions),
                    logits=logits[t, slot].copy()))
            if sess.exhausted:
                self._retire(slot)
        if want_factors:
            self.topology.observe(m)
            self.maybe_evolve_topology()
        return fed

    # -- live topology evolution --------------------------------------------
    def maybe_evolve_topology(self, force: bool = False):
        """Run a due DSST prune/regrow epoch between grid steps.

        The service returns ``(params, deltas)`` with identical shapes and
        slot shardings, so installing them is an atomic swap: active
        sessions keep their lanes and carried state, and the next grid step
        reuses the already-compiled chunk fn (``n_compiles`` stays 1).
        Returns the ``TopologyEpochEvent`` when an epoch ran, else None.
        """
        svc = self.topology
        step = self.grid.stats["steps"]
        if svc is None or not (force or svc.due(step)):
            return None
        merge_slots = tuple(
            slot for slot, sess in enumerate(self.grid.occupant)
            if sess is not None and sess.adapt)
        params, deltas, event = svc.evolve(
            self.params, self.deltas, merge_slots=merge_slots, grid_step=step)
        self.params = params
        self._replace_lanes(self.state, deltas)
        self.telemetry.record_topology_epoch(
            grid_step=event.grid_step, pruned=event.pruned,
            regrown=event.regrown, mask_change=event.mask_change,
            merged_streams=len(event.merged_slots))
        return event

    def run_until_drained(self, max_steps: int = 100_000) -> List[StreamSession]:
        while not self.grid.drained:
            self.step()
            if self.grid.stats["steps"] >= max_steps:
                break
        return self.retired

    # -- introspection -------------------------------------------------------
    @property
    def n_compiles(self) -> int:
        """Trace count of the slot-grid step (0 before warmup, must stay 1
        after — the zero-recompilation guarantee). Counted by the chunk fn
        itself rather than private jit cache internals."""
        return self.chunk_fn.n_traces()

    @property
    def utilization(self) -> float:
        return self.grid.utilization
