"""Per-stream stateful SNN sessions.

A ``StreamSession`` is the host-side record of one event stream: its
identity, lifecycle status, buffered-but-unprocessed event chunks, emitted
window predictions, and accumulated per-stream telemetry. The *device*-side
state (membrane potentials, the three-trace neuron SRAM, per-stream gate
thresholds, per-stream weight deltas) lives in batched pytrees whose leading
axis is the slot index — sessions only remember *which lane* is theirs.

Lane surgery (claiming a slot on admit, snapshotting on retire) is done with
``write_lane`` / ``read_lane``: tree-maps over the batched pytrees that
touch exactly one slot index, leaving every other stream's lane
bit-identical. That single-lane discipline is what the isolation tests pin
down.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional

import jax
import numpy as np

from repro.core.snn import (SNNConfig, init_stream_deltas, init_stream_state)


class SessionStatus(enum.Enum):
    QUEUED = "queued"
    ACTIVE = "active"
    RETIRED = "retired"


@dataclasses.dataclass
class WindowPrediction:
    """Readout emitted when a session's T-step window closes."""
    window_idx: int
    logits: np.ndarray        # [n_out]

    @property
    def label(self) -> int:
        return int(np.argmax(self.logits))


@dataclasses.dataclass
class StreamSession:
    sid: int
    source: Any = None                      # StreamSource (stream_source.py)
    adapt: bool = True                      # OSSL adaptation on for this stream
    n_in: Optional[int] = None              # event width; learned on first
    #   push, or stamped by the scheduler at submit — keeps pop_chunk's
    #   empty result a well-shaped [0, n_in] (not a [0, 0] broadcast trap)
    tier: Optional[str] = None              # QoS tier; resolved at submit
    status: SessionStatus = SessionStatus.QUEUED
    slot: Optional[int] = None
    timesteps_fed: int = 0
    predictions: List[WindowPrediction] = dataclasses.field(default_factory=list)
    # buffered events that arrived but have not been stepped yet
    _pending: List[np.ndarray] = dataclasses.field(default_factory=list)
    # the IngestWorker holding this session's queued-but-undrained chunks
    # (set by IngestWorker.attach, cleared at detach); consulted by
    # ``exhausted`` so lookahead polling cannot retire a stream early
    _ingest: Any = None
    # per-stream snapshot of deltas captured at retire (for inspection or
    # for promoting a stream's adaptation into the shared base); stacked in
    # the fleet's delta layout — compact [n_layers, J, T, bk, bo] on the
    # default hot path, dense [n_layers, Kmax, n_hidden] for dense fleets
    # (engine.densify_deltas converts when a dense view is needed)
    final_deltas: Optional[np.ndarray] = None

    # -- event buffering -----------------------------------------------------
    def push_events(self, chunk: np.ndarray) -> None:
        """chunk: [c, n_in] binary spikes, any c >= 1."""
        if chunk.ndim != 2:
            raise ValueError(f"chunk must be [c, n_in], got {chunk.shape}")
        if self.n_in is None:
            self.n_in = int(chunk.shape[1])
        elif chunk.shape[1] != self.n_in:
            raise ValueError(
                f"chunk width {chunk.shape[1]} != session n_in {self.n_in}")
        self._pending.append(np.asarray(chunk, np.float32))

    def pending_timesteps(self) -> int:
        """Buffered-but-unprocessed timesteps across all pending chunks."""
        return sum(c.shape[0] for c in self._pending)

    def pop_chunk(self, max_len: int) -> np.ndarray:
        """Pop up to ``max_len`` buffered timesteps as one [c, n_in] array."""
        out, need = [], max_len
        while self._pending and need > 0:
            head = self._pending[0]
            if head.shape[0] <= need:
                out.append(self._pending.pop(0))
                need -= head.shape[0]
            else:
                out.append(head[:need])
                self._pending[0] = head[need:]
                need = 0
        if not out:
            return np.zeros((0, self.n_in or 0), np.float32)
        return np.concatenate(out, axis=0)

    @property
    def exhausted(self) -> bool:
        """True when the source has ended and no buffered events remain —
        neither here in ``_pending`` nor queued in the ingest worker.

        The ingest check closes the EOS-exactly-once hole async polling
        opens: the worker polls ahead of the grid, so ``source.exhausted``
        can flip while the tail chunk still sits in the worker's queue
        (stamped for a future tick). Without it the scheduler would
        retire the session that step and the tail would never be fed
        (the lost-tail / double-retire regression in
        tests/test_serving_qos.py).
        """
        src_done = self.source is None or self.source.exhausted
        queued = self._ingest is not None and self._ingest.has_pending(self.sid)
        return src_done and not queued and not self._pending


# ---------------------------------------------------------------------------
# lane surgery over the batched device pytrees
# ---------------------------------------------------------------------------

def write_lane(batched, single, slot: int):
    """Write ``single`` (same pytree, leading axis 1) into lane ``slot`` of
    the slot-leading ``batched`` pytree; every other lane's bits are
    untouched. Returns the new pytree (leaves are fresh arrays —
    ``.at[].set`` never mutates)."""
    return jax.tree_util.tree_map(
        lambda b, s: b.at[slot].set(s[0]), batched, single)


def read_lane(batched, slot: int):
    """Extract lane ``slot`` of every leaf of a slot-leading pytree,
    keeping a leading axis of 1 (the shape ``write_lane`` expects back)."""
    return jax.tree_util.tree_map(lambda b: b[slot:slot + 1], batched)


def fresh_lane_state(cfg: SNNConfig, compact: bool | None = None):
    """A 1-slot initial ``(StreamState, deltas)`` pair used to reset a
    claimed lane (``compact`` selects the delta layout; None = auto)."""
    return init_stream_state(cfg, 1), init_stream_deltas(cfg, 1,
                                                         compact=compact)


def reset_lane(state, deltas, cfg: SNNConfig, slot: int):
    """Return ``(state, deltas)`` with lane ``slot`` re-initialized in
    place (fresh traces, zero delta) — the admit-time lane surgery. The
    fresh lane matches the layout of the ``deltas`` it is written into."""
    s1, d1 = fresh_lane_state(cfg, compact=deltas.ndim == 6)
    return write_lane(state, s1, slot), write_lane(deltas, d1, slot)
