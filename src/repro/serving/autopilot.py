"""Occupancy-driven adaptive pipeline depth for the serving grid.

``FleetTelemetry.record_overlap`` measures, per retired step, how much
device compute the host hid behind staging: ``hidden / (hidden + wait)``
is ~1 when the fleet is host-bound (the device finished long before the
host came back — a deeper pipeline buys throughput) and ~0 when it is
device-bound (staging hides nothing — deeper queues only add latency).
:class:`DepthAutopilot` turns that dashboard number into a control loop
over ``pipeline_depth``.

Controller state machine (documented in docs/SERVING.md):

* **SERIAL** (depth 0) — unpipelined steps carry no overlap signal
  (hidden is always 0), so after ``warmup_obs`` observations the
  controller *probes* to depth 1 regardless of the EMA.
* **PIPELINED** (depth >= 1) — every ``decide_every`` grid steps, if the
  overlap EMA exceeds ``deepen_above`` and depth < ``max_depth``, deepen
  by one (host-bound: hide more); if it falls below ``relax_below`` and
  depth > ``min_pipelined_depth``, relax by one (device-bound: shorten
  the queue, but never back to 0 — that would blind the signal).
  Otherwise hold.
* **Hysteresis** — after any change the depth is frozen for
  ``hold_steps`` grid steps, and the deadband between the two thresholds
  absorbs a noisy EMA, so an oscillating overlap signal cannot flap the
  depth (pinned in tests/test_serving_qos.py).

The controller itself only *proposes* depths; the scheduler applies a
proposal at a drain-safe boundary (flush every in-flight step, then
resize the empty pipelines), which is what keeps adaptive runs
bit-identical per-stream to every fixed depth they visited — pipelining
changes when host work happens, never what the device computes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional, Tuple

from repro.obs.trace import NULL_TRACER, Tracer


@dataclasses.dataclass(frozen=True)
class AutopilotConfig:
    """Bounds and hysteresis for :class:`DepthAutopilot`.

    The thresholds are a deadband on the overlap-ratio EMA: deepen only
    above ``deepen_above``, relax only below ``relax_below``, hold in
    between.  ``hold_steps`` freezes the depth after every change;
    ``decide_every`` rate-limits evaluations; ``warmup_obs`` observations
    must land before the first decision (and before the serial→pipelined
    probe).  ``min_pipelined_depth`` is the relax floor once pipelined.
    """
    max_depth: int = 2
    min_pipelined_depth: int = 1
    ema_alpha: float = 0.25
    deepen_above: float = 0.6
    relax_below: float = 0.05
    decide_every: int = 4
    hold_steps: int = 8
    warmup_obs: int = 2
    timeline_maxlen: int = 512

    def __post_init__(self):
        if not 0 <= self.min_pipelined_depth <= self.max_depth:
            raise ValueError(
                f"need 0 <= min_pipelined_depth <= max_depth, got "
                f"{self.min_pipelined_depth}..{self.max_depth}")
        if not 0.0 <= self.relax_below <= self.deepen_above <= 1.0:
            raise ValueError(
                "thresholds must satisfy 0 <= relax_below <= deepen_above "
                f"<= 1, got {self.relax_below}/{self.deepen_above}")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(f"ema_alpha in (0, 1], got {self.ema_alpha}")


class DepthAutopilot:
    """EMA-of-overlap pipeline-depth controller (host-only, no device
    interaction — HOST01-scoped).  ``observe`` folds one retired step's
    overlap ratio; ``decide`` returns the depth to run the next step at.
    ``timeline`` is a bounded ring of ``(grid_step, depth)`` change
    points — the chosen-depth timeline the bench artifact records."""

    def __init__(self, config: Optional[AutopilotConfig] = None,
                 tracer: Optional[Tracer] = None):
        self.cfg = config or AutopilotConfig()
        self.tracer = tracer or NULL_TRACER
        self.ema: Optional[float] = None      # None until first observation
        self.decisions = 0                    # depth changes proposed
        self.timeline: Deque[Tuple[int, int]] = deque(
            maxlen=self.cfg.timeline_maxlen)
        self._observed = 0
        self._last_eval_step = -10 ** 9
        self._last_change_step = -10 ** 9

    def note_depth(self, grid_step: int, depth: int) -> None:
        """Record a depth as current (the scheduler calls this with the
        initial depth and after applying each proposal)."""
        if not self.timeline or self.timeline[-1][1] != depth:
            self.timeline.append((int(grid_step), int(depth)))

    def observe(self, overlap_ratio: float) -> float:
        """Fold one retired step's overlap ratio into the EMA; returns
        the updated EMA (the value ``serving_overlap_ema`` exports)."""
        r = min(1.0, max(0.0, float(overlap_ratio)))
        self.ema = r if self.ema is None else (
            self.cfg.ema_alpha * r + (1.0 - self.cfg.ema_alpha) * self.ema)
        self._observed += 1
        return self.ema

    def decide(self, grid_step: int, depth: int) -> int:
        """Proposed pipeline depth for the step about to be staged.

        Returns ``depth`` unchanged while warming up, rate-limited, or
        frozen by hysteresis; otherwise applies the state machine above.
        Each evaluation emits an ``autopilot.decision`` trace span whose
        ``action`` attr is ``probe``/``deepen``/``relax``/``hold``.
        """
        c = self.cfg
        if self._observed < c.warmup_obs:
            return depth
        if grid_step - self._last_eval_step < c.decide_every:
            return depth
        self._last_eval_step = grid_step
        if grid_step - self._last_change_step < c.hold_steps:
            return depth
        ema = self.ema if self.ema is not None else 0.0
        action, new = "hold", depth
        if depth < 1 <= c.max_depth:
            # serial steps record overlap 0 by construction — there is no
            # signal to read until the fleet pipelines, so probe to 1
            action, new = "probe", 1
        elif ema > c.deepen_above and depth < c.max_depth:
            action, new = "deepen", depth + 1
        elif ema < c.relax_below and depth > c.min_pipelined_depth:
            action, new = "relax", depth - 1
        with self.tracer.span("autopilot.decision", grid_step=grid_step,
                              action=action, ema=round(ema, 4),
                              depth=depth, proposed=new):
            pass
        if new != depth:
            self._last_change_step = grid_step
            self.decisions += 1
        return new

    def depths_visited(self) -> Tuple[int, ...]:
        """Sorted unique depths the fleet actually ran at (from the
        change-point timeline) — what the bit-parity test replays as
        fixed-depth references."""
        return tuple(sorted({d for _, d in self.timeline}))
