"""Event-stream serving runtime.

Stateful SNN sessions, slot-multiplexed micro-batching over one jitted
chunk step with double-buffered event staging, per-stream gated OSSL
adaptation on a frozen shared base, live DSST topology evolution, and
per-stream/fleet energy telemetry. See ``docs/ARCHITECTURE.md`` /
``docs/SERVING.md`` and the modules' docstrings for the architecture.
"""
from .adapt import AdaptConfig, delta_norms, make_chunk_fn, merge_lane_into_base
from .checkpointing import restore_fleet, save_fleet
from .scheduler import StreamScheduler
from .session import (SessionStatus, StreamSession, WindowPrediction,
                      fresh_lane_state, read_lane, reset_lane, write_lane)
from .staging import InFlight, LaneRecord, StagedChunk, StagingPipeline
from .stream_source import ArrivalConfig, ReplaySource, TaskStreamSource
from .telemetry import FleetTelemetry, StreamCounters
from .topology_service import (TopologyEpochEvent, TopologyService,
                               TopologyServiceConfig)

__all__ = [
    "AdaptConfig", "ArrivalConfig", "FleetTelemetry", "InFlight",
    "LaneRecord", "ReplaySource", "SessionStatus", "StagedChunk",
    "StagingPipeline", "StreamCounters", "StreamScheduler", "StreamSession",
    "TaskStreamSource", "TopologyEpochEvent", "TopologyService",
    "TopologyServiceConfig", "WindowPrediction", "delta_norms",
    "fresh_lane_state", "make_chunk_fn", "merge_lane_into_base", "read_lane",
    "reset_lane", "restore_fleet", "save_fleet", "write_lane",
]
