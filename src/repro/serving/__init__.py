"""Event-stream serving runtime.

Stateful SNN sessions, slot-multiplexed micro-batching over per-tier
jitted chunk steps with double-buffered event staging, asynchronous
source ingestion, occupancy-driven adaptive pipeline depth, per-stream
gated OSSL adaptation on a frozen shared base, live DSST topology
evolution, and per-stream/tier/fleet energy telemetry. See
``docs/ARCHITECTURE.md`` / ``docs/SERVING.md`` and the modules'
docstrings for the architecture.
"""
from .adapt import AdaptConfig, delta_norms, make_chunk_fn, merge_lane_into_base
from .autopilot import AutopilotConfig, DepthAutopilot
from .checkpointing import restore_fleet, save_fleet
from .ingest import IngestConfig, IngestWorker
from .scheduler import StreamScheduler, TierConfig
from .session import (SessionStatus, StreamSession, WindowPrediction,
                      fresh_lane_state, read_lane, reset_lane, write_lane)
from .staging import InFlight, LaneRecord, StagedChunk, StagingPipeline
from .stream_source import (AERStreamSource, ArrivalConfig, ReplaySource,
                            TaskStreamSource, aer_decode, aer_encode)
from .telemetry import FleetTelemetry, StreamCounters
from .topology_service import (TopologyEpochEvent, TopologyService,
                               TopologyServiceConfig)

__all__ = [
    "AdaptConfig", "AERStreamSource", "ArrivalConfig", "AutopilotConfig",
    "DepthAutopilot", "FleetTelemetry", "InFlight", "IngestConfig",
    "IngestWorker", "LaneRecord", "ReplaySource", "SessionStatus",
    "StagedChunk", "StagingPipeline", "StreamCounters", "StreamScheduler",
    "StreamSession", "TaskStreamSource", "TierConfig", "TopologyEpochEvent",
    "TopologyService", "TopologyServiceConfig", "WindowPrediction",
    "aer_decode", "aer_encode", "delta_norms", "fresh_lane_state",
    "make_chunk_fn", "merge_lane_into_base", "read_lane", "reset_lane",
    "restore_fleet", "save_fleet", "write_lane",
]
