"""Streaming adapters: ragged, asynchronously-arriving event chunks.

Real event sensors (DVS cameras, cochleas, EEG front-ends) do not deliver
aligned [T, B, n_in] batches — they deliver bursts of timesteps whose
length and arrival time vary per stream. These adapters wrap the synthetic
tasks in ``data/events.py`` into exactly that shape so the scheduler is
exercised realistically:

* chunk lengths are drawn uniformly in [min_chunk, max_chunk];
* inter-arrival gaps are exponential (Poisson arrivals) on a virtual clock;
* ``poll(now)`` releases only the chunks that have "arrived" by ``now``.

Everything is seeded and deterministic, so scheduler tests can replay the
same traffic.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.data.events import EventTask


@dataclasses.dataclass(frozen=True)
class ArrivalConfig:
    min_chunk: int = 4
    max_chunk: int = 16
    mean_gap_s: float = 0.005      # exponential inter-arrival mean
    start_jitter_s: float = 0.01   # uniform offset of the first chunk


class ReplaySource:
    """Deterministic source over a pre-materialized event array (tests)."""

    def __init__(self, events: np.ndarray, chunk_len: int = 8):
        self._events = np.asarray(events, np.float32)   # [T_total, n_in]
        self._chunk_len = chunk_len
        self._cursor = 0

    @property
    def exhausted(self) -> bool:
        """True once every replayed timestep has been released."""
        return self._cursor >= self._events.shape[0]

    def poll(self, now: float) -> List[np.ndarray]:
        """Release the next ``chunk_len`` timesteps as one ``[c, n_in]``
        chunk (ignores ``now`` — replay is clock-independent)."""
        if self.exhausted:
            return []
        end = min(self._cursor + self._chunk_len, self._events.shape[0])
        chunk = self._events[self._cursor:end]
        self._cursor = end
        return [chunk]


class TaskStreamSource:
    """Continuous stream over an ``EventTask``: windows back-to-back, cut
    into ragged chunks with Poisson arrivals on a virtual clock."""

    def __init__(self, task: EventTask, n_windows: int, seed: int = 0,
                 arrival: ArrivalConfig | None = None):
        self.task = task
        self.arrival = arrival or ArrivalConfig()
        rng = np.random.default_rng(seed)
        windows, labels = zip(*task.sample_stream(rng, n_windows))
        stream = np.concatenate(windows, axis=0)           # [W*T, n_in]
        self.labels = np.asarray(labels, np.int32)         # [W] per-window
        self._chunks: List[Tuple[float, np.ndarray]] = []
        t = float(rng.uniform(0.0, self.arrival.start_jitter_s))
        cursor = 0
        while cursor < stream.shape[0]:
            c = int(rng.integers(self.arrival.min_chunk,
                                 self.arrival.max_chunk + 1))
            self._chunks.append((t, stream[cursor:cursor + c]))
            cursor += c
            t += float(rng.exponential(self.arrival.mean_gap_s))
        self._next = 0

    @property
    def exhausted(self) -> bool:
        """True once every pre-cut chunk has arrived and been polled."""
        return self._next >= len(self._chunks)

    @property
    def n_timesteps(self) -> int:
        """Total timesteps this source will deliver over its lifetime."""
        return sum(c.shape[0] for _, c in self._chunks)

    def poll(self, now: float) -> List[np.ndarray]:
        """Chunks whose arrival time is <= ``now`` (virtual seconds)."""
        out = []
        while (self._next < len(self._chunks)
               and self._chunks[self._next][0] <= now):
            out.append(self._chunks[self._next][1])
            self._next += 1
        return out
