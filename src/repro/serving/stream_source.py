"""Streaming adapters: ragged, asynchronously-arriving event chunks.

Real event sensors (DVS cameras, cochleas, EEG front-ends) do not deliver
aligned [T, B, n_in] batches — they deliver bursts of timesteps whose
length and arrival time vary per stream. These adapters wrap the synthetic
tasks in ``data/events.py`` into exactly that shape so the scheduler is
exercised realistically:

* chunk lengths are drawn uniformly in [min_chunk, max_chunk];
* inter-arrival gaps are exponential (Poisson arrivals) on a virtual clock;
* ``poll(now)`` releases only the chunks that have "arrived" by ``now``.

Everything is seeded and deterministic, so scheduler tests can replay the
same traffic.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.data.events import EventTask


@dataclasses.dataclass(frozen=True)
class ArrivalConfig:
    min_chunk: int = 4
    max_chunk: int = 16
    mean_gap_s: float = 0.005      # exponential inter-arrival mean
    start_jitter_s: float = 0.01   # uniform offset of the first chunk


class ReplaySource:
    """Deterministic source over a pre-materialized event array (tests)."""

    def __init__(self, events: np.ndarray, chunk_len: int = 8):
        self._events = np.asarray(events, np.float32)   # [T_total, n_in]
        self._chunk_len = chunk_len
        self._cursor = 0

    @property
    def exhausted(self) -> bool:
        """True once every replayed timestep has been released."""
        return self._cursor >= self._events.shape[0]

    @property
    def n_timesteps(self) -> int:
        """Total timesteps this source will deliver over its lifetime."""
        return int(self._events.shape[0])

    def poll(self, now: float) -> List[np.ndarray]:
        """Release the next ``chunk_len`` timesteps as one ``[c, n_in]``
        chunk (ignores ``now`` — replay is clock-independent)."""
        if self.exhausted:
            return []
        end = min(self._cursor + self._chunk_len, self._events.shape[0])
        chunk = self._events[self._cursor:end]
        self._cursor = end
        return [chunk]


class TaskStreamSource:
    """Continuous stream over an ``EventTask``: windows back-to-back, cut
    into ragged chunks with Poisson arrivals on a virtual clock."""

    def __init__(self, task: EventTask, n_windows: int, seed: int = 0,
                 arrival: ArrivalConfig | None = None):
        self.task = task
        self.arrival = arrival or ArrivalConfig()
        rng = np.random.default_rng(seed)
        windows, labels = zip(*task.sample_stream(rng, n_windows))
        stream = np.concatenate(windows, axis=0)           # [W*T, n_in]
        self.labels = np.asarray(labels, np.int32)         # [W] per-window
        self._chunks: List[Tuple[float, np.ndarray]] = []
        t = float(rng.uniform(0.0, self.arrival.start_jitter_s))
        cursor = 0
        while cursor < stream.shape[0]:
            c = int(rng.integers(self.arrival.min_chunk,
                                 self.arrival.max_chunk + 1))
            self._chunks.append((t, stream[cursor:cursor + c]))
            cursor += c
            t += float(rng.exponential(self.arrival.mean_gap_s))
        self._next = 0

    @property
    def exhausted(self) -> bool:
        """True once every pre-cut chunk has arrived and been polled."""
        return self._next >= len(self._chunks)

    @property
    def n_timesteps(self) -> int:
        """Total timesteps this source will deliver over its lifetime."""
        return sum(c.shape[0] for _, c in self._chunks)

    def poll(self, now: float) -> List[np.ndarray]:
        """Chunks whose arrival time is <= ``now`` (virtual seconds)."""
        out = []
        while (self._next < len(self._chunks)
               and self._chunks[self._next][0] <= now):
            out.append(self._chunks[self._next][1])
            self._next += 1
        return out


# ---------------------------------------------------------------------------
# address-event representation (AER) — packed chunks with a real decode cost
# ---------------------------------------------------------------------------

def aer_encode(chunk: np.ndarray):
    """Pack a dense ``[c, n_in]`` binary spike chunk as address events:
    ``(c, n_in, t_idx, k_idx)`` with one ``(t, k)`` address pair per
    spike — the wire format an event camera or ElfCore's async SerDes
    front-end actually ships (nonzero entries are treated as spikes)."""
    t, k = np.nonzero(chunk)
    return (int(chunk.shape[0]), int(chunk.shape[1]),
            t.astype(np.int32), k.astype(np.int32))


def aer_decode(c: int, n_in: int, t: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Densify one AER-packed chunk back to ``[c, n_in]`` f32 spikes —
    the per-chunk decode work a real event front-end pays at ingest."""
    out = np.zeros((c, n_in), np.float32)
    out[t, k] = 1.0
    return out


class AERStreamSource:
    """A :class:`TaskStreamSource` whose chunks are stored address-event
    packed and densified at ``poll`` time.

    Same seeded arrival schedule, chunk cuts and labels as a
    ``TaskStreamSource(task, n_windows, seed, arrival)`` — the two are
    poll-for-poll identical (pinned in tests/test_serving_qos.py) — but
    each poll pays a genuine decode cost.  Polled inline that cost lands
    in the stage phase and stalls the grid; behind the ingest worker it
    runs off the critical path — this source is what makes the async-
    ingestion A/B in ``bench_serving_streams`` measure a real win rather
    than a bookkeeping shuffle.  Spikes are binary, so the encode/decode
    round trip is exact and determinism is untouched.
    """

    def __init__(self, task: EventTask, n_windows: int, seed: int = 0,
                 arrival: ArrivalConfig | None = None):
        inner = TaskStreamSource(task, n_windows, seed=seed, arrival=arrival)
        self.labels = inner.labels
        self._packed = [(t, aer_encode(c)) for t, c in inner._chunks]
        self._next = 0

    @property
    def exhausted(self) -> bool:
        """True once every packed chunk has arrived and been polled."""
        return self._next >= len(self._packed)

    @property
    def n_timesteps(self) -> int:
        """Total timesteps this source will deliver over its lifetime."""
        return sum(c for _, (c, _n, _t, _k) in self._packed)

    def poll(self, now: float) -> List[np.ndarray]:
        """Densified chunks whose arrival time is <= ``now``."""
        out = []
        while (self._next < len(self._packed)
               and self._packed[self._next][0] <= now):
            out.append(aer_decode(*self._packed[self._next][1]))
            self._next += 1
        return out
