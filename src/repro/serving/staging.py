"""Double-buffered event staging for the serving hot path.

The grid step used to be strictly serial per step: pack host event buffers
→ dispatch the jitted chunk fn → block on the device → fetch metrics →
bookkeep.  Host packing and device compute each sat idle while the other
ran.  This module is the pipelining half of the fix (the other half is the
static ``want_factors`` seam in ``adapt.make_chunk_fn``): the scheduler's
step is split into three explicit phases —

* **stage**   — host-only: advance the virtual clock, poll sources, admit
  queued sessions, pack the ``[C, S, n_in]`` event / ``[C, S]`` valid
  buffers, and *decide* which sessions will exhaust after this step (a
  pure host fact: source done + pending buffer drained).  Produces a
  :class:`StagedChunk`.
* **dispatch** — enqueue the chunk fn on the staged buffers and return
  immediately (JAX dispatch is asynchronous); the device handles plus the
  staged host record become an :class:`InFlight` step.
* **retire**  — consume one in-flight step's results: fetch its metrics
  (this is the only point that waits on the device), route window
  predictions, fold telemetry, finalize retiring sessions from the
  *captured* output handles, and feed/drive the topology service.

With ``depth=0`` the three phases run back-to-back inside one ``step()``
— bit-identical to the pre-pipeline scheduler.  With ``depth>=1``
(:class:`StagingPipeline` holds the in-flight steps) the stage phase for
grid step ``t+1`` runs **while the device computes step t**, exactly the
way event-driven silicon (ElfCore's async SerDes front-end, ReckOn's
spike buffers) hides I/O behind compute.  Because JAX arrays are
immutable, the in-flight record's ``deltas``/``metrics`` handles are
unaffected by the lane surgery later stages perform on the scheduler's
live arrays, so deferred bookkeeping reads exactly the values the step
produced — the pipeline changes *when* host work happens, never *what*
the device computes.  Pipeline-on and pipeline-off trajectories are
pinned bit-identical (1-device and 8-device) in
``tests/test_serving_pipeline.py``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Tuple


@dataclasses.dataclass
class LaneRecord:
    """What one occupied lane was fed this grid step (host-side facts that
    the retire phase pairs with the device metrics)."""
    slot: int
    session: Any                 # StreamSession
    n_fed: int                   # timesteps packed into the lane
    events_in: float             # total input spikes packed (telemetry)


@dataclasses.dataclass
class StagedChunk:
    """One grid step's host-assembled inputs + scheduling decisions.

    ``events [C, S, n_in]`` / ``valid [C, S]`` / ``adapt_mask [S]`` are the
    chunk fn's staging buffers.  ``retiring`` lists the ``(slot, session)``
    pairs that exhaust after this step — known at stage time, finalized at
    retire time.  ``merge_slots`` snapshots the adaptive occupants eligible
    for a hot-stream fold should a topology epoch run after this step
    (captured here so a pipelined retire sees the same candidate set the
    serial scheduler would — admissions from *later* stage phases must not
    leak into an earlier step's epoch).
    """
    events: Any                  # np.ndarray [C, S, n_in] f32
    valid: Any                   # np.ndarray [C, S] bool
    adapt_mask: Any              # np.ndarray [S] bool
    lanes: List[LaneRecord]
    retiring: List[Tuple[int, Any]]
    merge_slots: Tuple[int, ...]
    fed: Dict[int, int]          # {slot: timesteps fed} (step() return value)


@dataclasses.dataclass
class InFlight:
    """A dispatched-but-unretired grid step: the staged host record plus
    the chunk fn's (asynchronous) output handles.  ``deltas`` is captured
    at dispatch, so retiring sessions snapshot their final adaptation even
    if a later admit has already reset that lane on the live arrays."""
    staged: StagedChunk
    deltas: Any                  # slot-leading delta handle (post-step); compact [S, L, J, T, bk, bo] or dense [S, L, Kmax, N]
    metrics: Any                 # ChunkMetrics device handles
    grid_step: int               # grid.stats["steps"] after this step's tick
    # host/device overlap bookkeeping (stamped by StagingPipeline push/pop;
    # both stay 0.0 on the serial depth=0 path, which never enqueues)
    pushed_at: float = 0.0       # perf_counter when the step entered the queue
    queued_s: float = 0.0        # time in flight before retire began


class StagingPipeline:
    """Bounded FIFO of in-flight grid steps (the double buffer).

    ``depth`` is the number of dispatched steps that may be outstanding
    before the scheduler must retire the oldest:

    * ``0`` — synchronous: every step retires before ``step()`` returns
      (the reference behavior; still runs through the same three phases).
    * ``1`` — double buffering: step ``t+1`` is staged while step ``t``
      computes.  The sweet spot: host packing is hidden, and a topology
      epoch due after step ``t`` still lands before step ``t+1`` is
      dispatched, which is what keeps evolving fleets bit-identical to
      the synchronous path.
    * ``>1`` — deeper queues additionally hide retire-phase host
      bookkeeping, but defer an epoch past already-dispatched steps — the
      scheduler therefore clamps depth to 1 when a live topology service
      is attached.
    """

    def __init__(self, depth: int = 1):
        if depth < 0:
            raise ValueError(f"pipeline depth must be >= 0, got {depth}")
        self.depth = depth
        self._q: Deque[InFlight] = deque()

    def set_depth(self, depth: int) -> None:
        """Resize the pipeline at a drain-safe boundary (the adaptive-depth
        autopilot's apply point). Refuses while steps are in flight —
        shrinking under a loaded queue would strand bookkeeping, and the
        bit-identity argument for adaptive depth rests on every resize
        happening against an empty pipeline (flush first)."""
        if depth < 0:
            raise ValueError(f"pipeline depth must be >= 0, got {depth}")
        if self._q:
            raise RuntimeError(
                f"cannot resize with {len(self._q)} step(s) in flight — "
                "flush the pipeline first (depth changes land only at "
                "drain-safe boundaries)")
        self.depth = depth

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        """True when a dispatch must be preceded by retiring the oldest."""
        return len(self._q) >= max(self.depth, 1)

    def push(self, fl: InFlight) -> None:
        if self.depth == 0:
            raise RuntimeError("synchronous pipeline (depth=0) cannot hold "
                               "in-flight steps; retire immediately instead")
        if self.full:
            raise RuntimeError("staging pipeline full; retire first")
        if hasattr(fl, "pushed_at"):
            fl.pushed_at = time.perf_counter()
        self._q.append(fl)

    def pop(self) -> InFlight:
        """Oldest in-flight step (FIFO — retire order is dispatch order).

        Stamps ``queued_s`` — how long the step was in flight while the
        host kept working (staging later steps). Paired with the retire
        phase's measured device wait, this yields the per-step host/device
        **overlap ratio** ``queued / (queued + wait)``: ~1 host-bound,
        ~0 device-bound (see ``FleetTelemetry.record_overlap``).
        """
        fl = self._q.popleft()
        if hasattr(fl, "pushed_at") and fl.pushed_at:
            fl.queued_s = time.perf_counter() - fl.pushed_at
        return fl
