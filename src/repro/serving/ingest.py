"""Asynchronous source ingestion for the serving hot path.

``StreamScheduler._poll_sources`` used to call every session's
``StreamSource.poll`` inline in the stage phase, so a source with a real
decode cost (an AER front-end unpacking address events, a codec, a
socket read) stalled the grid step and left the device idle for exactly
that long.  :class:`IngestWorker` moves the polling to a dedicated
daemon thread that drains each source into a bounded per-stream chunk
queue; the stage phase's ``_poll_sources`` becomes a lock-protected
queue drain that only moves already-decoded chunks into session buffers.

**Determinism contract.**  Async ingestion must not change *what* the
grid computes, only *when* the host pays for polling.  Three rules make
the worker bit-identical to the serial path:

* the worker replays the scheduler's virtual clock exactly — it calls
  ``poll(clock_at_tick)`` once per stream per grid tick, in tick order,
  with the clock accumulated ``+= clock_dt_s`` from 0.0 so the float
  sequence matches the serial scheduler's bit for bit (``k * dt`` would
  not);
* queued chunks carry ``(seq, tick)`` stamps; :meth:`drain` releases
  only chunks stamped at or before the grid tick being staged, in
  strictly monotone ``seq`` order (a gap or reorder raises), so a
  session's ``_pending`` buffer receives exactly the chunks — in exactly
  the order — the serial poll would have pushed at that tick;
* if the worker has not yet reached the drained tick for some stream
  (cold start, or it was parked by backpressure), :meth:`drain`
  steal-polls that stream inline under the lock, so the grid never
  observes a late chunk.

**Backpressure.**  The worker polls a stream ahead of the grid only
while its queue holds fewer than ``capacity_chunks`` entries and its
poll tick is within ``lookahead_ticks`` of the published grid tick; a
slow consumer therefore parks the producer instead of growing host
memory (the bounded-queue test asserts the high-water mark).  The queue
itself is an unbounded deque *gated by an explicit capacity check* — a
``deque(maxlen=...)`` would silently drop chunks instead of parking.

The lock is a ``threading.Condition``: every mutation of worker state
happens inside ``with self._lock`` (the lint's OBS02 discipline), and
the worker sleeps on the condition when it has nothing to do instead of
spinning.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Deque, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Bounds for the ingest worker.

    ``capacity_chunks``: max decoded chunks queued per stream before the
    worker parks that stream (backpressure; the grid's drain un-parks it).
    ``lookahead_ticks``: how many grid ticks ahead of the published tick
    the worker may poll — bounds both memory and how early a source's
    ``exhausted`` flag can flip (the session's EOS check compensates via
    :meth:`IngestWorker.has_pending`).
    ``idle_wait_s``: condition-wait timeout when fully caught up.
    """
    capacity_chunks: int = 64
    lookahead_ticks: int = 8
    idle_wait_s: float = 0.0005

    def __post_init__(self):
        if self.capacity_chunks < 1:
            raise ValueError("capacity_chunks must be >= 1")
        if self.lookahead_ticks < 1:
            raise ValueError("lookahead_ticks must be >= 1")


class _StreamQueue:
    """Per-stream ingest state: the bounded chunk queue plus the stream's
    private replica of the virtual clock (each stream accumulates its own
    ``+= dt`` sequence from its attach point, so poll clocks are
    bit-identical to the serial scheduler's)."""

    __slots__ = ("session", "chunks", "polled_tick", "clock", "seq",
                 "drained_seq", "peak")

    def __init__(self, session, tick: int, clock: float):
        self.session = session
        self.chunks: Deque[Tuple[int, int, Any]] = deque()  # (seq, tick, chunk)
        self.polled_tick = tick       # last tick this stream was polled for
        self.clock = clock            # virtual clock at polled_tick
        self.seq = 0                  # last sequence stamp issued
        self.drained_seq = 0          # last sequence stamp released to the grid
        self.peak = 0                 # high-water queue depth (backpressure cap)


class IngestWorker:
    """Drains ``StreamSource.poll`` into bounded per-stream chunk queues
    off the grid-step critical path.

    Lifecycle: the scheduler constructs one worker, :meth:`attach`\\ es
    each session at submit, calls :meth:`drain` once per grid tick from
    ``_poll_sources``, :meth:`detach`\\ es sessions as they retire, and
    :meth:`stop`\\ s the worker at :meth:`StreamScheduler.close`.  All
    shared state lives behind one condition lock.
    """

    def __init__(self, clock_dt_s: float,
                 config: Optional[IngestConfig] = None):
        self.cfg = config or IngestConfig()
        self._dt = float(clock_dt_s)
        self._lock = threading.Condition()
        self._streams: Dict[int, _StreamQueue] = {}
        self._tick = 0            # last grid tick published by drain()
        self._clock = 0.0         # virtual clock at _tick (+= dt replica)
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        self._polls = 0           # background polls issued by the worker
        self._steal_polls = 0     # catch-up polls issued inline by drain()
        self._chunks_queued = 0   # chunks decoded into queues, lifetime
        self._queue_peak = 0      # max per-stream queue depth ever seen

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Start the background poll thread (idempotent)."""
        with self._lock:
            if self._thread is not None or self._stop:
                return
            self._thread = threading.Thread(
                target=self._run, name="serving-ingest", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Stop the worker and join its thread; queued-but-undrained
        chunks are discarded (callers drain through the last tick first —
        ``run_until_drained`` does)."""
        with self._lock:
            self._stop = True
            self._lock.notify_all()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)

    def attach(self, session) -> None:
        """Register a session's source for background polling.  The
        stream's poll clock starts at the *published* grid tick, exactly
        where the serial path would first poll a freshly submitted
        session (the next stage phase)."""
        with self._lock:
            if session.sid in self._streams:
                raise ValueError(f"stream {session.sid} already attached")
            self._streams[session.sid] = _StreamQueue(
                session, self._tick, self._clock)
            session._ingest = self
            self._lock.notify_all()
        self.start()

    def detach(self, session) -> None:
        """Unregister a retired session (no-op if never attached).  Its
        queue must already be empty — a retire with queued chunks means
        the EOS discipline broke upstream."""
        with self._lock:
            q = self._streams.pop(session.sid, None)
            session._ingest = None
            if q is not None and q.chunks:
                raise RuntimeError(
                    f"stream {session.sid} detached with {len(q.chunks)} "
                    "undrained chunks — retired before EOS")

    # -- grid-facing API -----------------------------------------------------
    def has_pending(self, sid: int) -> bool:
        """True while the worker holds queued-but-undrained chunks for
        ``sid``.  ``StreamSession.exhausted`` consults this: lookahead
        polling flips ``source.exhausted`` *early*, and without this
        check a session with a queued tail chunk would retire before the
        tail landed (the lost-tail / double-retire regression)."""
        with self._lock:
            q = self._streams.get(sid)
            return q is not None and bool(q.chunks)

    def drain(self, tick: int) -> Tuple[int, int]:
        """Release every queued chunk stamped at or before grid ``tick``
        into its session's buffer; returns ``(chunks_pushed,
        queue_peak)``.  This is the lock-protected queue drain that
        replaced the inline poll loop in ``_poll_sources`` — the only
        ingest work left on the grid-step critical path.

        Publishing ``tick`` also advances the master virtual clock and
        wakes the worker to poll ahead of the new tick.  Streams the
        worker has not caught up to are steal-polled inline so no chunk
        arrives late.  Chunk release asserts monotone, gap-free sequence
        stamps per stream.
        """
        pushed = 0
        with self._lock:
            if self._err is not None:
                raise RuntimeError("ingest worker died") from self._err
            while self._tick < tick:      # replicate the += dt accumulation
                self._tick += 1
                self._clock += self._dt
            for q in self._streams.values():
                while q.polled_tick < tick:
                    self._steal_polls += 1
                    self._poll_one(q)
                while q.chunks and q.chunks[0][1] <= tick:
                    seq, _t, chunk = q.chunks.popleft()
                    if seq != q.drained_seq + 1:
                        raise RuntimeError(
                            f"stream {q.session.sid} sequence gap: "
                            f"expected {q.drained_seq + 1}, got {seq}")
                    q.drained_seq = seq
                    q.session.push_events(chunk)
                    pushed += 1
            peak = self._queue_peak
            self._lock.notify_all()
        return pushed, peak

    def stats(self) -> dict:
        """Lifetime worker stats (for telemetry and the backpressure
        tests): background vs steal polls, chunks decoded, high-water
        per-stream queue depth, streams attached now."""
        with self._lock:
            return {"polls": self._polls,
                    "steal_polls": self._steal_polls,
                    "chunks_queued": self._chunks_queued,
                    "queue_peak": self._queue_peak,
                    "attached": len(self._streams)}

    # -- worker internals ----------------------------------------------------
    def _poll_one(self, q: _StreamQueue) -> int:
        """Advance one stream by one grid tick: accumulate its clock
        replica, poll its source once at that clock, stamp and queue the
        resulting chunks.  Caller holds the lock; mutates only ``q``."""
        q.clock += self._dt
        q.polled_tick += 1
        src = q.session.source
        chunks = [] if src is None else src.poll(q.clock)
        for chunk in chunks:
            q.seq += 1
            q.chunks.append((q.seq, q.polled_tick, chunk))
        q.peak = max(q.peak, len(q.chunks))
        return len(chunks)

    def _poll_round(self) -> Tuple[int, int]:
        """One bounded unit of background work: poll each lagging,
        un-parked stream forward by at most one tick.  Caller holds the
        lock; returns ``(polls_issued, chunks_queued)`` so the run loop
        can fold them into ``self`` under the same lock hold."""
        target = self._tick + self.cfg.lookahead_ticks
        polls = queued = 0
        for q in self._streams.values():
            if q.polled_tick >= target:
                continue                       # caught up
            if len(q.chunks) >= self.cfg.capacity_chunks:
                continue                       # parked by backpressure
            polls += 1
            queued += self._poll_one(q)
        return polls, queued

    def _run(self) -> None:
        while True:
            with self._lock:
                if self._stop:
                    return
                try:
                    polls, queued = self._poll_round()
                except BaseException as e:     # surface at the next drain
                    self._err = e
                    return
                self._polls += polls
                self._chunks_queued += queued
                if self._streams:
                    self._queue_peak = max(
                        self._queue_peak,
                        max(q.peak for q in self._streams.values()))
                if polls == 0:
                    # caught up (or every lagging stream is parked): sleep
                    # until a drain publishes a new tick or capacity frees
                    self._lock.wait(self.cfg.idle_wait_s)
