"""Fault tolerance: failure recovery, elastic re-meshing, straggler policy.

Designed for the 1000+-node posture; exercised here by *simulation* (the
container has one real device, so failures are injected, not suffered):

* ``run_with_recovery`` — a supervisor loop around a training step: on a
  (simulated) node failure it restores the latest valid checkpoint and
  continues; tests assert the continuation is bitwise-identical to an
  uninterrupted run (determinism = the whole point of step-indexed data).
* ``elastic_remesh`` — rebuild a smaller/larger mesh and re-shard a pytree
  onto it with ``jax.device_put`` (the DP axis shrinks when replicas die;
  params are model-sharded so only the data axis changes).
* ``HeartbeatMonitor`` / ``StragglerPolicy`` — per-replica step-time EMAs;
  replicas slower than ``threshold ×`` the fleet median get flagged for
  (a) hot-spare swap or (b) exclusion at the next elastic boundary. On a
  real fleet the timings come from the coordinator's heartbeats; tests feed
  synthetic timings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import checkpoint as ckpt


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 1.5        # x median step time
    ema: float = 0.3
    min_steps: int = 3            # grace period before flagging


class HeartbeatMonitor:
    def __init__(self, n_replicas: int, policy: Optional[StragglerPolicy] = None):
        self.policy = policy or StragglerPolicy()
        self.ema = np.zeros(n_replicas)
        self.count = np.zeros(n_replicas, int)

    def record(self, replica: int, step_time: float):
        a = self.policy.ema
        if self.count[replica] == 0:
            self.ema[replica] = step_time
        else:
            self.ema[replica] = (1 - a) * self.ema[replica] + a * step_time
        self.count[replica] += 1

    def stragglers(self) -> List[int]:
        ready = self.count >= self.policy.min_steps
        if not ready.any():
            return []
        med = float(np.median(self.ema[ready]))
        flag = ready & (self.ema > self.policy.threshold * med)
        return [int(i) for i in np.where(flag)[0]]

    def healthy_replicas(self) -> List[int]:
        bad = set(self.stragglers())
        return [i for i in range(len(self.ema)) if i not in bad]


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------

def elastic_remesh(tree: Any, new_mesh: jax.sharding.Mesh,
                   spec_fn: Callable[[Any], jax.sharding.PartitionSpec]) -> Any:
    """Re-shard every leaf onto ``new_mesh`` (device_put handles movement)."""
    def one(path, leaf):
        spec = spec_fn(path)
        return jax.device_put(leaf, jax.sharding.NamedSharding(new_mesh, spec))
    return jax.tree_util.tree_map_with_path(one, tree)


class SimulatedFailure(RuntimeError):
    """Injected stand-in for a lost node / preempted slice."""


# ---------------------------------------------------------------------------
# supervisor loop
# ---------------------------------------------------------------------------

def run_with_recovery(
    step_fn: Callable[[Any, int], Tuple[Any, Dict]],
    init_state: Any,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    fail_at: Optional[Dict[int, int]] = None,
    max_restarts: int = 8,
) -> Tuple[Any, Dict]:
    """Run ``state, metrics = step_fn(state, step)`` for ``n_steps`` with
    checkpoint/restart. ``fail_at``: {step: how_many_times} injected faults.

    The state pytree must be fully step-indexed (data position included) so
    recovery is bitwise-deterministic — asserted by tests/test_fault_tolerance.
    """
    fail_at = dict(fail_at or {})
    restarts = 0
    log: Dict[str, Any] = {"restarts": 0, "restored_from": []}

    start = ckpt.latest_step(ckpt_dir)
    if start is not None:
        _, init_state, _ = ckpt.restore(ckpt_dir, init_state)
        step = start + 1
    else:
        ckpt.save(ckpt_dir, -1, init_state)
        step = 0

    state = init_state
    while step < n_steps:
        try:
            if fail_at.get(step, 0) > 0:
                fail_at[step] -= 1
                raise SimulatedFailure(f"node lost at step {step}")
            state, _ = step_fn(state, step)
            if step % ckpt_every == ckpt_every - 1:
                ckpt.save(ckpt_dir, step, state)
            step += 1
        except SimulatedFailure:
            restarts += 1
            log["restarts"] = restarts
            if restarts > max_restarts:
                raise
            last = ckpt.latest_step(ckpt_dir)
            log["restored_from"].append(last)
            _, state, _ = ckpt.restore(ckpt_dir, state, step=last)
            step = last + 1
    return state, log
