from .compression import CompressionConfig, compress, decompress, ErrorFeedback  # noqa: F401
from .fault_tolerance import (HeartbeatMonitor, StragglerPolicy,  # noqa: F401
                              run_with_recovery, elastic_remesh)
