"""Gradient compression for the DP all-reduce path, with error feedback.

At 512+ chips the cross-pod gradient all-reduce is the collective-term
killer (EXPERIMENTS.md §Roofline shows it directly for train shapes). Two
standard compressors, both with **error feedback** (the residual of this
step's compression is added to next step's gradient, preserving
convergence):

* ``int8`` — per-256-chunk absmax scaling, 4× over f32 / 2× over bf16;
* ``topk`` — keep the top ``frac`` magnitudes per leaf (values + int32
  indices).

``ErrorFeedback.step`` wraps either around a pytree; the all-reduce itself
is whatever the caller uses (``jax.lax.psum`` under shard_map in tests,
pjit-inserted collectives in the launcher). Compression is applied
*pre*-reduce; tests verify end-to-end convergence on a quadratic and
boundedness of the residual.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "int8"          # "int8" | "topk" | "none"
    chunk: int = 256
    topk_frac: float = 0.05


class Compressed(NamedTuple):
    payload: Any
    meta: Any


def _int8_compress(g: jax.Array, chunk: int) -> Compressed:
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return Compressed((q, scale.astype(jnp.float32)), (g.shape, pad))


def _int8_decompress(c: Compressed) -> jax.Array:
    (q, scale), (shape, pad) = c.payload, c.meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def _topk_compress(g: jax.Array, frac: float) -> Compressed:
    flat = g.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return Compressed((vals, idx.astype(jnp.int32)), (g.shape, flat.size))


def _topk_decompress(c: Compressed) -> jax.Array:
    (vals, idx), (shape, size) = c.payload, c.meta
    return jnp.zeros((size,), jnp.float32).at[idx].set(vals).reshape(shape)


def compress(g: jax.Array, cfg: CompressionConfig) -> Compressed:
    if cfg.kind == "int8":
        return _int8_compress(g, cfg.chunk)
    if cfg.kind == "topk":
        return _topk_compress(g, cfg.topk_frac)
    return Compressed(g, None)


def decompress(c: Compressed, cfg: CompressionConfig) -> jax.Array:
    if cfg.kind == "int8":
        return _int8_decompress(c)
    if cfg.kind == "topk":
        return _topk_decompress(c)
    return c.payload


def compressed_bytes(c: Compressed, cfg: CompressionConfig) -> int:
    if cfg.kind == "int8":
        q, scale = c.payload
        return q.size + scale.size * 4
    if cfg.kind == "topk":
        vals, idx = c.payload
        return vals.size * 4 + idx.size * 4
    return c.payload.size * c.payload.dtype.itemsize


class ErrorFeedback(NamedTuple):
    """Per-leaf residual memory. g_eff = g + e; e' = g_eff - decomp(comp(g_eff))."""
    residual: Any

    @staticmethod
    def init(grads) -> "ErrorFeedback":
        return ErrorFeedback(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))

    def step(self, grads, cfg: CompressionConfig) -> Tuple[Any, "ErrorFeedback"]:
        """Returns (compressed-then-decompressed grads, new state)."""
        def one(g, e):
            geff = g.astype(jnp.float32) + e
            rec = decompress(compress(geff, cfg), cfg)
            return rec.astype(g.dtype), geff - rec

        out = jax.tree.map(one, grads, self.residual)
        rec = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return rec, ErrorFeedback(res)
