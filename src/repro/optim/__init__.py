from .optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule  # noqa: F401
from .sparse import SparseTrainState, gated_scale_tree, lm_dsst_event  # noqa: F401
