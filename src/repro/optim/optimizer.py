"""AdamW + schedules, pytree-native (no optax dependency offline).

Integer / boolean leaves (sparsity masks ``umask``, kept-row tables
``rows``) are structural, not trainable: they get no moments and no updates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def _trainable(leaf) -> bool:
    return jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    def zeros():
        # fresh buffers each time — m and v must NOT alias (buffer donation)
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32) if _trainable(p)
            else jnp.zeros((), jnp.int8), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree) if _trainable(l)]
    return jnp.sqrt(sum(leaves))


def adamw_update(grads, params, state: AdamWState, cfg: AdamWConfig,
                 update_scale=None) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step. ``update_scale``: optional tree of per-leaf scalars
    (the activity-dependent gate — 0.0 skips a layer's update, exactly the
    chip's gated WU applied to the optimizer)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cosine_schedule(cfg, state.step)
    t = (state.step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, p, m, v, s):
        if not _trainable(p):
            return p, m, v
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step_ = lr * (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        step_ = step_ + lr * cfg.weight_decay * p.astype(jnp.float32)
        if s is not None:
            step_ = step_ * s
        return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

    if update_scale is None:
        out = jax.tree.map(lambda g, p, m, v: upd(g, p, m, v, None),
                           grads, params, state.m, state.v)
    else:  # full tree of scalar gates (no Nones — None is a pytree node)
        out = jax.tree.map(upd, grads, params, state.m, state.v, update_scale)

    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(state.step + 1, new_m, new_v), metrics
