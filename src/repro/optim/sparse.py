"""ElfCore's training-time machinery applied to LM-scale models:

* ``build_update_scale`` — per-leaf optimizer update scales combining
  (a) the activity-dependent per-layer gate (IA/SS; the chip's gated WU
  applied to AdamW — a gated-off layer's whole update is skipped) and
  (b) re-masking of N:M-masked weights (the STE in models/layers gives
  dense grads for DSST scoring; updates must stay on active connections).
* ``lm_dsst_event`` — one connectivity prune/regrow pass over every masked
  matrix in a parameter tree (RigL oracle on the real dense grads; the
  factorized neuron-level path is validated equivalent in core/dsst).
* ``SparseTrainState`` — gating statistics carried across steps.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SparsityConfig
from repro.core import gating as gating_lib
from repro.core.dsst import prune_regrow
from repro.core.sparsity import NMSpec
from repro.core.topology import prune_regrow_stacked


class SparseTrainState(NamedTuple):
    gate: gating_lib.GatingState
    pooled_ema: jax.Array          # [L, D] per-layer pooled-output EMA (SS ref)

    @staticmethod
    def init(n_layers: int, d_model: int) -> "SparseTrainState":
        return SparseTrainState(gate=gating_lib.init_state(n_layers),
                                pooled_ema=jnp.zeros((n_layers, d_model), jnp.float32))


def compute_gates(state: SparseTrainState, ia: jax.Array, pooled: jax.Array,
                  cfg: gating_lib.GatingConfig, ema_rho: float = 0.05
                  ) -> Tuple[jax.Array, SparseTrainState]:
    """ia [L], pooled [L, D] from forward aux -> (gate [L] 0/1, new state)."""
    def _n(x):
        return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-6)
    ss = (_n(pooled) * _n(state.pooled_ema)).sum(-1)            # [L]
    open_, gate_st = gating_lib.gate_batch(state.gate, ia, ss, cfg)
    ema = (1 - ema_rho) * state.pooled_ema + ema_rho * pooled
    return open_, SparseTrainState(gate=gate_st, pooled_ema=ema)


# ---------------------------------------------------------------------------
# update-scale tree (gate × mask)
# ---------------------------------------------------------------------------

def gated_scale_tree(params, gate_vec: Optional[jax.Array],
                     sp: Optional[SparsityConfig]):
    """Tree matching ``params``: scalar/broadcast scales for adamw_update.

    Leaves under the stacked ``layers`` subtree get ``gate_vec[l]`` (their
    leading dim is L); masked ``w`` leaves additionally get the expanded
    mask so pruned entries receive zero update.
    """
    one = jnp.ones((), jnp.float32)

    def expand_mask(node):
        m = node["umask"]                                       # [..., KB, 1]
        block = node["w"].shape[-2] // m.shape[-2]
        return jnp.repeat(m, block, axis=-2).astype(jnp.float32)  # [..., K, 1]

    def rec(node, under_layers: bool):
        if isinstance(node, dict):
            has_mask = "umask" in node and "w" in node
            out = {}
            for k, v in node.items():
                if k == "w" and has_mask:
                    s = expand_mask(node)
                    if under_layers and gate_vec is not None:
                        s = s * _lgate(gate_vec, v.ndim)
                    out[k] = s
                else:
                    out[k] = rec(v, under_layers)
            return out
        # plain leaf
        if under_layers and gate_vec is not None:
            return _lgate(gate_vec, jnp.ndim(node))
        return one

    def _lgate(gv, ndim):
        return gv.reshape((-1,) + (1,) * (ndim - 1))

    scales = {}
    for key, sub in params.items():
        scales[key] = rec(sub, under_layers=(key in ("layers", "local_heads")))
    return scales


# ---------------------------------------------------------------------------
# DSST over a parameter tree
# ---------------------------------------------------------------------------

def _unit_score_shared(x: jax.Array, kb: int) -> jax.Array:
    """|x| summarised per mask unit for shared-pattern masks: [.., K, O] ->
    [.., KB, 1] (sum over block rows and all output columns)."""
    *lead, k, o = x.shape
    xg = jnp.abs(x).reshape(*lead, kb, k // kb, o)
    return xg.sum(axis=(-1, -2))[..., None]


def lm_dsst_event(params, grads, sp: SparsityConfig) -> Tuple[Any, Dict[str, jax.Array]]:
    """Prune/regrow every masked matrix; returns (new params, stats)."""
    spec1 = NMSpec(n=sp.n, m=sp.m)      # unit-granular view ([KB, 1] masks)
    k_re = max(0, min(sp.n - 1, int(round(sp.n * 0.3))))
    flips_total = [jnp.zeros(())]

    def one(w, umask, gw):
        kb = umask.shape[-2]
        wsc = _unit_score_shared(w, kb)
        gsc = _unit_score_shared(gw, kb)

        def ev(um, ws, gs):
            nm, st = prune_regrow(um, ws, gs, spec1, k_re)
            return nm, st.mask_change

        if w.ndim > 2:   # stacked [L, ...] or experts [L, E, ...]
            # one topology-stacked event over the flattened leading dims —
            # the same vmapped prune/regrow the SNN epoch runs
            um2 = umask.reshape((-1,) + umask.shape[-2:])
            ws2 = wsc.reshape((-1,) + wsc.shape[-2:])
            gs2 = gsc.reshape((-1,) + gsc.shape[-2:])
            nm2, st = prune_regrow_stacked(um2, ws2, gs2, spec1, k_re)
            new_umask = nm2.reshape(umask.shape)
            flip = st.mask_change.mean()
        else:
            new_umask, flip = ev(umask, wsc, gsc)
        flips_total[0] = flips_total[0] + flip
        # survivors keep weights; regrown restart at 0 (apply via mask product)
        surv = (umask & new_umask)
        block = w.shape[-2] // kb
        survf = jnp.repeat(surv, block, axis=-2).astype(w.dtype)
        return w * survf, new_umask

    def rec(node):
        if isinstance(node, dict):
            if "umask" in node and "w" in node:
                gw = grads_by_id[id(node)]
                w, um = one(node["w"], node["umask"], gw)
                return {**node, "w": w, "umask": um}
            return {k: rec(v) for k, v in node.items()}
        return node

    # pair each masked node with its grad (walk both trees in lockstep)
    grads_by_id: Dict[int, jax.Array] = {}

    def pair(pn, gn):
        if isinstance(pn, dict):
            if "umask" in pn and "w" in pn:
                grads_by_id[id(pn)] = gn["w"]
            else:
                for k in pn:
                    pair(pn[k], gn[k])

    pair(params, grads)
    new_params = rec(params)
    return new_params, {"dsst_mask_change": flips_total[0]}
