"""Dynamic Structured Sparse Training (DSST) — ElfCore §II-C/D.

Sparse-to-sparse training: the network *starts* at uniform N:M sparsity and,
every ``period`` weight-update cycles, **prunes the k smallest-magnitude
active connections and regrows k inactive connections with the largest
gradient magnitude**, executed per N:M group so the exactly-N-per-group
invariant is preserved (and with it the compact SRAM layout).

Two regrow scorers:

* :func:`prune_regrow` — dense-oracle: any dense [K, O] gradient-magnitude
  score (what RigL [13] does, and our correctness reference).

* :func:`prune_regrow_factored` — the paper's contribution: for masked
  (never-materialised) weights the gradient of ``y = x @ w`` factors as
  ``g_ij = pre_i * post_j``. Within one N:M group of one output neuron the
  ``post_j`` factor is constant, so the regrow *ranking* along the group is
  the ranking of ``|pre_i|`` — computed **once per group, reused across every
  output neuron** ("reduces sorting complexity from the synapse to the neuron
  level", Fig. 5). We implement exactly that reuse: one sort of ``|pre|`` per
  group, then a gather per output column.

Both keep O(1) extra state (the chip's heap property) — JAX's ``top_k`` is
the XLA analogue of the five parallel sorting blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .sparsity import NMSpec


@dataclasses.dataclass(frozen=True)
class DSSTConfig:
    period: int = 100          # WU cycles between connectivity updates
    prune_frac: float = 0.3    # fraction of each group's n connections recycled
    start_step: int = 0        # no connectivity updates before this
    stop_step: int = 10**9     # freeze connectivity after this (RigL-style cool-down)
    frac_decay: float = 1.0    # multiplicative decay of prune_frac per event

    def k_for_event(self, spec: NMSpec, event: int) -> int:
        """Static number of connections recycled per group at the ``event``-th
        connectivity update (``frac_decay`` applied per event)."""
        frac = self.prune_frac * (self.frac_decay ** max(0, event))
        k = int(round(spec.n * frac))
        return max(0, min(k, spec.n - 1))

    def k_per_group(self, spec: NMSpec, step: int = 0) -> int:
        """Static (trace-safe) number of connections recycled per group at
        ``step``. ``step`` must be a host int — for a traced step use
        :func:`scheduled_k_apply`, which dispatches over :meth:`k_levels`."""
        events = max(0, int(step) - self.start_step) // max(1, self.period)
        return self.k_for_event(spec, events)

    def k_levels(self, spec: NMSpec, max_events: int = 100_000
                 ) -> Tuple[Tuple[int, int], ...]:
        """The decay schedule as static ``(first_event, k)`` levels.

        ``frac_decay`` makes k(event) monotone, so the whole schedule
        collapses to at most ``spec.n`` distinct levels — small enough for a
        trace-safe ``lax.switch`` (``top_k`` needs a static k; a traced step
        therefore selects a *branch*, not a size).
        """
        levels = [(0, self.k_for_event(spec, 0))]
        if self.frac_decay == 1.0:
            return tuple(levels)
        for e in range(1, max_events):
            k = self.k_for_event(spec, e)
            if k != levels[-1][1]:
                levels.append((e, k))
            if k == 0 or (self.frac_decay > 1.0 and k >= spec.n - 1):
                break
        return tuple(levels)

    def is_update_step(self, step) -> jax.Array:
        step = jnp.asarray(step)
        return ((step >= self.start_step)
                & (step < self.stop_step)
                & (step % self.period == self.period - 1))


class DSSTStats(NamedTuple):
    """Telemetry for EXPERIMENTS.md / energy model."""
    pruned: jax.Array      # connections recycled this event
    regrown: jax.Array
    mask_change: jax.Array  # fraction of units whose state flipped


def _grouped(x: jax.Array, spec: NMSpec) -> jax.Array:
    kb, j = x.shape
    return x.reshape(kb // spec.m, spec.m, j)


def prune_regrow(
    unit_mask: jax.Array,          # bool [KB, J]
    weight_score: jax.Array,       # [KB, J]  |w| summarised to units (prune key)
    grad_score: jax.Array,         # [KB, J]  |g| summarised to units (regrow key)
    spec: NMSpec,
    k: int,
) -> tuple[jax.Array, DSSTStats]:
    """One DSST event with a dense regrow oracle. Keeps exactly n per group.

    Prune: among the n active units of each (group, out-tile), drop the ``k``
    with smallest weight_score. Regrow: among the m-n inactive units, add the
    ``k`` with largest grad_score. Active/inactive sets are disjoint so the
    invariant is structural, not checked at runtime.
    """
    if k == 0:
        z = jnp.zeros((), jnp.int32)
        return unit_mask, DSSTStats(z, z, jnp.zeros(()))
    if k >= spec.n:
        raise ValueError(f"k={k} must be < n={spec.n}")
    gm_mask = _grouped(unit_mask, spec)
    gm_w = _grouped(weight_score, spec)
    gm_g = _grouped(grad_score, spec)

    neg_inf = jnp.asarray(-jnp.inf, gm_w.dtype)
    # survivors: top (n-k) of active by weight score
    keep_key = jnp.where(gm_mask, gm_w, neg_inf)
    _, keep_idx = jax.lax.top_k(jnp.moveaxis(keep_key, 1, -1), spec.n - k)
    # regrown: top k of inactive by grad score
    grow_key = jnp.where(gm_mask, neg_inf, gm_g)
    _, grow_idx = jax.lax.top_k(jnp.moveaxis(grow_key, 1, -1), k)

    new_idx = jnp.concatenate([keep_idx, grow_idx], axis=-1)       # [G, J, n]
    onehot = jax.nn.one_hot(new_idx, spec.m, dtype=jnp.bool_)      # [G, J, n, m]
    new_gm = jnp.moveaxis(onehot.any(axis=2), -1, 1)               # [G, m, J]
    new_mask = new_gm.reshape(unit_mask.shape)

    flips = (new_mask != unit_mask).sum()
    stats = DSSTStats(
        pruned=(unit_mask & ~new_mask).sum().astype(jnp.int32),
        regrown=(~unit_mask & new_mask).sum().astype(jnp.int32),
        mask_change=flips / unit_mask.size,
    )
    return new_mask, stats


# ---------------------------------------------------------------------------
# the paper's factorized (neuron-level) regrow sorting
# ---------------------------------------------------------------------------

def factored_group_order(pre_score: jax.Array, spec: NMSpec) -> jax.Array:
    """Rank units inside each group by |pre| once — shared by all out columns.

    ``pre_score``: [KB] per-unit input-activity magnitude (the pre-synaptic
    gradient factor). Returns int32 [G, m] with units in descending score
    order. This is the "post-gradient sorting reused across presynaptic
    neurons" step: ONE sort per group instead of one per (group x output).
    """
    g = pre_score.shape[0] // spec.m
    grouped = pre_score.reshape(g, spec.m)
    return jnp.argsort(-grouped, axis=1, stable=True).astype(jnp.int32)


def prune_regrow_factored(
    unit_mask: jax.Array,          # bool [KB, J]
    weight_score: jax.Array,       # [KB, J]
    pre_score: jax.Array,          # [KB]   pre-synaptic factor |a_i|
    post_score: jax.Array,         # [J]    post-synaptic factor |g_j| (>=0)
    spec: NMSpec,
    k: int,
) -> tuple[jax.Array, DSSTStats]:
    """DSST event using the factorized gradient ``|g_ij| = |pre_i|·|post_j|``.

    Since ``|post_j|`` is constant along a group, the dense regrow choice
    reduces to "first k inactive units in the shared per-group |pre| order".
    Equivalent to :func:`prune_regrow` with ``grad_score = outer(pre, post)``
    whenever ``post_score > 0`` (ties measure-zero) — tested property.
    """
    del post_score  # rank-1 ⇒ column factor does not change within-group order
    order = factored_group_order(pre_score, spec)                   # [G, m]
    g, m = order.shape
    j = unit_mask.shape[1]
    # rank position of each unit inside its group (0 = largest |pre|)
    rank = jnp.zeros_like(order).at[jnp.arange(g)[:, None], order].set(
        jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (g, m)))
    # regrow score: shared, higher for smaller rank; -inf on active units.
    shared = (m - rank).astype(weight_score.dtype)                  # [G, m]
    grad_score = jnp.broadcast_to(shared.reshape(g * m, 1), (g * m, j))
    return prune_regrow(unit_mask, weight_score, grad_score, spec, k)


# ---------------------------------------------------------------------------
# gradient-statistics accumulator (what the chip writes back for DSST sorting)
# ---------------------------------------------------------------------------

class DSSTAccumulator(NamedTuple):
    """Running |pre| / |post| factors between connectivity updates.

    The chip "writes post-gradients back for DSST sorting"; we accumulate the
    factor magnitudes with a decaying sum so one buffer per layer suffices
    (O(K + O) instead of O(K·O) — the whole point of the factorization).
    """
    pre: jax.Array    # [KB]
    post: jax.Array   # [J]

    @staticmethod
    def init(kb: int, j: int, dtype=jnp.float32) -> "DSSTAccumulator":
        return DSSTAccumulator(jnp.zeros((kb,), dtype), jnp.zeros((j,), dtype))

    def update(self, pre_mag: jax.Array, post_mag: jax.Array, decay: float = 0.9):
        return DSSTAccumulator(self.pre * decay + pre_mag,
                               self.post * decay + post_mag)


def dense_grad_unit_score(grad: jax.Array, spec: NMSpec) -> jax.Array:
    """|grad| summarised to unit granularity — the RigL oracle key."""
    from .sparsity import unit_scores
    return unit_scores(grad, spec, *grad.shape, reduce="abs_sum")


def apply_dsst_to_weights(
    w: jax.Array, old_mask: jax.Array, new_mask: jax.Array, spec: NMSpec
) -> jax.Array:
    """Zero regrown connections (they restart from 0, as on-chip) and keep
    surviving values; pruned values are dropped from compact storage."""
    from .sparsity import expand_unit_mask
    k, o = w.shape
    survived = expand_unit_mask(old_mask & new_mask, spec, k, o)
    return w * survived.astype(w.dtype)


def scheduled_k_apply(step: Union[int, jax.Array], cfg: DSSTConfig,
                      spec: NMSpec, fn: Callable[[int], object]):
    """Run ``fn(k)`` with ``k`` drawn from ``cfg``'s decay schedule at
    ``step``, trace-safely.

    ``k`` is a *shape* parameter of ``top_k``, so it must be static.  A host
    int resolves it directly; a traced step selects among the static
    :meth:`DSSTConfig.k_levels` with ``lax.switch`` — every branch is traced
    with its own static k and the traced event index picks one at runtime,
    which is how ``frac_decay``/``start_step`` finally reach the jitted
    train step (the old code pinned k to the step-0 value forever).
    """
    if isinstance(step, (int, np.integer)):
        return fn(cfg.k_per_group(spec, int(step)))
    levels = cfg.k_levels(spec)
    if len(levels) == 1:
        return fn(levels[0][1])
    event = jnp.maximum(0, jnp.asarray(step) - cfg.start_step) \
        // max(1, cfg.period)
    idx = (event >= jnp.asarray([e for e, _ in levels[1:]])).sum()
    return jax.lax.switch(idx, [lambda _, k=k: fn(k) for _, k in levels],
                          None)


def maybe_dsst(
    step,
    cfg: DSSTConfig,
    spec: NMSpec,
    w: jax.Array,
    unit_mask: jax.Array,
    acc: DSSTAccumulator,
):
    """jit-safe conditional DSST event (identity off-cycle).

    Returns (w, unit_mask, fresh_acc, did_update). The recycled-connection
    count follows ``cfg``'s schedule (``frac_decay``/``start_step``) even
    under a traced ``step`` — see :func:`scheduled_k_apply`.
    """
    from .sparsity import unit_scores

    def do(_):
        wscore = unit_scores(w, spec, *w.shape, reduce="abs_sum")
        new_mask, _ = scheduled_k_apply(
            step, cfg, spec,
            lambda k: prune_regrow_factored(unit_mask, wscore, acc.pre,
                                            acc.post, spec, k))
        new_w = apply_dsst_to_weights(w, unit_mask, new_mask, spec)
        return new_w, new_mask, DSSTAccumulator.init(acc.pre.shape[0], acc.post.shape[0],
                                                     acc.pre.dtype), jnp.array(True)

    def skip(_):
        return w, unit_mask, acc, jnp.array(False)

    return jax.lax.cond(cfg.is_update_step(step), do, skip, operand=None)
