"""Activity-dependent weight-update gating — ElfCore's third contribution.

A layer's weight update fires only when

* **IA** (input activity — mean presynaptic spike rate this TS) exceeds a
  *global* threshold: silent inputs carry nothing to learn, and updating on
  them just integrates noise; and
* **SS** (similarity score from the neuron dynamics — cosine between the
  current trace and the stored previous-sample trace) is below an *adaptive
  layer-specific* threshold: a trace (nearly) identical to what the layer
  already produced means either a same-class repeat (contrastive target
  invalid) or nothing new — skip, saving the full WU energy.

The SS threshold adapts per layer as a running mean of observed SS, so gating
self-calibrates on streaming data — no external scheduler, unlike
accuracy-driven time-window tuning [2] or time-step skipping [4].

The same machinery gates per-layer *optimizer* updates for the LM-family
archs (optim/sparse.py) — IA = mean |block input|, SS = cosine of pooled
block output vs its EMA.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GatingConfig:
    enabled: bool = True
    theta_ia: float = 0.005    # global input-activity threshold (spike rate)
    ss_rho: float = 0.05       # adaptation rate of the per-layer SS threshold
    ss_scale: float = 1.0      # threshold = ss_scale * running-mean SS:
    #   an input whose similarity exceeds the layer's *typical* similarity
    #   carries nothing new -> skip. With scale 1.0 the threshold rides the
    #   running mean itself, so the gate self-calibrates to the fluctuation
    #   band of SS whatever its absolute scale (0.1 for SNN traces across
    #   samples, 0.9999 for slowly-moving LM pooled features).
    ss_init: float = 1.0       # running-mean starts pessimistic: gate open early


class GatingState(NamedTuple):
    ss_mean: jax.Array   # [L] running mean of SS per layer
    opened: jax.Array    # [L] count of fired gates   (telemetry)
    offered: jax.Array   # [L] count of gate decisions (telemetry)


def init_state(n_layers: int, cfg: GatingConfig | None = None) -> GatingState:
    init = (cfg or GatingConfig()).ss_init
    return GatingState(
        ss_mean=jnp.full((n_layers,), init),
        opened=jnp.zeros((n_layers,)),
        offered=jnp.zeros((n_layers,)),
    )


class LayerGate(NamedTuple):
    ss_mean: jax.Array
    opened: jax.Array
    offered: jax.Array


def gate_decide(ss_mean: jax.Array, ia: jax.Array, ss: jax.Array,
                cfg: GatingConfig):
    """THE gate formula — shared by the timestep engine (train + serve), the
    scalar/batch helpers below, and the LM optimizer path.

    Broadcasts over any common shape of (ss_mean, ia, ss): scalars for one
    training layer, ``[S]`` for per-stream serving slots, ``[L]`` for the
    LM per-layer batch. Returns (open?, new running-mean SS threshold); the
    running mean always adapts, whether or not the gate fired.
    """
    thr = cfg.ss_scale * ss_mean
    open_ = (ia > cfg.theta_ia) & (ss < thr)
    if not cfg.enabled:
        open_ = jnp.ones_like(open_, bool)
    new_mean = (1 - cfg.ss_rho) * ss_mean + cfg.ss_rho * jnp.abs(ss)
    return open_, new_mean


def gate_update(state: GatingState, layer: int, ia: jax.Array, ss: jax.Array,
                cfg: GatingConfig):
    """One gate decision for ``layer``. Returns (open?, per-layer new state)."""
    open_, new_mean = gate_decide(state.ss_mean[layer], ia, ss, cfg)
    return open_, LayerGate(new_mean,
                            state.opened[layer] + open_.astype(jnp.float32),
                            state.offered[layer] + 1.0)


def merge(state: GatingState, layer_gates: Sequence[LayerGate]) -> GatingState:
    return GatingState(
        ss_mean=jnp.stack([g.ss_mean for g in layer_gates]),
        opened=jnp.stack([g.opened for g in layer_gates]),
        offered=jnp.stack([g.offered for g in layer_gates]),
    )


def gate_batch(state: GatingState, ia: jax.Array, ss: jax.Array,
               cfg: GatingConfig):
    """Vectorised per-layer gate decision (LM training path).

    ``ia``, ``ss``: [L]. Returns (open [L] float 0/1, new state)."""
    open_, new_mean = gate_decide(state.ss_mean, ia, ss, cfg)
    new = GatingState(
        ss_mean=new_mean,
        opened=state.opened + open_.astype(jnp.float32),
        offered=state.offered + 1.0,
    )
    return open_.astype(jnp.float32), new


def skip_rate(state: GatingState) -> jax.Array:
    """Fraction of offered WUs that were skipped (→ power saved)."""
    return 1.0 - state.opened.sum() / jnp.maximum(state.offered.sum(), 1.0)
