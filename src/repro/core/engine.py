"""One layer-stacked timestep engine shared by training and serving.

ElfCore's central architectural claim is that spike integration (SI) and the
weight update (WU) run *concurrently through the same datapath* for every
layer.  This module is that datapath, exactly once: :func:`_layer_timestep`
is the only per-timestep layer body in ``src/repro/core`` — both
``snn.run_sample`` (training: aligned batch, in-place base weights, one gate
decision per layer shared across the batch) and ``snn.run_chunk`` (serving:
slot axis, frozen base + per-stream deltas, per-slot gates, valid masking)
are thin wrappers over the scans built here.

Two structural decisions:

* **Layer stacking.**  Per-layer parameters and state live in pytrees with a
  leading ``[L, ...]`` layer axis (zero-padded on the fan-in dimension when
  layer fan-ins differ) and the depth loop is a ``lax.scan`` over that axis.
  Trace size and compile time no longer multiply with depth — the Fig. 7
  depth study and the ROADMAP's sharded-slot-grid work both need this.

* **Backend seam.**  ``SNNConfig.backend`` selects how the three inner ops
  (forward current, fused LIF step, WU outer product) are computed:

  - ``"ref"``             — pure jnp on dense masked weights (default);
  - ``"pallas"``          — route through ``kernels/nm_spmm``, ``kernels/lif``
                            and ``kernels/wu_outer``; real Pallas kernels on
                            TPU, their jnp oracles elsewhere.  The compact
                            N:M layout (values + block indices) is built from
                            the mask at scan entry and carried *alongside*
                            the mask through the time scan — training updates
                            land directly in compact storage via
                            ``wu_outer`` and are densified once per sample;
  - ``"pallas-interpret"`` — same routing with ``interpret=True`` everywhere,
                            the CPU-CI correctness mode for kernel parity.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import gating as gating_lib
from . import topology as topology_lib

BACKENDS = ("ref", "pallas", "pallas-interpret")


# ---------------------------------------------------------------------------
# neuron math — the single source of truth (re-exported by core.snn)
# ---------------------------------------------------------------------------

def lif_step(v, tr, current, *, alpha, beta, theta):
    """One LIF timestep with soft reset + trace decay. Returns (v', tr', s)."""
    v = alpha * v + current
    s = (v >= theta).astype(v.dtype)
    v = v - s * theta
    tr = beta * tr + s
    return v, tr, s


def surrogate_grad(v, *, theta, width):
    """Triangular STE (the chip's STE LUT for the non-derivative spike fn)."""
    return jnp.maximum(0.0, 1.0 - jnp.abs(v - theta) / (theta * width))


def _cos(a, b, eps=1e-6):
    num = (a * b).sum(-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + eps
    return num / den


def _cos_grad(a, b, eps=1e-6):
    """d cos(a,b) / d a."""
    na = jnp.linalg.norm(a, axis=-1, keepdims=True) + eps
    nb = jnp.linalg.norm(b, axis=-1, keepdims=True) + eps
    c = ((a * b).sum(-1, keepdims=True)) / (na * nb)
    return b / (na * nb) - c * a / (na * na)


def ossl_modulator(tr, tr_pc, tr_cc, v, cfg):
    """Third factor of the three-factor rule, from purely local quantities.

    Local loss  L = -cos(tr, tr_pc) + cc_weight * cos(tr, tr_cc):
    *predict* (stay similar to) the earlier-TS trace of the same sample,
    *contrast* against the previous sample's final trace. The modulator is
    -dL/dtr shaped through the spike-function surrogate. PC and CC run
    concurrently (no class-transition flag) — ElfCore §II-C.
    """
    g = _cos_grad(tr, tr_pc) - cfg.cc_weight * _cos_grad(tr, tr_cc)
    return g * surrogate_grad(v, theta=cfg.theta, width=cfg.surrogate_width)


# ---------------------------------------------------------------------------
# stacked state / geometry
# ---------------------------------------------------------------------------

class LayerState(NamedTuple):
    """Three-trace neuron SRAM + membrane; leaves are stacked ``[L, R, N]``
    (``R`` = batch rows in training, slots in serving) inside the engine,
    or a per-layer ``[R, N]`` slice inside the layer scan."""
    v: jax.Array        # membrane
    tr: jax.Array       # current trace (WU slot)
    tr_pc: jax.Array    # earlier-TS snapshot (PC slot)
    tr_cc: jax.Array    # final trace of the previous sample (CC slot)


class Geometry(NamedTuple):
    fanins: Tuple[int, ...]
    k_max: int
    uniform: bool       # all layers share fan-in and spec


def geometry(cfg) -> Geometry:
    """Static layer-stack geometry: per-layer fan-ins, the zero-padded
    stack width ``k_max = max(fanins)``, and whether all layers share one
    fan-in (which unlocks the vmapped/kernel fast paths)."""
    fanins = tuple(cfg.layer_fanins)
    k_max = max(fanins)
    uniform = len(set(fanins)) == 1
    return Geometry(fanins=fanins, k_max=k_max, uniform=uniform)


# one shared zero-padding helper with the rest of the topology layout code
_pad_rows = topology_lib._pad_rows


def _pad_cols(x, k):
    if x.shape[-1] == k:
        return x
    return jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, k - x.shape[-1]),))


def dense_masks(mask_stacked: jax.Array, cfg) -> jax.Array:
    """Stacked unit masks ``[L, KBmax, J]`` -> dense float ``[L, Kmax, N]``
    (zero rows where a layer's fan-in is below the stack width).

    The expansion itself lives with the rest of the topology lifecycle in
    ``core/topology.py``; this is the engine-facing alias.
    """
    return topology_lib.dense_masks(mask_stacked, cfg, dtype=jnp.float32)


def hidden_slice(params, l: int, cfg) -> Tuple[jax.Array, jax.Array]:
    """Layer ``l``'s (w ``[fan_in, N]``, unit_mask ``[KB, J]``) view of the
    stacked params — what tests and DSST inspect per layer."""
    fan_in = cfg.layer_fanins[l]
    spec = cfg.spec(fan_in)
    kb, jj = spec.unit_counts(fan_in, cfg.n_hidden)
    return (params["hidden"]["w"][l, :fan_in, :],
            params["hidden"]["mask"][l, :kb, :jj])


def stack_params(legacy, cfg):
    """PR-1 layout (lists of per-layer dicts) -> stacked layout.

    Checkpoint migration helper: old manifests keyed ``hidden/0/w`` etc.;
    restore into the legacy template, then stack.
    """
    geo = geometry(cfg)
    w = jnp.stack([_pad_rows(p["w"], geo.k_max) for p in legacy["hidden"]])
    mask = jnp.stack([_pad_rows(p["mask"], geo.k_max)
                      for p in legacy["hidden"]])
    return {"hidden": {"w": w, "mask": mask},
            "readout": jnp.stack(list(legacy["readout"]))}


def unstack_params(params, cfg):
    """Stacked layout -> PR-1 layout (for legacy consumers/tests)."""
    hidden = []
    for l in range(cfg.n_layers):
        w, m = hidden_slice(params, l, cfg)
        hidden.append({"w": w, "mask": m})
    return {"hidden": hidden,
            "readout": [params["readout"][l] for l in range(cfg.n_layers)]}


# ---------------------------------------------------------------------------
# backend seam
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    use_kernels: bool     # route through kernels/{nm_spmm,lif,wu_outer}
    force_pallas: bool
    interpret: bool


def make_backend(cfg) -> Backend:
    """Resolve ``cfg.backend`` ("ref" | "pallas" | "pallas-interpret") to
    the engine's static :class:`Backend` dispatch record."""
    name = getattr(cfg, "backend", "ref")
    if name == "ref":
        return Backend("ref", False, False, False)
    if name == "pallas":
        return Backend("pallas", True, False, False)
    if name == "pallas-interpret":
        return Backend("pallas-interpret", True, True, True)
    raise ValueError(f"unknown backend {name!r}; expected one of {BACKENDS}")


def prepare_weights(w_stacked, mask_stacked, cfg, backend: Backend, *,
                    include_mask: bool = False):
    """Weight representation carried through the time scan.

    The rep is a dict whose *keys* drive dispatch downstream
    (``"wc" in w_l`` → compact): ``ref`` carries the dense stacked weights
    plus the dense float mask (``{"w", "mask_f"}`` — the mask is part of the
    weight rep, not a separate scan input); kernel backends carry the
    compact N:M layout (values ``[L, J, T, bk, bo]`` + block ids
    ``[L, J, T]``), the chip's value/index SRAM pair, with the dense mask
    added only when ``include_mask`` (dense-delta serving still scatters
    its WU through it).
    """
    if not backend.use_kernels:
        return {"w": w_stacked, "mask_f": dense_masks(mask_stacked, cfg)}
    wrep = compact_weights(w_stacked, mask_stacked, cfg)
    if include_mask:
        wrep["mask_f"] = dense_masks(mask_stacked, cfg)
    return wrep


def compact_weights(w_stacked, mask_stacked, cfg):
    """Stacked dense weights + unit masks -> ``{"wc", "idx"}`` compact rep.

    The mask-free serving weight rep: values ``[L, J, T, bk, bo]`` and kept
    block ids ``[L, J, T]``. Requires uniform layer fan-in (the stacked
    ``idx`` shares one geometry across layers).
    """
    geo = geometry(cfg)
    if not geo.uniform:
        raise ValueError(
            "the compact N:M layout requires uniform layer fan-in "
            f"(got {geo.fanins}); use the dense rep / dense deltas instead")
    from repro.kernels.nm_spmm import ops as nm_ops
    spec = cfg.spec(geo.fanins[0])
    wcs, idxs = [], []
    for l in range(cfg.n_layers):
        wc, idx = nm_ops.make_compact(
            w_stacked[l], mask_stacked[l], spec.block, spec.out_tile,
            n_kept=compact_kept(cfg))
        wcs.append(wc)
        idxs.append(idx)
    return {"wc": jnp.stack(wcs), "idx": jnp.stack(idxs)}


def compact_deltas(deltas, idx, cfg):
    """Dense slot-leading deltas ``[S, L, Kmax, N]`` -> compact
    ``[S, L, J, T, bk, bo]`` by gathering the kept blocks of ``idx``
    (``[L, J, T]``). Pure gather — bitwise for every kept coordinate."""
    spec = cfg.spec(cfg.layer_fanins[0])
    bk, bo = spec.block, spec.out_tile
    s, l_, k, n = deltas.shape
    db = deltas.reshape(s, l_, k // bk, bk, n // bo, bo)
    db = db.transpose(0, 1, 4, 2, 3, 5)            # [S, L, J, KB, bk, bo]
    return jnp.take_along_axis(db, idx[None, :, :, :, None, None], axis=3)


def densify_deltas(deltas_c, idx, cfg):
    """Compact slot-leading deltas ``[S, L, J, T, bk, bo]`` -> dense
    ``[S, L, Kmax, N]`` (zeros at pruned coordinates). Pure scatter into
    disjoint block rows — bitwise for every kept coordinate."""
    geo = geometry(cfg)
    s, l_, j, t, bk, bo = deltas_c.shape
    kb = geo.k_max // bk
    li = jnp.arange(l_)[:, None, None]
    ji = jnp.arange(j)[None, :, None]
    db = jnp.zeros((s, l_, j, kb, bk, bo), deltas_c.dtype)
    db = db.at[:, li, ji, idx].add(deltas_c)       # disjoint ids: exact set
    return db.transpose(0, 1, 3, 4, 2, 5).reshape(s, l_, geo.k_max, j * bo)


def compact_kept(cfg) -> int:
    """Static kept-block count per out tile (trace-safe, from the spec)."""
    spec = cfg.spec(cfg.layer_fanins[0])
    kb, _ = spec.unit_counts(cfg.layer_fanins[0], cfg.n_hidden)
    return (kb // spec.m) * spec.n


def finalize_weights(wrep, cfg, backend: Backend) -> jax.Array:
    """Back to dense stacked ``[L, Kmax, N]`` after the time scan."""
    if not backend.use_kernels:
        return wrep["w"]
    from repro.kernels.nm_spmm import ref as nm_ref
    geo = geometry(cfg)
    return jnp.stack([nm_ref.densify(wrep["wc"][l], wrep["idx"][l], geo.k_max)
                      for l in range(cfg.n_layers)])


def fwd_current(backend: Backend, pre, w_l, delta_l):
    """Forward synaptic current for one layer: ``pre @ w`` (+ slot deltas).

    Dispatch is on the weight rep's keys: a compact rep (``"wc"``) routes
    through ``nm_spmm`` regardless of backend (the jnp reference off-TPU),
    and compact per-slot deltas (rank 5: ``[S, J, T, bk, bo]``) contract
    through ``nm_spmm_deltas`` on the same kept-block ids — no dense
    ``[K, N]`` tensor exists anywhere on this path.
    """
    if "wc" in w_l:
        from repro.kernels.nm_spmm import ops as nm_ops
        cur = nm_ops.nm_spmm_batched(pre, w_l["wc"], w_l["idx"],
                                     interpret=backend.interpret,
                                     force_pallas=backend.force_pallas)
        if delta_l is not None:
            if delta_l.ndim == 5:
                cur = cur + nm_ops.nm_spmm_deltas(pre, delta_l, w_l["idx"])
            else:
                cur = cur + jnp.einsum("sk,skn->sn", pre, delta_l)
        return cur
    cur = pre @ w_l["w"]
    if delta_l is not None:
        cur = cur + jnp.einsum("sk,skn->sn", pre, delta_l)
    return cur


def lif(backend: Backend, cfg, v, tr, current):
    """One fused LIF step (``lif_step`` semantics) through the backend
    seam; ``v``/``tr``/``current`` are ``[R, N]``. Returns (v', tr', s)."""
    if backend.use_kernels:
        from repro.kernels.lif import ops as lif_ops
        return lif_ops.lif_step(v, tr, current, alpha=cfg.alpha,
                                beta=cfg.beta, theta=cfg.theta,
                                interpret=backend.interpret,
                                force_pallas=backend.force_pallas)
    return lif_step(v, tr, current, alpha=cfg.alpha, beta=cfg.beta,
                    theta=cfg.theta)


def train_wu(backend: Backend, cfg, w_l, pre_trace, mod, scale):
    """Gated three-factor WU into the base weights (training path).

    The sparsity pattern comes from the weight rep itself: kept block ids
    for the compact rep, the dense float mask (``w_l["mask_f"]``) for ref.
    """
    if "wc" in w_l:
        from repro.kernels.wu_outer import ops as wu_ops
        spec = cfg.spec(cfg.layer_fanins[0])
        dwc = wu_ops.wu_outer(pre_trace, mod, w_l["idx"], scale,
                              bk=spec.block, bo=spec.out_tile,
                              interpret=backend.interpret,
                              force_pallas=backend.force_pallas)
        return {**w_l, "wc": w_l["wc"] + dwc}
    dw = scale * (pre_trace.T @ mod)
    return {**w_l, "w": w_l["w"] + dw * w_l["mask_f"]}


# ---------------------------------------------------------------------------
# THE per-timestep layer body (exists exactly once)
# ---------------------------------------------------------------------------

class LayerSlice(NamedTuple):
    """Per-layer xs of the layer scan (leading ``[L]`` axis before slicing).

    There is no dense-mask field: the sparsity pattern lives inside the
    weight rep ``w`` (kept block ids for compact, ``mask_f`` for dense), so
    a compact serving trace never holds a dense mask at all.
    """
    w: Any                                # weight rep (see prepare_weights)
    readout: jax.Array                    # [N, n_out] bypass readout
    st: LayerState                        # leaves [R, N]
    ss_mean: jax.Array                    # [] (train) or [S] (serve)
    gate_opened: Optional[jax.Array]      # [] train telemetry; None serving
    gate_offered: Optional[jax.Array]
    delta: Optional[jax.Array]            # serving: [S, J, T, bk, bo] compact
    #   or [S, Kmax, N] dense; None in training
    fanin: jax.Array                      # [] f32 — true fan-in (pre padding)
    density: jax.Array                    # [] f32 — spec density


class LayerCarry(NamedTuple):
    """Flows down the layer stack within one timestep."""
    pre_spikes: jax.Array                 # [R, Kmax]
    pre_trace: jax.Array                  # [R, Kmax]
    logits: jax.Array                     # [R, n_out] bypass accumulator
    sop_fwd: jax.Array                    # [R]
    sop_wu: jax.Array                     # [R]
    sop_wu_off: jax.Array                 # [R]
    loss: jax.Array                       # [R]


class LayerOut(NamedTuple):
    st: LayerState
    w: Any
    delta: Optional[jax.Array]
    ss_mean: jax.Array
    gate_opened: Optional[jax.Array]
    gate_offered: Optional[jax.Array]
    open_: jax.Array                      # gate decision ([] or [S])
    pre_mag: Optional[jax.Array]          # [S, Kmax] |pre trace|, valid-masked
    #   (serving only; the DSST pre factor the topology service accumulates)
    post_mag: Optional[jax.Array]         # [S, N] |OSSL modulator|, valid-masked


def _layer_timestep(cfg, backend: Backend, geo: Geometry, learn: bool,
                    serving: bool, factors: bool, t_pc: int, t_wu: int,
                    t_row, valid, carry: LayerCarry, xs: LayerSlice
                    ) -> Tuple[LayerCarry, LayerOut]:
    """SI + gated WU for ONE layer at ONE timestep — training and serving.

    Training is the ``delta=None`` / ``valid=None`` special case: the gate
    decision is shared across the batch (IA/SS reduced over rows), the
    update lands in the base weights with the batch-mean scale ``lr/R``, and
    ``t_row`` is the sample-global timestep broadcast to every row. Serving
    keeps every quantity per-slot and masks invalid slots to exact no-ops.

    ``factors`` (serving only) selects whether the per-slot DSST activity
    magnitudes (``pre_mag``/``post_mag``) are emitted at all. A non-evolving
    fleet passes False and the O(S·(K+N))-per-timestep factor arithmetic
    never enters the trace — it is compiled out, not just skipped.
    """
    g = cfg.gating
    st, pre, pre_tr = xs.st, carry.pre_spikes, carry.pre_trace
    col = (lambda c: c[:, None]) if serving else (lambda c: c)

    current = fwd_current(backend, pre, xs.w, xs.delta)
    v, tr, s = lif(backend, cfg, st.v, st.tr, current)
    tr_pc = jnp.where(col(t_row == t_pc), tr, st.tr_pc)

    # ---- OSSL three-factor WU, gated, concurrent with SI ----
    mod = ossl_modulator(tr, tr_pc, st.tr_cc, v, cfg)
    if serving:
        ia = pre.mean(-1) if geo.uniform else pre.sum(-1) / xs.fanin
        ss = _cos(tr, st.tr_cc)
    else:
        ia = pre.mean() if geo.uniform \
            else pre.sum() / (pre.shape[0] * xs.fanin)
        ss = _cos(tr, st.tr_cc).mean()
    open_, new_mean = gating_lib.gate_decide(xs.ss_mean, ia, ss, g)
    if serving:
        open_ = open_ & valid
        new_mean = jnp.where(valid, new_mean, xs.ss_mean)
    wu_on = open_ & (t_row >= t_wu) & jnp.asarray(learn)

    if serving:
        if xs.delta.ndim == 5:
            # compact per-slot WU: the outer product lands only in kept
            # blocks — sparse in compute AND storage (the paper's
            # activity-dependent sparse WU)
            from repro.kernels.wu_outer import ops as wu_ops
            spec = cfg.spec(geo.fanins[0])
            scale = jnp.where(wu_on, cfg.lr, 0.0)
            delta_new = xs.delta + wu_ops.wu_outer_slots(
                pre_tr, mod, xs.w["idx"], scale,
                bk=spec.block, bo=spec.out_tile)
        else:
            scale = jnp.where(wu_on, cfg.lr, 0.0)[:, None, None]
            dw = scale * pre_tr[:, :, None] * mod[:, None, :]
            delta_new = xs.delta + dw * xs.w["mask_f"][None]
        w_new, opened_new, offered_new = xs.w, None, None
        if factors:
            # DSST factors for the live topology service: per-slot activity
            # magnitudes, zero on invalid timesteps (slot axis survives — the
            # slot-separability contract extends to topology telemetry)
            valf = valid.astype(tr.dtype)[:, None]
            pre_mag = jnp.abs(pre_tr) * valf
            post_mag = jnp.abs(mod) * valf
        else:
            pre_mag = post_mag = None   # frozen fleet: factors compiled out
    else:
        scale = jnp.where(wu_on, cfg.lr / pre.shape[0], 0.0)
        w_new = train_wu(backend, cfg, xs.w, pre_tr, mod, scale)
        delta_new = None
        opened_new = xs.gate_opened + open_.astype(jnp.float32)
        offered_new = xs.gate_offered + 1.0
        pre_mag = post_mag = None   # training accumulates its own factors

    # ---- telemetry (energy model inputs), per row ----
    late = (t_row >= t_wu) & valid if serving else (t_row >= t_wu)
    offered = xs.fanin * cfg.n_hidden * xs.density
    sop_fwd = carry.sop_fwd + pre.sum(-1) * cfg.n_hidden * xs.density
    sop_wu_off = carry.sop_wu_off + offered * late
    sop_wu = carry.sop_wu + offered * wu_on
    loss = carry.loss + \
        (-_cos(tr, tr_pc) + cfg.cc_weight * _cos(tr, st.tr_cc)) * late

    # invalid slots keep their exact previous state
    if serving:
        vv = valid[:, None]
        v = jnp.where(vv, v, st.v)
        tr = jnp.where(vv, tr, st.tr)
        tr_pc = jnp.where(vv, tr_pc, st.tr_pc)
        s = s * valid.astype(s.dtype)[:, None]

    logits = carry.logits + tr @ xs.readout
    new_carry = LayerCarry(
        pre_spikes=_pad_cols(s, geo.k_max),
        pre_trace=_pad_cols(tr, geo.k_max),
        logits=logits, sop_fwd=sop_fwd, sop_wu=sop_wu,
        sop_wu_off=sop_wu_off, loss=loss)
    out = LayerOut(st=LayerState(v, tr, tr_pc, st.tr_cc), w=w_new,
                   delta=delta_new, ss_mean=new_mean,
                   gate_opened=opened_new, gate_offered=offered_new,
                   open_=open_, pre_mag=pre_mag, post_mag=post_mag)
    return new_carry, out


def _layer_arrays(cfg):
    geo = geometry(cfg)
    fan = jnp.asarray([float(f) for f in geo.fanins], jnp.float32)
    dens = jnp.asarray([cfg.spec(f).density for f in geo.fanins], jnp.float32)
    return fan, dens


def _windows(cfg) -> Tuple[int, int]:
    return (int(cfg.t_steps * cfg.pc_snapshot_frac),
            int(cfg.t_steps * cfg.wu_start_frac))


# ---------------------------------------------------------------------------
# time scans: training (aligned sample) and serving (chunked streams)
# ---------------------------------------------------------------------------

def scan_sample(wrep, readout, layers: LayerState, x_tr, gate,
                events, cfg, backend: Backend, learn: bool):
    """T aligned timesteps over the layer stack (training datapath).

    Returns (wrep', layers', x_tr', gate', outs) with per-timestep outs.
    """
    geo = geometry(cfg)
    t_pc, t_wu = _windows(cfg)
    fan, dens = _layer_arrays(cfg)
    body = partial(_layer_timestep, cfg, backend, geo, learn, False, False,
                   t_pc, t_wu)

    def ts(carry, inp):
        t, x = inp["t"], inp["x"]
        layers, x_tr, gate, wrep = carry
        x_tr = cfg.beta * x_tr + x
        lc0 = LayerCarry(
            pre_spikes=_pad_cols(x, geo.k_max),
            pre_trace=_pad_cols(x_tr, geo.k_max),
            logits=jnp.zeros((x.shape[0], readout.shape[-1])),
            sop_fwd=jnp.zeros(x.shape[0]), sop_wu=jnp.zeros(x.shape[0]),
            sop_wu_off=jnp.zeros(x.shape[0]), loss=jnp.zeros(x.shape[0]))
        xs = LayerSlice(w=wrep, readout=readout, st=layers,
                        ss_mean=gate.ss_mean, gate_opened=gate.opened,
                        gate_offered=gate.offered, delta=None,
                        fanin=fan, density=dens)
        lc, ys = jax.lax.scan(partial(body, t, None), lc0, xs)
        new_gate = gating_lib.GatingState(
            ss_mean=ys.ss_mean, opened=ys.gate_opened,
            offered=ys.gate_offered)
        out = dict(logits=lc.logits, sop_fwd=lc.sop_fwd.sum(),
                   sop_wu=lc.sop_wu.sum(), sop_wu_off=lc.sop_wu_off.sum(),
                   gate=ys.open_.astype(jnp.float32).sum() / cfg.n_layers,
                   loss=lc.loss.mean() / cfg.n_layers)
        return (ys.st, x_tr, new_gate, ys.w), out

    T = events.shape[0]
    carry0 = (layers, x_tr, gate, wrep)
    (layers, x_tr, gate, wrep), outs = jax.lax.scan(
        ts, carry0, {"t": jnp.arange(T), "x": events})
    return wrep, layers, x_tr, gate, outs


def scan_chunk(wrep, readout, deltas, layers: LayerState, x_tr,
               ss_mean, t_win, samp, events, valid, cfg, backend: Backend,
               learn: bool, want_factors: bool = True):
    """Up to C timesteps of S independent streams (serving datapath).

    Engine layout: layer axis leading on ``layers``/``deltas``/``ss_mean``
    (``[L, S, ...]``); the public slot-leading layout is transposed at the
    ``run_chunk`` boundary. Returns (deltas', state pieces, outs).

    A compact ``wrep`` (``{"wc", "idx"}``) with compact deltas
    (``[L, S, J, T, bk, bo]``) is the serving default: the trace then holds
    no dense mask and no dense ``[·, K, N]`` delta leaf at all.

    With ``want_factors`` (static bool) the carry also accumulates per-slot
    DSST activity factors (``acc_pre [L, S, Kmax]``, ``acc_post [L, S, N]``)
    over the chunk — the raw material the serving topology service turns
    into live prune/regrow epochs. ``want_factors=False`` removes the two
    accumulators from the scan carry entirely (no factor leaf appears in
    the jaxpr — pinned by ``tests/test_serving_pipeline.py``): a fleet with
    a frozen topology pays zero in-scan cost for machinery it never reads,
    mirroring how the chip gates its learning datapath off when inactive.
    """
    geo = geometry(cfg)
    t_pc, t_wu = _windows(cfg)
    fan, dens = _layer_arrays(cfg)
    body = partial(_layer_timestep, cfg, backend, geo, learn, True,
                   want_factors, t_pc, t_wu)

    def ts(carry, inp):
        layers, x_tr, ss_mean, t_w, samp, dls, *acc = carry
        x, val = inp["x"], inp["v"]
        valf = val.astype(x.dtype)[:, None]
        x = x * valf
        x_tr = jnp.where(val[:, None], cfg.beta * x_tr + x, x_tr)
        S = x.shape[0]
        lc0 = LayerCarry(
            pre_spikes=_pad_cols(x, geo.k_max),
            pre_trace=_pad_cols(x_tr, geo.k_max),
            logits=jnp.zeros((S, readout.shape[-1])),
            sop_fwd=jnp.zeros(S), sop_wu=jnp.zeros(S),
            sop_wu_off=jnp.zeros(S), loss=jnp.zeros(S))
        xs = LayerSlice(w=wrep, readout=readout, st=layers,
                        ss_mean=ss_mean, gate_opened=None, gate_offered=None,
                        delta=dls, fanin=fan, density=dens)
        lc, ys = jax.lax.scan(partial(body, t_w, val), lc0, xs)

        # ---- per-slot window roll: final trace becomes the CC negative ----
        at_end = val & (t_w == cfg.t_steps - 1)
        endf = at_end[:, None]
        rolled = LayerState(
            v=jnp.where(endf, 0.0, ys.st.v),
            tr=jnp.where(endf, 0.0, ys.st.tr),
            tr_pc=jnp.where(endf, 0.0, ys.st.tr_pc),
            tr_cc=jnp.where(endf, ys.st.tr, ys.st.tr_cc))
        x_tr = jnp.where(endf, 0.0, x_tr)
        samp = samp + at_end.astype(jnp.int32)
        t_w = jnp.where(val, (t_w + 1) % cfg.t_steps, t_w)

        out = dict(logits=lc.logits, at_end=at_end, sop_fwd=lc.sop_fwd,
                   sop_wu=lc.sop_wu, sop_wu_off=lc.sop_wu_off,
                   opened=ys.open_.T.astype(jnp.float32),
                   offered=jnp.tile(val.astype(jnp.float32)[:, None],
                                    (1, cfg.n_layers)),
                   loss=lc.loss / cfg.n_layers,
                   steps=val.astype(jnp.float32))
        new_acc = (acc[0] + ys.pre_mag, acc[1] + ys.post_mag) if acc else ()
        return (rolled, x_tr, ys.ss_mean, t_w, samp, ys.delta,
                *new_acc), out

    S = events.shape[1]
    acc0 = ()
    if want_factors:
        acc0 = (jnp.zeros((cfg.n_layers, S, geo.k_max)),
                jnp.zeros((cfg.n_layers, S, cfg.n_hidden)))
    carry0 = (layers, x_tr, ss_mean, t_win, samp, deltas, *acc0)
    carry, outs = jax.lax.scan(ts, carry0, {"x": events, "v": valid})
    _assert_slot_separable(carry, outs, events.shape[0], events.shape[1], cfg,
                           want_factors)
    return carry, outs


def ordered_slot_sum(x: jax.Array) -> jax.Array:
    """Reduce the leading slot axis with a shape-fixed binary halving tree.

    ``x``: any ``[S, ...]`` array; returns ``x.sum(0)`` computed as
    ``(x[:S//2] + x[S//2:2*(S//2)])`` recursively (odd tails ride along one
    level). Every level is a plain elementwise add of two halves, so the
    floating-point association order is a function of ``S`` alone — NOT of
    the device count, sharding, or XLA's reduction strategy. This is what
    lets the serving layer move the DSST-factor slot reduction onto the
    device (one tiny ``[L, ·]`` transfer instead of ``[S, L, ·]`` per grid
    step) while keeping the 1-device and slot-sharded fleets' topology
    epoch decisions bit-identical — a bare ``x.sum(0)`` would not.
    """
    while x.shape[0] > 1:
        half = x.shape[0] // 2
        paired = x[:half] + x[half:2 * half]
        x = paired if x.shape[0] % 2 == 0 else \
            jnp.concatenate([paired, x[2 * half:]], axis=0)
    return x[0]


def _assert_slot_separable(carry, outs, C: int, S: int, cfg,
                           want_factors: bool) -> None:
    """The chunk step's zero-collective contract: every per-stream quantity
    keeps its slot axis through the scan. A reduction over slots — which
    would silently break the slot-axis ``shard_map`` in serving/adapt.py —
    shows up at trace time as a dropped ``S`` dimension here. Thin wrapper
    over the shared analyzer (repro.analysis.jaxpr_contracts), imported
    lazily so the engine keeps no static analysis dependency."""
    from repro.analysis.jaxpr_contracts import \
        assert_chunk_carry_slot_separable
    assert_chunk_carry_slot_separable(carry, outs, C=C, S=S,
                                      n_layers=cfg.n_layers,
                                      want_factors=want_factors)
