"""ElfCore's three contributions as composable JAX modules.

* :mod:`repro.core.sparsity` — N:M structured masks (element + MXU-block).
* :mod:`repro.core.dsst`     — dynamic prune/regrow with factorized sorting.
* :mod:`repro.core.ossl`     — local predictive+contrastive learning.
* :mod:`repro.core.gating`   — activity-dependent weight-update gating.
* :mod:`repro.core.snn`      — the paper-faithful chip network (LIF, traces).
* :mod:`repro.core.energy`   — SOP-count → µW model (paper constants).
"""
from .sparsity import NMSpec, paper_spec_4groups  # noqa: F401
from .dsst import DSSTConfig, DSSTAccumulator  # noqa: F401
from .gating import GatingConfig  # noqa: F401
from .ossl import OSSLConfig  # noqa: F401
from .snn import SNNConfig  # noqa: F401
