"""Energy/power model — the CPU-land stand-in for ElfCore's silicon numbers.

The container cannot measure µW; what it *can* do is count the exact
architectural events the chip's power decomposes into (synaptic ops, weight
updates, SRAM touches, leakage) and price them with the paper's measured
constants. All Fig. 7 / Table I reproductions report BOTH the counted events
(ours) and the modeled µW (ours × paper constants) next to the paper's
measured values — the *relative* claims (DSST −56 % learn power, gating −52 %
beyond zero-skipping, 16× vs [3]) are what we validate.

Constants and where they come from:
* 2.4 pJ/SOP @ 0.6 V / 20 MHz, 9.2 pJ/SOP @ 0.9 V (chip summary, Fig. 8).
* leakage 8 µW @ 0.6 V, 39 µW @ 0.9 V (chip summary).
* WU is priced as a SOP plus a weight-SRAM read-modify-write; SRAM energies
  use standard 28 nm figures (~5 fJ/bit read, ~8 fJ/bit write) — these only
  matter for the *split*, the totals are dominated by SOP counts.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    name: str
    vdd: float
    freq_hz: float
    e_sop_j: float        # energy per synaptic operation
    leakage_w: float

    @staticmethod
    def low_power() -> "OperatingPoint":
        return OperatingPoint("0.6V/20MHz", 0.6, 20e6, 2.4e-12, 8e-6)

    @staticmethod
    def high_perf() -> "OperatingPoint":
        return OperatingPoint("0.9V/155MHz", 0.9, 155e6, 9.2e-12, 39e-6)


E_SRAM_READ_PER_BIT = 5e-15   # 28nm-class
E_SRAM_WRITE_PER_BIT = 8e-15
WEIGHT_BITS = 8
INDEX_BITS = 9


@dataclasses.dataclass
class EnergyReport:
    sop_forward: float
    sop_wu: float
    sop_wu_offered: float
    duration_s: float
    op: OperatingPoint

    @property
    def e_forward_j(self) -> float:
        # forward SOP = MAC + weight read (+ index read when sparse)
        per = self.op.e_sop_j + (WEIGHT_BITS + INDEX_BITS) * E_SRAM_READ_PER_BIT
        return self.sop_forward * per

    @property
    def e_wu_j(self) -> float:
        # WU = MAC + weight read + weight write-back
        per = (self.op.e_sop_j
               + WEIGHT_BITS * (E_SRAM_READ_PER_BIT + E_SRAM_WRITE_PER_BIT))
        return self.sop_wu * per

    @property
    def e_leak_j(self) -> float:
        return self.op.leakage_w * self.duration_s

    @property
    def total_j(self) -> float:
        return self.e_forward_j + self.e_wu_j + self.e_leak_j

    @property
    def power_w(self) -> float:
        return self.total_j / max(self.duration_s, 1e-12)

    @property
    def wu_skip_rate(self) -> float:
        if self.sop_wu_offered <= 0:
            return 0.0
        return 1.0 - self.sop_wu / self.sop_wu_offered

    def as_dict(self) -> dict:
        return {
            "op_point": self.op.name,
            "sop_forward": self.sop_forward,
            "sop_wu": self.sop_wu,
            "wu_skip_rate": self.wu_skip_rate,
            "power_uW": self.power_w * 1e6,
            "e_per_sop_pJ": self.op.e_sop_j * 1e12,
        }


def report(sop_forward, sop_wu, sop_wu_offered, n_timesteps,
           op: OperatingPoint | None = None,
           cycles_per_ts: float = 512.0) -> EnergyReport:
    """Price counted events at an operating point.

    ``cycles_per_ts`` models the chip's event-driven duty cycle: one TS
    occupies roughly fan-in cycles on the serial input path; the AON SerDes
    clock-gates the core between TSs (we charge leakage for wall time).
    """
    op = op or OperatingPoint.low_power()
    duration = float(n_timesteps) * cycles_per_ts / op.freq_hz
    return EnergyReport(float(sop_forward), float(sop_wu), float(sop_wu_offered),
                        duration, op)


def network_capacity_efficiency(n_neurons: int, area_mm2: float, e_sop_pj: float) -> float:
    """NCE = max NN scale / (area × peak energy/SOP) — Table I footnote d."""
    return n_neurons / (area_mm2 * e_sop_pj)
