"""N:M structured sparsity — the weight-memory substrate of ElfCore's DSST.

Two granularities (DESIGN.md §2):

* **element** (``block=1``): the paper-faithful form. For a weight matrix
  ``w[(in), (out)]`` the input dimension is split into groups of ``m``
  consecutive elements; exactly ``n`` of each group are materialised per
  output neuron.  ElfCore stores these as (8-bit value, 9-bit index) SRAM
  words; we store (value, local-index) arrays with the same structural ratio.

* **block** (``block=128``): the TPU/MXU adaptation. The input dimension is
  split into blocks of ``block`` rows; blocks are grouped ``m`` at a time and
  ``n`` blocks per group are kept, with an independent pattern per
  ``block``-wide output tile.  Arithmetic inside kept tiles stays dense
  (MXU-friendly); the memory cut and prune/regrow dynamics match the paper's
  at block resolution.

Masks are always materialisable to a dense boolean ``[K, O]`` for reference
math; compact layouts are what kernels and checkpoints carry.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class NMSpec:
    """Keep ``n`` of every ``m`` units (elements or blocks) along the input dim.

    ``block`` is the unit size in rows; ``block == 1`` is element N:M.
    ``out_tile`` is the output-column tile that shares one pattern
    (1 for element granularity, typically 128 for block granularity).
    """

    n: int
    m: int
    block: int = 1
    out_tile: int = 1

    def __post_init__(self):
        if not (0 < self.n <= self.m):
            raise ValueError(f"need 0 < n <= m, got n={self.n} m={self.m}")
        if self.block < 1 or self.out_tile < 1:
            raise ValueError("block/out_tile must be >= 1")

    @property
    def density(self) -> float:
        return self.n / self.m

    @property
    def sparsity(self) -> float:
        return 1.0 - self.density

    def group_shape(self, k: int, o: int) -> Tuple[int, int, int]:
        """(num_groups G, units per group M, num out tiles J) for a [k, o] weight."""
        kb, ob = self.unit_counts(k, o)
        if kb % self.m:
            raise ValueError(f"K units {kb} not divisible by m={self.m}")
        return kb // self.m, self.m, ob

    def unit_counts(self, k: int, o: int) -> Tuple[int, int]:
        if k % self.block:
            raise ValueError(f"K={k} not divisible by block={self.block}")
        if o % self.out_tile:
            raise ValueError(f"O={o} not divisible by out_tile={self.out_tile}")
        return k // self.block, o // self.out_tile


def paper_spec_4groups(k: int, sparsity: float = 0.8) -> NMSpec:
    """ElfCore's configuration: 4 N:M groups across the fan-in.

    The chip splits each neuron's fan-in into 4 groups (one per PE); with
    target sparsity ``s`` each group keeps ``round(M * (1-s))`` connections.
    """
    if k % 4:
        raise ValueError("fan-in must divide into 4 groups")
    m = k // 4
    n = max(1, int(round(m * (1.0 - sparsity))))
    return NMSpec(n=n, m=m, block=1, out_tile=1)


# ---------------------------------------------------------------------------
# mask construction / validation
# ---------------------------------------------------------------------------

def _unit_mask_shape(spec: NMSpec, k: int, o: int) -> Tuple[int, int]:
    return spec.unit_counts(k, o)


def random_unit_mask(rng: jax.Array, spec: NMSpec, k: int, o: int) -> jax.Array:
    """Uniform random N:M pattern at unit (element/block) granularity.

    Returns bool ``[KB, J]`` where KB = K/block units and J = O/out_tile tiles.
    DSST starts from exactly this ("uniform N:M sparsity to maximise mask
    diversity"), *not* from a dense net.
    """
    kb, j = _unit_mask_shape(spec, k, o)
    g, m, _ = spec.group_shape(k, o)
    scores = jax.random.uniform(rng, (g, m, j))
    # top-n random scores per (group, out-tile) -> exactly n kept units.
    kth = jnp.sort(scores, axis=1)[:, m - spec.n, :]  # n-th largest
    mask = scores >= kth[:, None, :]
    return mask.reshape(kb, j)


def expand_unit_mask(unit_mask: jax.Array, spec: NMSpec, k: int, o: int) -> jax.Array:
    """Unit-granular mask [KB, J] -> dense boolean [K, O]."""
    kb, j = _unit_mask_shape(spec, k, o)
    assert unit_mask.shape == (kb, j), (unit_mask.shape, (kb, j))
    dense = jnp.repeat(jnp.repeat(unit_mask, spec.block, axis=0), spec.out_tile, axis=1)
    return dense


def check_unit_mask(unit_mask: jax.Array, spec: NMSpec) -> jax.Array:
    """True iff every (group, out-tile) keeps exactly n units.

    Accepts any leading batch dims (``[..., KB, J]``) sharing one spec —
    a stacked ``[L, KB, J]`` topology checks in one call.
    """
    *lead, kb, j = unit_mask.shape
    g = kb // spec.m
    counts = unit_mask.reshape(*lead, g, spec.m, j).sum(axis=-2)
    return jnp.all(counts == spec.n)


# ---------------------------------------------------------------------------
# compact <-> dense conversion (value + index storage, as on the chip)
# ---------------------------------------------------------------------------

def compact_indices(unit_mask: jax.Array, spec: NMSpec) -> jax.Array:
    """Per (group, out-tile): the ``n`` kept unit indices (local in [0, m)).

    Returns int32 ``[G, n, J]``, ascending per group. Shape is static — this
    is the 9-bit index SRAM of the chip.
    """
    kb, j = unit_mask.shape
    g = kb // spec.m
    grouped = unit_mask.reshape(g, spec.m, j)
    # argsort of (not kept) is stable => kept units first, ascending order.
    order = jnp.argsort(~grouped, axis=1, stable=True)
    return order[:, : spec.n, :].astype(jnp.int32)


def indices_to_unit_mask(idx: jax.Array, spec: NMSpec) -> jax.Array:
    """Inverse of :func:`compact_indices`: int32 [G, n, J] -> bool [KB, J]."""
    g, n, j = idx.shape
    onehot = jax.nn.one_hot(idx, spec.m, axis=1, dtype=jnp.bool_)  # [G, m, n, J]
    grouped = onehot.any(axis=2)
    return grouped.reshape(g * spec.m, j)


def compact_values(w: jax.Array, idx: jax.Array, spec: NMSpec) -> jax.Array:
    """Gather kept weights into compact storage.

    ``w``: dense [K, O]; ``idx``: [G, n, J] local unit indices.
    Returns [G, n, block, O] — for element granularity this is [G, n, 1, O].
    (The out_tile axis stays dense inside O; the pattern only repeats.)
    """
    k, o = w.shape
    g, n, j = idx.shape
    wg = w.reshape(g, spec.m, spec.block, o)
    # broadcast idx over out-tiles: take per (g, tile) — build per-column index.
    idx_cols = jnp.repeat(idx, spec.out_tile, axis=2)  # [G, n, O]
    return jnp.take_along_axis(wg, idx_cols[:, :, None, :], axis=1)


def densify_values(values: jax.Array, idx: jax.Array, spec: NMSpec, k: int, o: int) -> jax.Array:
    """Scatter compact [G, n, block, O] back to dense [K, O] (zeros elsewhere)."""
    g, n, j = idx.shape
    idx_cols = jnp.repeat(idx, spec.out_tile, axis=2)  # [G, n, O]
    dense_g = jnp.zeros((g, spec.m, spec.block, o), values.dtype)
    dense_g = jax.vmap(  # over groups
        lambda dg, ic, vv: dg.at[ic[:, None, :], jnp.arange(spec.block)[None, :, None],
                                 jnp.arange(o)[None, None, :]].set(vv)
    )(dense_g, idx_cols, values)
    return dense_g.reshape(k, o)


# ---------------------------------------------------------------------------
# memory accounting (the paper's "3.8x on-chip memory cut")
# ---------------------------------------------------------------------------

def memory_bits(k: int, o: int, spec: NMSpec, weight_bits: int = 8) -> dict:
    """Weight-memory cost of dense vs compact N:M storage, in bits.

    Mirrors the chip: ``weight_bits`` per kept value plus an index of
    ``ceil(log2 m)`` bits per kept unit per out-tile column group.
    """
    g, m, j = spec.group_shape(k, o)
    idx_bits = max(1, int(np.ceil(np.log2(spec.m))))
    dense = k * o * weight_bits
    kept_values = g * spec.n * spec.block * o * weight_bits
    kept_index = g * spec.n * j * idx_bits
    comp = kept_values + kept_index
    return {
        "dense_bits": dense,
        "compact_bits": comp,
        "reduction": 1.0 - comp / dense,
        "index_overhead": kept_index / comp,
    }


# ---------------------------------------------------------------------------
# masked-apply helpers used by reference paths
# ---------------------------------------------------------------------------

def apply_mask(w: jax.Array, unit_mask: jax.Array, spec: NMSpec) -> jax.Array:
    return w * expand_unit_mask(unit_mask, spec, *w.shape).astype(w.dtype)


def unit_scores(x: jax.Array, spec: NMSpec, k: int, o: int, reduce: str = "abs_sum") -> jax.Array:
    """Summarise a dense [K, O] tensor to unit granularity [KB, J].

    Used to turn dense weight/grad magnitudes into block-level prune/regrow
    scores. ``abs_sum`` matches "k smallest weights" at block resolution.
    """
    kb, j = spec.unit_counts(k, o)
    xg = x.reshape(kb, spec.block, j, spec.out_tile)
    if reduce == "abs_sum":
        return jnp.abs(xg).sum(axis=(1, 3))
    if reduce == "sum":
        return xg.sum(axis=(1, 3))
    if reduce == "max":
        return jnp.abs(xg).max(axis=(1, 3))
    raise ValueError(reduce)
