"""OSSL beyond the chip — local self-supervised learning for deep nets.

ElfCore's hidden layers learn with a *local* predictive + contrastive rule
and therefore have **no backward inter-layer dependency** ("WU locking"
resolved, §III). Scaled up, that property is a distribution feature: a
transformer trained with per-block local losses needs **no backward pass
across pipeline stages** — each stage updates concurrently with the forward
wave, exactly like the chip's layer-parallel WU.

This module provides that adaptation for the LM-family archs:

* ``local_head_init`` — a small predictor head per block (the trace-compare
  logic of Fig. 2, learned instead of wired).
* ``local_loss`` — per-block loss with
    PC  (within-sample): block output at position t predicts its own
        representation d tokens ahead (cosine, through the predictor), and
    CC  (across-samples): pooled representations of different sequences in
        the batch are pushed apart (the "previous sample" negative of the
        chip generalises to in-batch negatives for batch > 1).
* ``block_stats`` — the IA / SS quantities the gating engine consumes.

``models/transformer.py`` uses these in ``mode="local"``: block inputs are
``stop_gradient``-ed so the total loss is a *sum of independent per-block
problems* plus a supervised readout on frozen features (the chip's SL output
layer).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OSSLConfig:
    predict_offset: int = 8     # d: how many tokens ahead PC predicts
    cc_weight: float = 0.5
    temperature: float = 0.1


def local_head_init(rng: jax.Array, d_model: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {"p": jax.random.normal(rng, (d_model, d_model), dtype) * (d_model ** -0.5)}


def _l2n(x, axis=-1, eps=1e-6):
    return x / (jnp.linalg.norm(x, axis=axis, keepdims=True) + eps)


def local_loss(h_out: jax.Array, head: Dict[str, jax.Array], cfg: OSSLConfig) -> jax.Array:
    """Per-block OSSL loss. ``h_out``: [B, S, D] block output (block input was
    stop_gradient-ed by the caller; targets are stop_gradient-ed here)."""
    d = cfg.predict_offset
    pred = _l2n(h_out[:, :-d] @ head["p"])                  # [B, S-d, D]
    tgt = _l2n(jax.lax.stop_gradient(h_out[:, d:]))
    pc = -(pred * tgt).sum(-1).mean()

    pooled = _l2n(h_out.mean(axis=1))                       # [B, D]
    sim = pooled @ pooled.T / cfg.temperature               # [B, B]
    b = pooled.shape[0]
    off = sim - 1e9 * jnp.eye(b, dtype=sim.dtype)
    # push in-batch negatives apart (previous-sample contrast generalised)
    cc = jax.nn.logsumexp(off, axis=-1).mean() - jnp.log(jnp.asarray(max(b - 1, 1), sim.dtype))
    return pc + cfg.cc_weight * cc


def block_stats(h_in: jax.Array, h_out: jax.Array, ema: jax.Array):
    """(IA, SS, pooled) for the gating engine.

    IA = mean |block input| (the LM analogue of presynaptic spike rate);
    SS = cosine of the pooled block output against its running EMA (the LM
    analogue of comparing the current trace with the stored one)."""
    ia = jnp.abs(h_in).mean()
    pooled = h_out.mean(axis=(0, 1))
    ss = (_l2n(pooled, axis=0) * _l2n(ema, axis=0)).sum()
    return ia, ss, pooled
