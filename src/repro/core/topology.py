"""First-class layer-stacked N:M topology lifecycle — shared by train & serve.

Before this module the sparsity *topology* of the network was scattered:
``sparsity.py`` owned per-layer mask construction, ``dsst.py`` owned the
per-layer prune/regrow event, ``engine.py``'s param dict carried the stacked
``[L, KBmax, J]`` mask, and the training loop in ``snn.run_sample`` hand-
rolled the per-layer epoch while the serving runtime froze connectivity
forever.  ``Topology`` makes the stacked mask (plus its compact kept-unit
index view — the chip's 9-bit index SRAM) a value with a lifecycle:

* :func:`topology_epoch` — ONE stacked prune/regrow epoch over every hidden
  layer, used verbatim by the offline training step (``snn.run_sample``) and
  the live serving topology service (``serving/topology_service.py``).  It
  honors the ``DSSTConfig`` decay schedule trace-safely: a host-int step
  resolves ``k`` directly; a traced step dispatches over the static schedule
  levels with ``lax.switch`` (see :func:`repro.core.dsst.scheduled_k_apply`).
* :func:`project_deltas` — remap the slot-sharded ``[S, L, Kmax, N]``
  per-stream delta tensor across a mask change: surviving connections keep
  their delta values **bit-exactly** (``jnp.where``, not a multiply), pruned
  and regrown coordinates restart at zero.  Same shapes in and out, so a
  topology swap never recompiles the serving chunk step.
* :func:`prune_regrow_stacked` / :func:`prune_regrow_factored_stacked` —
  vmapped-over-layers forms of the core DSST events, also reused by the
  LM-scale DSST pass (``optim/sparse.lm_dsst_event``).

Layer stacking follows the engine convention: masks are padded with
``False`` rows up to the stack width ``Kmax``; all topology math slices each
layer back to its true ``(KB, J)`` before grouping, so padded rows can never
be pruned into or regrown from.  When every layer shares one fan-in (the
paper's 512-512 configuration) the epoch runs as a single vmap over the
layer axis; otherwise it falls back to an equivalent per-layer loop — one
code path, two lowerings.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .dsst import prune_regrow, prune_regrow_factored, scheduled_k_apply
from .sparsity import (NMSpec, check_unit_mask, compact_indices,
                       expand_unit_mask, unit_scores)


# ---------------------------------------------------------------------------
# the topology value
# ---------------------------------------------------------------------------

class Topology(NamedTuple):
    """Stacked N:M connectivity of every hidden layer.

    ``unit_mask``: bool ``[L, Kmax(=KBmax·block), J]`` — the same padded
    layout ``params["hidden"]["mask"]`` carries (False rows above a layer's
    true unit count). ``idx``: int32 ``[L, G, n, J]`` compact kept-unit ids
    per group — the value/index SRAM pair's index half; present only for
    uniform layer geometry (``None`` otherwise, where per-layer group shapes
    differ and a single stacked index tensor does not exist).
    """
    unit_mask: jax.Array
    idx: Optional[jax.Array]


class TopologyStats(NamedTuple):
    """Per-layer epoch telemetry: int32 ``[L]`` pruned/regrown, f32 ``[L]``
    mask-change fraction."""
    pruned: jax.Array
    regrown: jax.Array
    mask_change: jax.Array

    @property
    def total_pruned(self):
        return self.pruned.sum()

    @property
    def total_regrown(self):
        return self.regrown.sum()


def specs(cfg) -> Tuple[NMSpec, ...]:
    """Per-layer N:M specs (one per hidden layer, in stack order)."""
    return tuple(cfg.spec(f) for f in cfg.layer_fanins)


def uniform_geometry(cfg) -> bool:
    return len(set(cfg.layer_fanins)) == 1


def _k_max(cfg) -> int:
    return max(cfg.layer_fanins)


def _pad_rows(x: jax.Array, k: int) -> jax.Array:
    if x.shape[0] == k:
        return x
    return jnp.pad(x, ((0, k - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def layer_mask(mask_stacked: jax.Array, l: int, cfg) -> jax.Array:
    """Layer ``l``'s true ``[KB, J]`` unit mask out of the padded stack."""
    spec = cfg.spec(cfg.layer_fanins[l])
    kb, j = spec.unit_counts(cfg.layer_fanins[l], cfg.n_hidden)
    return mask_stacked[l, :kb, :j]


def from_mask(mask_stacked: jax.Array, cfg) -> Topology:
    """Wrap a stacked padded mask, building the compact index view when the
    layer geometry is uniform."""
    idx = None
    if uniform_geometry(cfg):
        spec = cfg.spec(cfg.layer_fanins[0])
        idx = jax.vmap(lambda m: compact_indices(m, spec))(mask_stacked)
    return Topology(unit_mask=mask_stacked, idx=idx)


def from_params(params: Dict[str, Any], cfg) -> Topology:
    return from_mask(params["hidden"]["mask"], cfg)


def install(topo: Topology, params: Dict[str, Any]) -> Dict[str, Any]:
    """Return ``params`` with the topology's mask installed — a generic
    pytree update that preserves every other key at both nesting levels."""
    return {**params,
            "hidden": {**params["hidden"], "mask": topo.unit_mask}}


def check(mask_or_topo: Union[Topology, jax.Array], cfg) -> bool:
    """Host-side invariant check: every layer keeps exactly n units per
    (group, out-tile) and padded rows stay all-False."""
    mask = mask_or_topo.unit_mask if isinstance(mask_or_topo, Topology) \
        else mask_or_topo
    mask = np.asarray(mask)
    if uniform_geometry(cfg):        # no padding: one stacked check
        return bool(check_unit_mask(jnp.asarray(mask),
                                    cfg.spec(cfg.layer_fanins[0])))
    for l, fan_in in enumerate(cfg.layer_fanins):
        spec = cfg.spec(fan_in)
        kb, j = spec.unit_counts(fan_in, cfg.n_hidden)
        if not bool(check_unit_mask(jnp.asarray(mask[l, :kb, :j]), spec)):
            return False
        if mask[l, kb:].any():
            return False
    return True


def dense_masks(mask_stacked: jax.Array, cfg, dtype=jnp.float32) -> jax.Array:
    """Stacked unit masks ``[L, KBmax, J]`` -> dense ``[L, Kmax, N]`` (zero
    rows where a layer's fan-in is below the stack width)."""
    k_max = _k_max(cfg)
    cols = []
    for l, fan_in in enumerate(cfg.layer_fanins):
        spec = cfg.spec(fan_in)
        kb, j = spec.unit_counts(fan_in, cfg.n_hidden)
        d = expand_unit_mask(mask_stacked[l, :kb, :j], spec, fan_in,
                             cfg.n_hidden)
        cols.append(_pad_rows(d.astype(dtype), k_max))
    return jnp.stack(cols)


# ---------------------------------------------------------------------------
# stacked prune/regrow (vmapped over the layer axis)
# ---------------------------------------------------------------------------

def prune_regrow_stacked(unit_mask: jax.Array, weight_score: jax.Array,
                         grad_score: jax.Array, spec: NMSpec, k: int
                         ) -> Tuple[jax.Array, TopologyStats]:
    """Dense-oracle DSST event for a ``[L, KB, J]`` mask stack sharing one
    spec — one vmap instead of L traces."""
    new_mask, st = jax.vmap(
        lambda m, w, g: prune_regrow(m, w, g, spec, k)
    )(unit_mask, weight_score, grad_score)
    return new_mask, TopologyStats(st.pruned, st.regrown, st.mask_change)


def prune_regrow_factored_stacked(unit_mask: jax.Array,
                                  weight_score: jax.Array,
                                  pre_score: jax.Array, post_score: jax.Array,
                                  spec: NMSpec, k: int
                                  ) -> Tuple[jax.Array, TopologyStats]:
    """Factored (neuron-level-sorted) DSST event for a mask stack:
    ``pre_score [L, KB]``, ``post_score [L, J]``."""
    new_mask, st = jax.vmap(
        lambda m, w, p, q: prune_regrow_factored(m, w, p, q, spec, k)
    )(unit_mask, weight_score, pre_score, post_score)
    return new_mask, TopologyStats(st.pruned, st.regrown, st.mask_change)


# ---------------------------------------------------------------------------
# delta / weight remapping across a mask change
# ---------------------------------------------------------------------------

def survivors_dense(old_mask: jax.Array, new_mask: jax.Array, cfg,
                    dtype=jnp.bool_) -> jax.Array:
    """Dense ``[L, Kmax, N]`` mask of connections present in BOTH masks."""
    return dense_masks(old_mask & new_mask, cfg, dtype=dtype)


def stacked_kept_ids(mask_stacked: jax.Array, cfg) -> jax.Array:
    """Stacked kept-block ids ``[L, J, T]`` — the same argsort convention as
    ``kernels/nm_spmm.make_compact`` (ascending kept block ids per out
    tile), so ids derived here address compact tensors built there.
    Uniform geometry only (one ``T`` shared by every layer)."""
    if not uniform_geometry(cfg):
        raise ValueError("stacked kept ids require uniform layer fan-in "
                         f"(got {tuple(cfg.layer_fanins)})")
    spec = cfg.spec(cfg.layer_fanins[0])
    kb, _ = spec.unit_counts(cfg.layer_fanins[0], cfg.n_hidden)
    t = (kb // spec.m) * spec.n
    idx = jnp.argsort(~mask_stacked, axis=1, stable=True)[:, :t, :]
    return idx.transpose(0, 2, 1).astype(jnp.int32)           # [L, J, T]


def project_deltas_compact(deltas_c: jax.Array, old_ids: jax.Array,
                           new_ids: jax.Array) -> jax.Array:
    """Remap compact per-stream deltas ``[S, L, J, T, bk, bo]`` from the old
    topology's kept-block ids to the new one's (both ``[L, J, T]``).

    A pure gather: every new slot that addresses a surviving block copies
    the old slot's bits unchanged; regrown blocks start at zero. No dense
    tensor is ever built — the epoch-boundary analogue of the mask-free
    hot path.
    """
    eq = new_ids[..., :, None] == old_ids[..., None, :]       # [L, J, T, T]
    hit = eq.any(-1)                                          # [L, J, T]
    pos = jnp.argmax(eq, axis=-1)                             # [L, J, T]
    gathered = jnp.take_along_axis(
        deltas_c, pos[None, :, :, :, None, None], axis=3)
    return jnp.where(hit[None, :, :, :, None, None], gathered,
                     jnp.zeros((), deltas_c.dtype))


def project_deltas(deltas: jax.Array, old_mask: jax.Array,
                   new_mask: jax.Array, cfg) -> jax.Array:
    """Remap the per-stream delta tensor across a mask change: surviving
    connections keep their values bit-exactly, pruned and regrown
    coordinates go to zero (regrown restart clean, as on-chip).

    Dispatches on layout: compact ``[S, L, J, T, bk, bo]`` deltas remap by
    a kept-block-id gather (no dense tensor materialised); dense
    ``[S, L, Kmax, N]`` deltas use a ``jnp.where`` against the dense
    survivor mask (not a mask multiply) so survivors are the identical
    bits — the acceptance property of the zero-recompile topology swap.
    """
    if deltas.ndim == 6:
        return project_deltas_compact(deltas,
                                      stacked_kept_ids(old_mask, cfg),
                                      stacked_kept_ids(new_mask, cfg))
    surv = survivors_dense(old_mask, new_mask, cfg)           # [L, Kmax, N]
    return jnp.where(surv[None], deltas, jnp.zeros((), deltas.dtype))


def remap_weights(w_stacked: jax.Array, old_mask: jax.Array,
                  new_mask: jax.Array, cfg) -> jax.Array:
    """Stacked form of ``dsst.apply_dsst_to_weights``: survivors keep their
    values bit-exactly; pruned and regrown entries are zeroed."""
    surv = survivors_dense(old_mask, new_mask, cfg)
    return jnp.where(surv, w_stacked, jnp.zeros((), w_stacked.dtype))


def weight_unit_scores(w_stacked: jax.Array, cfg) -> jax.Array:
    """|w| summarised to unit granularity per layer: ``[L, KBmax, J]``
    (padded rows score 0 — they are structurally unprunable anyway)."""
    k_max = _k_max(cfg)
    cols = []
    for l, fan_in in enumerate(cfg.layer_fanins):
        spec = cfg.spec(fan_in)
        kb, j = spec.unit_counts(fan_in, cfg.n_hidden)
        s = unit_scores(w_stacked[l, :fan_in, :], spec, fan_in, cfg.n_hidden)
        cols.append(_pad_rows(s, k_max))
    return jnp.stack(cols)


# ---------------------------------------------------------------------------
# THE shared epoch (train == serve)
# ---------------------------------------------------------------------------

def topology_epoch(params: Dict[str, Any], pre: jax.Array, post: jax.Array,
                   cfg, step: Union[int, jax.Array]
                   ) -> Tuple[Dict[str, Any], TopologyStats]:
    """One stacked DSST prune/regrow epoch over every hidden layer.

    ``pre``: unit-granular ``[L, KBmax]`` pre-synaptic activity factors
    (padded rows ignored), ``post``: ``[L, J]`` post factors — the
    ``DSSTAccumulator`` contents, stacked.  ``step`` selects the recycled
    count ``k`` from ``cfg.dsst``'s decay schedule: a host int resolves it
    statically, a traced array dispatches over the precomputed schedule
    levels (trace-safe — see ``DSSTConfig.k_levels``).

    Returns ``(new_params, stats)``; ``new_params`` has the evolved mask
    installed and weights remapped (survivors bit-exact, recycled zeroed),
    every other param leaf untouched.  Used by ``snn.run_sample`` (offline
    epochs inside the jitted train step) and by
    ``serving.topology_service.TopologyService`` (live epochs between grid
    steps) — train and serve share this one prune/regrow code path.
    """
    mask = params["hidden"]["mask"]
    w = params["hidden"]["w"]
    wscore = weight_unit_scores(w, cfg)

    if uniform_geometry(cfg):
        spec = cfg.spec(cfg.layer_fanins[0])
        new_mask, stats = scheduled_k_apply(
            step, cfg.dsst, spec,
            lambda k: prune_regrow_factored_stacked(
                mask, wscore, pre, post, spec, k))
    else:
        new_masks, per_layer = [], []
        for l, fan_in in enumerate(cfg.layer_fanins):
            spec = cfg.spec(fan_in)
            kb, j = spec.unit_counts(fan_in, cfg.n_hidden)
            nm, st = scheduled_k_apply(
                step, cfg.dsst, spec,
                lambda k, l=l, spec=spec, kb=kb, j=j: prune_regrow_factored(
                    mask[l, :kb, :j], wscore[l, :kb, :j],
                    pre[l, :kb], post[l, :j], spec, k))
            new_masks.append(_pad_rows(nm, mask.shape[1]))
            per_layer.append(st)
        new_mask = jnp.stack(new_masks)
        stats = TopologyStats(
            pruned=jnp.stack([s.pruned for s in per_layer]),
            regrown=jnp.stack([s.regrown for s in per_layer]),
            mask_change=jnp.stack([s.mask_change for s in per_layer]))

    new_w = remap_weights(w, mask, new_mask, cfg)
    new_params = install(Topology(new_mask, None), params)
    new_params = {**new_params,
                  "hidden": {**new_params["hidden"], "w": new_w}}
    return new_params, stats
