"""ElfCore's spiking network — the paper-faithful reproduction (floor).

Implements the chip of Fig. 2 as a pure-JAX simulator:

* (512)-512-512-16 topology, two hidden LIF layers (each = 4 N:M groups /
  "PEs"), **bypass connections** from every hidden layer to the output, so
  depth can be varied for the Fig. 7 depth study.
* **Neuron SRAM with three traces per neuron**: the current TS's trace (used
  by WU), a snapshot from an earlier TS of the same sample (used by
  predictive coding), and the trace at the final TS of the *previous* sample
  (used by contrastive coding).
* **OSSL**: per-layer three-factor updates with concurrent PC + CC — no
  labels, no backprop, all hidden layers update in parallel with the forward
  pass (WU-locking removed; §III's 67–72 % TS-length cut).
* **SL output layer**: delta-rule readout (the only place labels enter).
* **DSST**: connectivity prune/regrow every ``period`` samples from the
  factorized |pre|·|post| statistics written back during WU.
* **Activity-dependent WU gating**: IA vs a global threshold, SS vs an
  adaptive per-layer threshold (core/gating.py).
* SOP / WU / memory-access counters feed the energy model (core/energy.py).

Everything is jit-compatible; a full sample (T timesteps) is one
``lax.scan``. Forward integration and weight update happen in the same scan
step — the chip's "SI and WU run concurrently".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import gating as gating_lib
from .dsst import (DSSTAccumulator, DSSTConfig, apply_dsst_to_weights,
                   prune_regrow_factored)
from .sparsity import NMSpec, apply_mask, paper_spec_4groups, random_unit_mask, unit_scores


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SNNConfig:
    n_in: int = 512
    n_hidden: int = 512
    n_layers: int = 2          # hidden layers (1 or 2; bypass keeps output wired)
    n_out: int = 16
    t_steps: int = 50          # timesteps per sample
    # neuron dynamics
    alpha: float = 0.9         # membrane decay
    beta: float = 0.85         # trace decay
    theta: float = 1.0         # firing threshold (soft reset)
    surrogate_width: float = 1.0
    # learning
    lr: float = 0.02           # hidden OSSL rate
    lr_out: float = 0.1        # SL readout rate
    cc_weight: float = 1.0     # contrastive term weight
    pc_snapshot_frac: float = 0.5   # TS (fraction of T) at which tr_pc is latched
    wu_start_frac: float = 0.6      # WU runs on late TSs (traces must be formed)
    # sparsity
    sparsity: float = 0.8
    dense: bool = False        # dense baseline (Fig. 5/7 comparisons)
    dsst: DSSTConfig = dataclasses.field(default_factory=lambda: DSSTConfig(period=40, prune_frac=0.25))
    dsst_enabled: bool = True  # False = static sparse training baseline
    # gating
    gating: gating_lib.GatingConfig = dataclasses.field(default_factory=gating_lib.GatingConfig)

    def spec(self, fan_in: int) -> NMSpec:
        if self.dense:
            return NMSpec(n=4, m=4)  # degenerate: keep everything, 4 "groups"
        return paper_spec_4groups(fan_in, self.sparsity)

    @property
    def layer_fanins(self):
        return [self.n_in] + [self.n_hidden] * (self.n_layers - 1)


# ---------------------------------------------------------------------------
# parameters and state
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: SNNConfig) -> Dict[str, Any]:
    """Random weights at target sparsity from step 0 (sparse-to-sparse)."""
    keys = jax.random.split(rng, 2 * cfg.n_layers + 2)
    params: Dict[str, Any] = {"hidden": [], "readout": []}
    for l, fan_in in enumerate(cfg.layer_fanins):
        spec = cfg.spec(fan_in)
        w = jax.random.normal(keys[2 * l], (fan_in, cfg.n_hidden)) * (1.5 / jnp.sqrt(fan_in * spec.density))
        mask = random_unit_mask(keys[2 * l + 1], spec, fan_in, cfg.n_hidden)
        params["hidden"].append({"w": apply_mask(w, mask, spec), "mask": mask})
    for l in range(cfg.n_layers):  # bypass: every hidden layer feeds the output
        wo = jax.random.normal(keys[2 * cfg.n_layers + l % 2], (cfg.n_hidden, cfg.n_out)) * 0.05
        params["readout"].append(wo)
    return params


class LayerState(NamedTuple):
    v: jax.Array        # [B, N] membrane
    tr: jax.Array       # [B, N] current trace (WU slot)
    tr_pc: jax.Array    # [B, N] earlier-TS snapshot (PC slot)
    tr_cc: jax.Array    # [B, N] final trace of the previous sample (CC slot)


class NetState(NamedTuple):
    layers: Tuple[LayerState, ...]
    x_tr: jax.Array            # [B, K] input (pre-synaptic) trace
    gate: gating_lib.GatingState
    acc: Tuple[DSSTAccumulator, ...]
    sample_idx: jax.Array      # scalar int32


def init_state(cfg: SNNConfig, batch: int) -> NetState:
    mk = lambda n: LayerState(*(jnp.zeros((batch, n)) for _ in range(4)))
    layers = tuple(mk(cfg.n_hidden) for _ in range(cfg.n_layers))
    accs = []
    for fan_in in cfg.layer_fanins:
        spec = cfg.spec(fan_in)
        kb, j = spec.unit_counts(fan_in, cfg.n_hidden)
        accs.append(DSSTAccumulator.init(kb, j))
    return NetState(
        layers=layers,
        x_tr=jnp.zeros((batch, cfg.n_in)),
        gate=gating_lib.init_state(cfg.n_layers, cfg.gating),
        acc=tuple(accs),
        sample_idx=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# neuron dynamics (ref path; the Pallas kernel in kernels/lif mirrors this)
# ---------------------------------------------------------------------------

def lif_step(v, tr, current, *, alpha, beta, theta):
    """One LIF timestep with soft reset + trace decay. Returns (v', tr', s)."""
    v = alpha * v + current
    s = (v >= theta).astype(v.dtype)
    v = v - s * theta
    tr = beta * tr + s
    return v, tr, s


def surrogate_grad(v, *, theta, width):
    """Triangular STE (the chip's STE LUT for the non-derivative spike fn)."""
    return jnp.maximum(0.0, 1.0 - jnp.abs(v - theta) / (theta * width))


def _cos(a, b, eps=1e-6):
    num = (a * b).sum(-1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + eps
    return num / den


def _cos_grad(a, b, eps=1e-6):
    """d cos(a,b) / d a."""
    na = jnp.linalg.norm(a, axis=-1, keepdims=True) + eps
    nb = jnp.linalg.norm(b, axis=-1, keepdims=True) + eps
    c = ((a * b).sum(-1, keepdims=True)) / (na * nb)
    return b / (na * nb) - c * a / (na * na)


def ossl_modulator(tr, tr_pc, tr_cc, v, cfg: SNNConfig):
    """Third factor of the three-factor rule, from purely local quantities.

    Local loss  L = -cos(tr, tr_pc) + cc_weight * cos(tr, tr_cc):
    *predict* (stay similar to) the earlier-TS trace of the same sample,
    *contrast* against the previous sample's final trace. The modulator is
    -dL/dtr shaped through the spike-function surrogate. PC and CC run
    concurrently (no class-transition flag) — ElfCore §II-C.
    """
    g = _cos_grad(tr, tr_pc) - cfg.cc_weight * _cos_grad(tr, tr_cc)
    return g * surrogate_grad(v, theta=cfg.theta, width=cfg.surrogate_width)


# ---------------------------------------------------------------------------
# one sample (T timesteps), SI + WU concurrent, one lax.scan
# ---------------------------------------------------------------------------

class SampleMetrics(NamedTuple):
    logits: jax.Array          # [B, n_out] (final-TS readout)
    sop_forward: jax.Array     # synaptic ops on the forward path
    sop_wu: jax.Array          # weight-update MACs actually performed
    sop_wu_offered: jax.Array  # WU MACs before gating (for skip-rate)
    gate_open_frac: jax.Array  # fraction of (layer, TS) gates that fired
    local_loss: jax.Array     # mean OSSL loss over late TSs (learning signal)


def run_sample(
    params: Dict[str, Any],
    state: NetState,
    events: jax.Array,          # [T, B, n_in] binary spikes
    label: Optional[jax.Array],  # [B] int or None (inference)
    cfg: SNNConfig,
    *,
    learn: bool = True,
) -> Tuple[Dict[str, Any], NetState, SampleMetrics]:
    T, B, _ = events.shape
    specs = [cfg.spec(f) for f in cfg.layer_fanins]
    t_pc = int(cfg.t_steps * cfg.pc_snapshot_frac)
    t_wu = int(cfg.t_steps * cfg.wu_start_frac)

    def ts_body(carry, inp):
        t, s_in = inp["t"], inp["x"]
        layers, x_tr, gate_st, params_h, params_r = carry
        x_tr = cfg.beta * x_tr + s_in

        new_layers = []
        pre_spikes, pre_trace = s_in, x_tr
        sop_fwd = jnp.zeros(())
        sop_wu = jnp.zeros(())
        sop_wu_off = jnp.zeros(())
        gate_open = jnp.zeros(())
        local_loss = jnp.zeros(())
        new_params_h = []
        new_gate = []

        for l in range(cfg.n_layers):
            p = params_h[l]
            w_eff = p["w"]  # masked at write-time; stays masked
            current = pre_spikes @ w_eff
            st = layers[l]
            v, tr, s = lif_step(st.v, st.tr, current, alpha=cfg.alpha, beta=cfg.beta, theta=cfg.theta)
            tr_pc = jnp.where(t == t_pc, tr, st.tr_pc)

            # ---- OSSL three-factor WU, gated, concurrent with SI ----
            mod = ossl_modulator(tr, tr_pc, st.tr_cc, v, cfg)          # [B, N]
            ia = pre_spikes.mean()
            ss = _cos(tr, st.tr_cc).mean()
            open_, gate_l = gating_lib.gate_update(gate_st, l, ia, ss, cfg.gating)
            wu_on = open_ & (t >= t_wu) & jnp.asarray(learn)
            scale = jnp.where(wu_on, cfg.lr / B, 0.0)
            dw = scale * (pre_trace.T @ mod)                           # [K, N]
            mask_f = _dense_mask(p["mask"], specs[l], *p["w"].shape)
            w_new = p["w"] + dw * mask_f
            new_params_h.append({"w": w_new, "mask": p["mask"]})
            new_gate.append(gate_l)

            # ---- telemetry (energy model inputs) ----
            act_density = specs[l].density
            sop_fwd += pre_spikes.sum() * cfg.n_hidden * act_density
            offered = B * pre_trace.shape[1] * cfg.n_hidden * act_density
            sop_wu_off += offered * (t >= t_wu)
            sop_wu += offered * wu_on
            gate_open += open_.astype(jnp.float32)
            local_loss += (-_cos(tr, tr_pc) + cfg.cc_weight * _cos(tr, st.tr_cc)).mean() * (t >= t_wu)

            new_layers.append(LayerState(v, tr, tr_pc, st.tr_cc))
            pre_spikes, pre_trace = s, tr

        gate_st = gating_lib.merge(gate_st, new_gate)

        # readout (bypass: all hidden traces feed the output)
        logits = sum(new_layers[l].tr @ params_r[l] for l in range(cfg.n_layers))
        out = dict(logits=logits, sop_fwd=sop_fwd, sop_wu=sop_wu,
                   sop_wu_off=sop_wu_off, gate=gate_open / cfg.n_layers,
                   loss=local_loss / cfg.n_layers)
        return (tuple(new_layers), x_tr, gate_st, new_params_h, params_r), out

    carry0 = (state.layers, state.x_tr, state.gate, list(params["hidden"]), list(params["readout"]))
    xs = {"t": jnp.arange(T), "x": events}
    (layers, x_tr, gate_st, ph, pr), outs = jax.lax.scan(ts_body, carry0, xs)

    logits = outs["logits"][-1]

    # ---- SL delta rule on the output layer (labels only used here) ----
    if label is not None and learn:
        err = jax.nn.one_hot(label, cfg.n_out) - jax.nn.softmax(logits)   # [B, n_out]
        pr = [pr[l] + (cfg.lr_out / B) * (layers[l].tr.T @ err) for l in range(cfg.n_layers)]

    # ---- DSST statistics write-back + (maybe) connectivity update ----
    new_acc = []
    new_hidden = []
    pre_traces = [x_tr] + [layers[l].tr for l in range(cfg.n_layers - 1)]
    for l in range(cfg.n_layers):
        spec = specs[l]
        pre_mag = jnp.abs(pre_traces[l]).mean(0)                      # [K]
        mod = ossl_modulator(layers[l].tr, layers[l].tr_pc, layers[l].tr_cc,
                             layers[l].v, cfg)
        post_mag = jnp.abs(mod).mean(0)                               # [N]
        kb = spec.unit_counts(*ph[l]["w"].shape)[0]
        pre_units = pre_mag.reshape(kb, -1).sum(-1)
        acc = state.acc[l].update(pre_units, post_mag)
        w, mask = ph[l]["w"], ph[l]["mask"]
        if cfg.dsst_enabled and not cfg.dense and learn:
            def do(args):
                w, mask, acc = args
                wsc = unit_scores(w, spec, *w.shape, reduce="abs_sum")
                k = cfg.dsst.k_per_group(spec)
                nm, _ = prune_regrow_factored(mask, wsc, acc.pre, acc.post, spec, k)
                return (apply_dsst_to_weights(w, mask, nm, spec), nm,
                        DSSTAccumulator.init(acc.pre.shape[0], acc.post.shape[0]))

            def skip(args):
                return args

            w, mask, acc = jax.lax.cond(
                cfg.dsst.is_update_step(state.sample_idx), do, skip, (w, mask, acc))
        new_acc.append(acc)
        new_hidden.append({"w": w, "mask": mask})

    # ---- roll the CC slot: final trace of this sample becomes the negative ----
    final_layers = tuple(
        LayerState(v=jnp.zeros_like(st.v), tr=jnp.zeros_like(st.tr),
                   tr_pc=jnp.zeros_like(st.tr_pc), tr_cc=st.tr)
        for st in layers)

    new_params = {"hidden": new_hidden, "readout": pr}
    new_state = NetState(layers=final_layers, x_tr=jnp.zeros_like(x_tr),
                         gate=gate_st, acc=tuple(new_acc),
                         sample_idx=state.sample_idx + 1)
    metrics = SampleMetrics(
        logits=logits,
        sop_forward=outs["sop_fwd"].sum(),
        sop_wu=outs["sop_wu"].sum(),
        sop_wu_offered=outs["sop_wu_off"].sum(),
        gate_open_frac=outs["gate"].mean(),
        local_loss=outs["loss"].sum() / max(1, T - t_wu),
    )
    return new_params, new_state, metrics


def _dense_mask(unit_mask, spec: NMSpec, k, o):
    from .sparsity import expand_unit_mask
    return expand_unit_mask(unit_mask, spec, k, o).astype(jnp.float32)


# ---------------------------------------------------------------------------
# chunked streaming step (serving path)
# ---------------------------------------------------------------------------
#
# ``run_sample`` integrates one aligned batch over a full sample and shares
# gating / WU statistics across the batch. Serving needs the opposite: many
# *independent* event streams multiplexed onto the slots of one jitted step,
# each resuming from carried state at an arbitrary position inside its own
# T-step window. ``run_chunk`` therefore keeps every quantity per-slot
# separable:
#
# * gating IA/SS and the adaptive SS threshold are per-stream (``ss_mean``
#   is [S, L], not [L]);
# * weight updates go into per-stream deltas over a frozen shared base
#   (``w_eff[s] = w_base + delta[s]``), so one stream's adaptation never
#   leaks into another slot;
# * per-slot window counters (``t_in_window``) decide PC-snapshot latching,
#   the WU window, and the CC roll at window end — streams need not be
#   aligned;
# * a ``valid [C, S]`` mask makes ragged chunks and idle slots exact no-ops
#   (state bit-identical, zero telemetry).
#
# This separability is what makes slot multiplexing sound; asserted by the
# interleaved-vs-solo equivalence test in tests/test_serving_streams.py.


class StreamState(NamedTuple):
    layers: Tuple[LayerState, ...]   # leaves [S, N]
    x_tr: jax.Array                  # [S, n_in]
    ss_mean: jax.Array               # [S, L] per-stream adaptive SS threshold
    t_in_window: jax.Array           # [S] int32, position inside the T-window
    sample_idx: jax.Array            # [S] int32, windows completed


def init_stream_state(cfg: SNNConfig, n_slots: int) -> StreamState:
    mk = lambda n: LayerState(*(jnp.zeros((n_slots, n)) for _ in range(4)))
    return StreamState(
        layers=tuple(mk(cfg.n_hidden) for _ in range(cfg.n_layers)),
        x_tr=jnp.zeros((n_slots, cfg.n_in)),
        ss_mean=jnp.full((n_slots, cfg.n_layers), cfg.gating.ss_init,
                         dtype=jnp.float32),   # explicit dtype: weak-typed
        # init would force one retrace when the first chunk strong-types it
        t_in_window=jnp.zeros((n_slots,), jnp.int32),
        sample_idx=jnp.zeros((n_slots,), jnp.int32),
    )


def init_stream_deltas(cfg: SNNConfig, n_slots: int) -> Tuple[jax.Array, ...]:
    """Per-stream weight deltas over the frozen shared base, one per layer."""
    return tuple(jnp.zeros((n_slots, fan_in, cfg.n_hidden))
                 for fan_in in cfg.layer_fanins)


class ChunkMetrics(NamedTuple):
    logits: jax.Array          # [C, S, n_out] per-timestep readout
    window_end: jax.Array      # [C, S] bool: logits here close a T-window
    sop_forward: jax.Array     # [S]
    sop_wu: jax.Array          # [S]
    sop_wu_offered: jax.Array  # [S]
    gate_opened: jax.Array     # [S, L]
    gate_offered: jax.Array    # [S, L]
    local_loss: jax.Array      # [S] summed OSSL loss over late TSs
    steps: jax.Array           # [S] valid timesteps processed


def run_chunk(
    params: Dict[str, Any],
    deltas: Tuple[jax.Array, ...],
    state: StreamState,
    events: jax.Array,          # [C, S, n_in] binary spikes
    valid: jax.Array,           # [C, S] bool — ragged chunks / idle slots
    cfg: SNNConfig,
    *,
    learn: bool = True,
) -> Tuple[Tuple[jax.Array, ...], StreamState, ChunkMetrics]:
    """Advance S independent streams by up to C timesteps each.

    Resumes from carried ``state``; base ``params`` are frozen, adaptation
    accumulates in per-stream ``deltas``.
    """
    specs = [cfg.spec(f) for f in cfg.layer_fanins]
    t_pc = int(cfg.t_steps * cfg.pc_snapshot_frac)
    t_wu = int(cfg.t_steps * cfg.wu_start_frac)
    g = cfg.gating
    masks_f = [_dense_mask(params["hidden"][l]["mask"], specs[l],
                           *params["hidden"][l]["w"].shape)
               for l in range(cfg.n_layers)]

    def ts_body(carry, inp):
        layers, x_tr, ss_mean, t_win, samp, dls = carry
        x, val = inp["x"], inp["v"]                  # [S, n_in], [S] bool
        valf = val.astype(x.dtype)[:, None]
        x = x * valf
        x_tr = jnp.where(val[:, None], cfg.beta * x_tr + x, x_tr)

        pre_spikes, pre_trace = x, x_tr
        new_layers, new_dls = [], []
        ss_cols, open_cols = [], []
        sop_fwd = jnp.zeros(events.shape[1])
        sop_wu = jnp.zeros(events.shape[1])
        sop_wu_off = jnp.zeros(events.shape[1])
        loss = jnp.zeros(events.shape[1])

        for l in range(cfg.n_layers):
            st = layers[l]
            w = params["hidden"][l]["w"]
            current = pre_spikes @ w + jnp.einsum("sk,skn->sn", pre_spikes, dls[l])
            v, tr, s = lif_step(st.v, st.tr, current,
                                alpha=cfg.alpha, beta=cfg.beta, theta=cfg.theta)
            tr_pc = jnp.where((t_win == t_pc)[:, None], tr, st.tr_pc)

            # ---- per-stream gated OSSL three-factor update ----
            mod = ossl_modulator(tr, tr_pc, st.tr_cc, v, cfg)      # [S, N]
            ia = pre_spikes.mean(-1)                               # [S]
            ss = _cos(tr, st.tr_cc)                                # [S]
            thr = g.ss_scale * ss_mean[:, l]
            open_ = (ia > g.theta_ia) & (ss < thr) if g.enabled \
                else jnp.ones_like(val)
            open_ = open_ & val
            wu_on = open_ & (t_win >= t_wu) & jnp.asarray(learn)
            scale = jnp.where(wu_on, cfg.lr, 0.0)[:, None, None]
            dw = scale * pre_trace[:, :, None] * mod[:, None, :]   # [S, K, N]
            new_dls.append(dls[l] + dw * masks_f[l][None])
            new_mean = (1 - g.ss_rho) * ss_mean[:, l] + g.ss_rho * jnp.abs(ss)
            ss_cols.append(jnp.where(val, new_mean, ss_mean[:, l]))
            open_cols.append(open_)

            # ---- per-slot telemetry ----
            act_density = specs[l].density
            sop_fwd += pre_spikes.sum(-1) * cfg.n_hidden * act_density
            offered = pre_trace.shape[1] * cfg.n_hidden * act_density
            late = (t_win >= t_wu) & val
            sop_wu_off += offered * late
            sop_wu += offered * wu_on
            loss += (-_cos(tr, tr_pc) + cfg.cc_weight * _cos(tr, st.tr_cc)) * late

            # invalid slots keep their exact previous state
            v = jnp.where(val[:, None], v, st.v)
            tr = jnp.where(val[:, None], tr, st.tr)
            tr_pc = jnp.where(val[:, None], tr_pc, st.tr_pc)
            new_layers.append(LayerState(v, tr, tr_pc, st.tr_cc))
            pre_spikes, pre_trace = s * valf, tr

        # readout (bypass): all hidden traces feed the output
        logits = sum(new_layers[l].tr @ params["readout"][l]
                     for l in range(cfg.n_layers))

        # ---- per-slot window roll: final trace becomes the CC negative ----
        at_end = val & (t_win == cfg.t_steps - 1)
        endf = at_end[:, None]
        rolled = []
        for st in new_layers:
            rolled.append(LayerState(
                v=jnp.where(endf, 0.0, st.v),
                tr=jnp.where(endf, 0.0, st.tr),
                tr_pc=jnp.where(endf, 0.0, st.tr_pc),
                tr_cc=jnp.where(endf, st.tr, st.tr_cc)))
        x_tr = jnp.where(endf, 0.0, x_tr)
        samp = samp + at_end.astype(jnp.int32)
        t_win = jnp.where(val, (t_win + 1) % cfg.t_steps, t_win)

        out = dict(logits=logits, at_end=at_end, sop_fwd=sop_fwd,
                   sop_wu=sop_wu, sop_wu_off=sop_wu_off,
                   opened=jnp.stack(open_cols, -1).astype(jnp.float32),
                   offered=jnp.tile(val.astype(jnp.float32)[:, None],
                                    (1, cfg.n_layers)),
                   loss=loss / cfg.n_layers, steps=val.astype(jnp.float32))
        carry = (tuple(rolled), x_tr, jnp.stack(ss_cols, -1), t_win, samp,
                 tuple(new_dls))
        return carry, out

    carry0 = (state.layers, state.x_tr, state.ss_mean, state.t_in_window,
              state.sample_idx, tuple(deltas))
    xs = {"x": events, "v": valid}
    (layers, x_tr, ss_mean, t_win, samp, dls), outs = jax.lax.scan(
        ts_body, carry0, xs)

    new_state = StreamState(layers=layers, x_tr=x_tr, ss_mean=ss_mean,
                            t_in_window=t_win, sample_idx=samp)
    metrics = ChunkMetrics(
        logits=outs["logits"],
        window_end=outs["at_end"],
        sop_forward=outs["sop_fwd"].sum(0),
        sop_wu=outs["sop_wu"].sum(0),
        sop_wu_offered=outs["sop_wu_off"].sum(0),
        gate_opened=outs["opened"].sum(0),
        gate_offered=outs["offered"].sum(0),
        local_loss=outs["loss"].sum(0),
        steps=outs["steps"].sum(0),
    )
    return dls, new_state, metrics


# jit entry points -----------------------------------------------------------

def make_train_fn(cfg: SNNConfig):
    @jax.jit
    def step(params, state, events, label):
        return run_sample(params, state, events, label, cfg, learn=True)
    return step


def make_eval_fn(cfg: SNNConfig):
    @jax.jit
    def step(params, state, events):
        _, state, m = run_sample(params, state, events, None, cfg, learn=False)
        return state, m
    return step


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return (jnp.argmax(logits, -1) == labels).mean()
