"""ElfCore's spiking network — the paper-faithful reproduction (floor).

Implements the chip of Fig. 2 as a pure-JAX simulator:

* (512)-512-512-16 topology, hidden LIF layers (each = 4 N:M groups /
  "PEs"), **bypass connections** from every hidden layer to the output, so
  depth can be varied for the Fig. 7 depth study.
* **Neuron SRAM with three traces per neuron**: the current TS's trace (used
  by WU), a snapshot from an earlier TS of the same sample (used by
  predictive coding), and the trace at the final TS of the *previous* sample
  (used by contrastive coding).
* **OSSL**: per-layer three-factor updates with concurrent PC + CC — no
  labels, no backprop, all hidden layers update in parallel with the forward
  pass (WU-locking removed; §III's 67–72 % TS-length cut).
* **SL output layer**: delta-rule readout (the only place labels enter).
* **DSST**: connectivity prune/regrow every ``period`` samples from the
  factorized |pre|·|post| statistics written back during WU.
* **Activity-dependent WU gating**: IA vs a global threshold, SS vs an
  adaptive per-layer threshold (core/gating.py).
* SOP / WU / memory-access counters feed the energy model (core/energy.py).

The per-timestep datapath lives in **core/engine.py** — one layer-stacked
``layer_timestep`` scanned over a ``[L, ...]`` layer axis, shared by the
training path (:func:`run_sample`) and the serving path (:func:`run_chunk`),
with a pluggable ``ref``/``pallas`` backend seam. This module owns the
network-level layouts and the per-sample bookkeeping around that engine:
parameter/state initialisation, the SL readout delta rule, DSST events, and
the CC-slot roll.

Parameter layout (stacked; one leaf per role, leading layer axis)::

    params = {
      "hidden": {"w":    f32[L, Kmax, n_hidden],   # masked base weights
                 "mask": bool[L, KBmax, J]},       # N:M unit masks
      "readout": f32[L, n_hidden, n_out],          # bypass readouts
    }

``engine.hidden_slice(params, l, cfg)`` gives the per-layer view;
``engine.stack_params`` migrates PR-1 (list-of-dicts) checkpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import engine
from . import gating as gating_lib
from . import topology as topology_lib
from .dsst import DSSTAccumulator, DSSTConfig
from .engine import (LayerState, _cos, lif_step, ossl_modulator,  # noqa: F401
                     surrogate_grad)
from .sparsity import NMSpec, apply_mask, paper_spec_4groups, random_unit_mask


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SNNConfig:
    n_in: int = 512
    n_hidden: int = 512
    n_layers: int = 2          # hidden layers (bypass keeps output wired)
    n_out: int = 16
    t_steps: int = 50          # timesteps per sample
    # neuron dynamics
    alpha: float = 0.9         # membrane decay
    beta: float = 0.85         # trace decay
    theta: float = 1.0         # firing threshold (soft reset)
    surrogate_width: float = 1.0
    # learning
    lr: float = 0.02           # hidden OSSL rate
    lr_out: float = 0.1        # SL readout rate
    cc_weight: float = 1.0     # contrastive term weight
    pc_snapshot_frac: float = 0.5   # TS (fraction of T) at which tr_pc is latched
    wu_start_frac: float = 0.6      # WU runs on late TSs (traces must be formed)
    # sparsity
    sparsity: float = 0.8
    dense: bool = False        # dense baseline (Fig. 5/7 comparisons)
    dsst: DSSTConfig = dataclasses.field(default_factory=lambda: DSSTConfig(period=40, prune_frac=0.25))
    dsst_enabled: bool = True  # False = static sparse training baseline
    # gating
    gating: gating_lib.GatingConfig = dataclasses.field(default_factory=gating_lib.GatingConfig)
    # compute backend for the timestep engine (core/engine.py):
    # "ref" (jnp), "pallas" (kernels; real Pallas on TPU), "pallas-interpret"
    # (kernels emulated everywhere — the CPU-CI parity mode).
    backend: str = "ref"

    def spec(self, fan_in: int) -> NMSpec:
        if self.dense:
            return NMSpec(n=4, m=4)  # degenerate: keep everything, 4 "groups"
        return paper_spec_4groups(fan_in, self.sparsity)

    @property
    def layer_fanins(self):
        return [self.n_in] + [self.n_hidden] * (self.n_layers - 1)


# ---------------------------------------------------------------------------
# parameters and state
# ---------------------------------------------------------------------------

def init_params(rng: jax.Array, cfg: SNNConfig) -> Dict[str, Any]:
    """Random weights at target sparsity from step 0 (sparse-to-sparse).

    One key per (layer weight, layer mask, layer readout) — readout layers
    no longer share initial weights at any depth.
    """
    geo = engine.geometry(cfg)
    keys = jax.random.split(rng, 3 * cfg.n_layers)
    ws, masks = [], []
    for l, fan_in in enumerate(cfg.layer_fanins):
        spec = cfg.spec(fan_in)
        w = jax.random.normal(keys[2 * l], (fan_in, cfg.n_hidden)) * (1.5 / jnp.sqrt(fan_in * spec.density))
        mask = random_unit_mask(keys[2 * l + 1], spec, fan_in, cfg.n_hidden)
        ws.append(engine._pad_rows(apply_mask(w, mask, spec), geo.k_max))
        masks.append(engine._pad_rows(mask, geo.k_max))
    readout = jnp.stack([
        jax.random.normal(keys[2 * cfg.n_layers + l],
                          (cfg.n_hidden, cfg.n_out)) * 0.05
        for l in range(cfg.n_layers)])
    return {"hidden": {"w": jnp.stack(ws), "mask": jnp.stack(masks)},
            "readout": readout}


class NetState(NamedTuple):
    layers: LayerState         # leaves [L, B, N]
    x_tr: jax.Array            # [B, K] input (pre-synaptic) trace
    gate: gating_lib.GatingState
    acc: Tuple[DSSTAccumulator, ...]
    sample_idx: jax.Array      # scalar int32


def init_state(cfg: SNNConfig, batch: int) -> NetState:
    layers = LayerState(*(jnp.zeros((cfg.n_layers, batch, cfg.n_hidden))
                          for _ in range(4)))
    accs = []
    for fan_in in cfg.layer_fanins:
        spec = cfg.spec(fan_in)
        kb, j = spec.unit_counts(fan_in, cfg.n_hidden)
        accs.append(DSSTAccumulator.init(kb, j))
    return NetState(
        layers=layers,
        x_tr=jnp.zeros((batch, cfg.n_in)),
        gate=gating_lib.init_state(cfg.n_layers, cfg.gating),
        acc=tuple(accs),
        sample_idx=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# one sample (T timesteps), SI + WU concurrent, one lax.scan over the engine
# ---------------------------------------------------------------------------

class SampleMetrics(NamedTuple):
    logits: jax.Array          # [B, n_out] (final-TS readout)
    sop_forward: jax.Array     # synaptic ops on the forward path
    sop_wu: jax.Array          # weight-update MACs actually performed
    sop_wu_offered: jax.Array  # WU MACs before gating (for skip-rate)
    gate_open_frac: jax.Array  # fraction of (layer, TS) gates that fired
    local_loss: jax.Array     # mean OSSL loss over late TSs (learning signal)


def run_sample(
    params: Dict[str, Any],
    state: NetState,
    events: jax.Array,          # [T, B, n_in] binary spikes
    label: Optional[jax.Array],  # [B] int or None (inference)
    cfg: SNNConfig,
    *,
    learn: bool = True,
) -> Tuple[Dict[str, Any], NetState, SampleMetrics]:
    T, B, _ = events.shape
    backend = engine.make_backend(cfg)
    t_wu = int(cfg.t_steps * cfg.wu_start_frac)
    masks = params["hidden"]["mask"]
    wrep = engine.prepare_weights(params["hidden"]["w"], masks, cfg, backend)

    wrep, layers, x_tr, gate_st, outs = engine.scan_sample(
        wrep, params["readout"], state.layers, state.x_tr,
        state.gate, events, cfg, backend, learn)
    w_stacked = engine.finalize_weights(wrep, cfg, backend)

    logits = outs["logits"][-1]

    # ---- SL delta rule on the output layer (labels only used here) ----
    pr = params["readout"]
    if label is not None and learn:
        err = jax.nn.one_hot(label, cfg.n_out) - jax.nn.softmax(logits)   # [B, n_out]
        pr = pr + (cfg.lr_out / B) * jnp.einsum("lbn,bo->lno", layers.tr, err)

    # ---- DSST statistics write-back + (maybe) stacked connectivity epoch ----
    # Accumulator updates stay per layer (unit counts differ when fan-ins
    # do); the prune/regrow epoch itself is ONE call into
    # ``topology.topology_epoch`` — the identical code path the serving
    # topology service runs between grid steps, honoring the decay schedule
    # through the traced sample index (lax.switch over static k levels).
    pre_traces = [x_tr] + [layers.tr[l] for l in range(cfg.n_layers - 1)]
    new_acc = []
    for l, fan_in in enumerate(cfg.layer_fanins):
        spec = cfg.spec(fan_in)
        kb, jj = spec.unit_counts(fan_in, cfg.n_hidden)
        pre_mag = jnp.abs(pre_traces[l]).mean(0)                      # [K]
        mod = ossl_modulator(layers.tr[l], layers.tr_pc[l], layers.tr_cc[l],
                             layers.v[l], cfg)
        post_mag = jnp.abs(mod).mean(0)                               # [N]
        pre_units = pre_mag.reshape(kb, -1).sum(-1)
        new_acc.append(state.acc[l].update(pre_units, post_mag))

    new_params = {"hidden": {"w": w_stacked, "mask": masks}, "readout": pr}
    new_acc = tuple(new_acc)
    if cfg.dsst_enabled and not cfg.dense and learn:
        pre_stacked = jnp.stack([engine._pad_rows(a.pre, masks.shape[1])
                                 for a in new_acc])                   # [L, KBmax]
        post_stacked = jnp.stack([a.post for a in new_acc])           # [L, J]

        def do(args):
            p, accs = args
            p2, _ = topology_lib.topology_epoch(p, pre_stacked, post_stacked,
                                                cfg, step=state.sample_idx)
            fresh = tuple(DSSTAccumulator.init(a.pre.shape[0], a.post.shape[0])
                          for a in accs)
            return p2, fresh

        def skip(args):
            return args

        new_params, new_acc = jax.lax.cond(
            cfg.dsst.is_update_step(state.sample_idx), do, skip,
            (new_params, new_acc))

    # ---- roll the CC slot: final trace of this sample becomes the negative ----
    final_layers = LayerState(
        v=jnp.zeros_like(layers.v), tr=jnp.zeros_like(layers.tr),
        tr_pc=jnp.zeros_like(layers.tr_pc), tr_cc=layers.tr)
    new_state = NetState(layers=final_layers, x_tr=jnp.zeros_like(x_tr),
                         gate=gate_st, acc=new_acc,
                         sample_idx=state.sample_idx + 1)
    metrics = SampleMetrics(
        logits=logits,
        sop_forward=outs["sop_fwd"].sum(),
        sop_wu=outs["sop_wu"].sum(),
        sop_wu_offered=outs["sop_wu_off"].sum(),
        gate_open_frac=outs["gate"].mean(),
        local_loss=outs["loss"].sum() / max(1, T - t_wu),
    )
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# chunked streaming step (serving path)
# ---------------------------------------------------------------------------
#
# ``run_sample`` integrates one aligned batch over a full sample and shares
# gating / WU statistics across the batch. Serving needs the opposite: many
# *independent* event streams multiplexed onto the slots of one jitted step,
# each resuming from carried state at an arbitrary position inside its own
# T-step window. ``run_chunk`` therefore drives the same engine in its
# per-slot mode:
#
# * gating IA/SS and the adaptive SS threshold are per-stream (``ss_mean``
#   is [S, L], not [L]);
# * weight updates go into per-stream deltas over a frozen shared base
#   (``w_eff[s] = w_base + delta[s]``), so one stream's adaptation never
#   leaks into another slot;
# * per-slot window counters (``t_in_window``) decide PC-snapshot latching,
#   the WU window, and the CC roll at window end — streams need not be
#   aligned;
# * a ``valid [C, S]`` mask makes ragged chunks and idle slots exact no-ops
#   (state bit-identical, zero telemetry).
#
# This separability is what makes slot multiplexing sound; asserted by the
# interleaved-vs-solo equivalence test in tests/test_serving_streams.py, and
# the engine-sharing by the train↔serve trajectory-equivalence test in
# tests/test_train_serve_equivalence.py.


class StreamState(NamedTuple):
    layers: LayerState               # leaves [S, L, N] (slot axis leads —
    #   lane surgery in serving/session.py slices the leading axis of every
    #   leaf; the engine transposes to its [L, S, N] layout at the
    #   run_chunk boundary)
    x_tr: jax.Array                  # [S, n_in]
    ss_mean: jax.Array               # [S, L] per-stream adaptive SS threshold
    t_in_window: jax.Array           # [S] int32, position inside the T-window
    sample_idx: jax.Array            # [S] int32, windows completed


def init_stream_state(cfg: SNNConfig, n_slots: int) -> StreamState:
    layers = LayerState(*(jnp.zeros((n_slots, cfg.n_layers, cfg.n_hidden))
                          for _ in range(4)))
    return StreamState(
        layers=layers,
        x_tr=jnp.zeros((n_slots, cfg.n_in)),
        ss_mean=jnp.full((n_slots, cfg.n_layers), cfg.gating.ss_init,
                         dtype=jnp.float32),   # explicit dtype: weak-typed
        # init would force one retrace when the first chunk strong-types it
        t_in_window=jnp.zeros((n_slots,), jnp.int32),
        sample_idx=jnp.zeros((n_slots,), jnp.int32),
    )


def init_stream_deltas(cfg: SNNConfig, n_slots: int,
                       compact: Optional[bool] = None) -> jax.Array:
    """Per-stream weight deltas over the frozen shared base (slot axis
    leads for lane surgery).

    Default (``compact=None``) is layout auto-selection: the compact N:M
    tensor ``[S, L, J, T, bk, bo]`` — storage scales with density, not
    ``K·N`` — whenever the layer geometry is uniform, else the dense
    ``[S, L, Kmax, n_hidden]`` fallback. Pass ``compact=False`` to force
    the dense baseline layout (the A/B reference path).
    """
    geo = engine.geometry(cfg)
    if compact is None:
        compact = geo.uniform
    if compact:
        if not geo.uniform:
            raise ValueError(
                "compact stream deltas require uniform layer fan-in "
                f"(got {geo.fanins}); pass compact=False")
        spec = cfg.spec(geo.fanins[0])
        jj = cfg.n_hidden // spec.out_tile
        return jnp.zeros((n_slots, cfg.n_layers, jj, engine.compact_kept(cfg),
                          spec.block, spec.out_tile))
    return jnp.zeros((n_slots, cfg.n_layers, geo.k_max, cfg.n_hidden))


def serving_params(params: Dict[str, Any], cfg: SNNConfig) -> Dict[str, Any]:
    """Dense training params -> the mask-free serving weight rep.

    ``{"wc" [L,J,T,bk,bo], "idx" [L,J,T], "readout" [L,N,n_out]}`` — what a
    compact-mode :func:`run_chunk` consumes. Built on the host (outside
    jit) at fleet construction and at topology epoch boundaries, so neither
    the dense weights nor the dense mask ever enter the serving jaxpr.
    """
    wrep = engine.compact_weights(params["hidden"]["w"],
                                  params["hidden"]["mask"], cfg)
    return {**wrep, "readout": params["readout"]}


class ChunkMetrics(NamedTuple):
    """Per-chunk serving metrics; every per-stream leaf keeps its slot axis.

    The two DSST factor fields are ``None`` when the chunk ran with
    ``want_factors=False`` (frozen-topology fleets — the accumulators are
    compiled out of the scan, see ``engine.scan_chunk``). Out of
    :func:`run_chunk` they are per-slot ``[S, L, ·]``; the serving layer
    (``serving/adapt.make_chunk_fn``) slot-reduces them on device with the
    order-fixed ``engine.ordered_slot_sum`` before they leave the jit, so
    callers of the jitted chunk fn see ``[L, Kmax]`` / ``[L, N]`` instead.
    """
    logits: jax.Array          # [C, S, n_out] per-timestep readout
    window_end: jax.Array      # [C, S] bool: logits here close a T-window
    sop_forward: jax.Array     # [S]
    sop_wu: jax.Array          # [S]
    sop_wu_offered: jax.Array  # [S]
    gate_opened: jax.Array     # [S, L]
    gate_offered: jax.Array    # [S, L]
    local_loss: jax.Array      # [S] summed OSSL loss over late TSs
    steps: jax.Array           # [S] valid timesteps processed
    pre_mag: Optional[jax.Array]   # [S, L, Kmax] summed |pre trace|
    #   (DSST factor; [L, Kmax] past the serving chunk fn; None when off)
    post_mag: Optional[jax.Array]  # [S, L, N] summed |OSSL modulator|
    #   (DSST factor; [L, N] past the serving chunk fn; None when off)


def _to_engine(tree):
    """Slot-leading public layout -> layer-leading engine layout."""
    return jax.tree_util.tree_map(lambda a: jnp.swapaxes(a, 0, 1), tree)


def run_chunk(
    params: Dict[str, Any],
    deltas: jax.Array,          # compact [S,L,J,T,bk,bo] | dense [S,L,Kmax,N]
    state: StreamState,
    events: jax.Array,          # [C, S, n_in] binary spikes
    valid: jax.Array,           # [C, S] bool — ragged chunks / idle slots
    cfg: SNNConfig,
    *,
    learn: bool = True,
    want_factors: bool = True,
) -> Tuple[jax.Array, StreamState, ChunkMetrics]:
    """Advance S independent streams by up to C timesteps each.

    Args:
      params:  frozen shared base — either the dense training layout
        (stacked ``hidden/{w,mask}`` + readout) or the mask-free serving
        rep from :func:`serving_params` (``{"wc", "idx", "readout"}``).
      deltas:  per-stream adaptation, slot-leading — compact
        ``[S, L, J, T, bk, bo]`` (the hot-path default) or dense
        ``[S, L, Kmax, n_hidden]`` (the A/B baseline); the layout is
        inferred from the rank.
      state:   carried :class:`StreamState` (slot-leading leaves).
      events:  ``[C, S, n_in]`` binary spikes.
      valid:   ``[C, S]`` bool — ragged chunks / idle slots are exact no-ops.
      learn:   gate the per-stream OSSL delta updates on/off.
      want_factors: static; False compiles the DSST ``pre_mag``/``post_mag``
        accumulators out of the chunk scan and returns them as ``None`` —
        the right mode for fleets whose topology never evolves.

    With compact deltas the whole chunk runs on the compact layout: the
    forward current goes through ``nm_spmm``, the per-stream WU scatters
    only into kept blocks, and no dense mask or ``[S, L, K, N]`` leaf
    appears in the jaxpr (asserted by ``tests/test_compact_serving.py``).

    Returns ``(deltas', state', metrics)``: same shapes/dtypes in and out,
    so the caller can jit once and stream forever.
    """
    backend = engine.make_backend(cfg)
    compact = deltas.ndim == 6
    if "wc" in params:               # mask-free serving rep
        if not compact:
            raise ValueError("the mask-free serving params carry no dense "
                             "mask, so dense [S, L, K, N] deltas cannot be "
                             "applied; use compact deltas "
                             "(init_stream_deltas default)")
        wrep = {"wc": params["wc"], "idx": params["idx"]}
    else:
        masks = params["hidden"]["mask"]
        if compact:
            wrep = engine.compact_weights(params["hidden"]["w"], masks, cfg)
        else:
            wrep = engine.prepare_weights(params["hidden"]["w"], masks, cfg,
                                          backend, include_mask=True)

    (layers, x_tr, ss_mean, t_win, samp, dls, *accs), outs = \
        engine.scan_chunk(
            wrep, params["readout"], _to_engine(deltas),
            _to_engine(state.layers), state.x_tr, state.ss_mean.T,
            state.t_in_window, state.sample_idx, events, valid, cfg, backend,
            learn, want_factors)

    new_state = StreamState(layers=_to_engine(layers), x_tr=x_tr,
                            ss_mean=ss_mean.T, t_in_window=t_win,
                            sample_idx=samp)
    metrics = ChunkMetrics(
        logits=outs["logits"],
        window_end=outs["at_end"],
        sop_forward=outs["sop_fwd"].sum(0),
        sop_wu=outs["sop_wu"].sum(0),
        sop_wu_offered=outs["sop_wu_off"].sum(0),
        gate_opened=outs["opened"].sum(0),
        gate_offered=outs["offered"].sum(0),
        local_loss=outs["loss"].sum(0),
        steps=outs["steps"].sum(0),
        pre_mag=_to_engine(accs[0]) if accs else None,
        post_mag=_to_engine(accs[1]) if accs else None,
    )
    # slot-separability contract (backs the slot-axis shard_map in serving):
    # metric reductions run over time only — the S axis survives everywhere
    S = events.shape[1]
    assert metrics.logits.shape[1] == S, metrics.logits.shape
    assert metrics.window_end.shape == events.shape[:2], metrics.window_end.shape
    for leaf in (metrics.sop_forward, metrics.sop_wu, metrics.sop_wu_offered,
                 metrics.local_loss, metrics.steps):
        assert leaf.shape == (S,), leaf.shape
    assert metrics.gate_opened.shape == metrics.gate_offered.shape \
        == (S, cfg.n_layers), metrics.gate_opened.shape
    if want_factors:
        assert metrics.pre_mag.shape[:2] == (S, cfg.n_layers), \
            metrics.pre_mag.shape
        assert metrics.post_mag.shape == (S, cfg.n_layers, cfg.n_hidden), \
            metrics.post_mag.shape
    else:
        assert metrics.pre_mag is None and metrics.post_mag is None
    return _to_engine(dls), new_state, metrics


# jit entry points -----------------------------------------------------------

def make_train_fn(cfg: SNNConfig):
    @jax.jit
    def step(params, state, events, label):
        return run_sample(params, state, events, label, cfg, learn=True)
    return step


def make_eval_fn(cfg: SNNConfig):
    @jax.jit
    def step(params, state, events):
        _, state, m = run_sample(params, state, events, None, cfg, learn=False)
        return state, m
    return step


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return (jnp.argmax(logits, -1) == labels).mean()
