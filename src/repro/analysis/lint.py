"""Host-path lint: AST rules for the bug classes previous PRs fixed by hand.

``python -m repro.analysis.lint`` walks ``src/repro``, ``docs/`` and
``README.md`` and applies custom rules that encode this repo's host-side
discipline — the things a generic linter cannot know:

====== ====================================================================
rule   what it catches
====== ====================================================================
SYNC01 hidden host<->device syncs in serving hot phases: ``.item()``,
       ``jax.device_get`` / ``block_until_ready``, and ``np.asarray`` /
       ``float()`` / ``int()`` applied to device-state expressions inside
       stage/poll/dispatch-phase functions. Retire is the one sanctioned
       wait point; everything else must stay asynchronous or the staging
       pipeline's overlap is silently destroyed.
OBS01  unbounded container growth in obs/telemetry: a ``self.x = []`` /
       ``{}`` (or ``deque()`` without ``maxlen``) that other methods
       append to / insert into. The PR-6 ``step_latencies_s`` bug class —
       per-step state must be O(1) in steps (bounded ring or histogram).
OBS02  mutation of shared obs state outside its lock: in a class that owns
       a ``_lock``/``lock``, any ``self.*`` mutation outside ``__init__``
       must sit lexically inside ``with self._lock:``.
HOST01 module-level ``jax`` / ``jax.numpy`` imports in host-only modules
       (obs/, staging, telemetry, stream sources, this package): these
       modules are imported by pure-host tooling and must not drag in a
       device runtime.
DOC01  docs code fences that dodge the executable-docs tripwire: a fenced
       block with no info string whose body looks like Python. Tag it
       ```` ```python ```` (executed by tests/test_docs_examples.py) or
       ```` ```python noexec ```` (illustration only) — never leave it
       bare.
====== ====================================================================

Suppression: append ``# lint: ok RULE reason`` on (or on the line above)
the offending line; in markdown use ``<!-- lint: ok RULE reason -->`` on
the preceding line. Fleet-level intentional violations live in the
checked-in baseline (``lint-baseline.json`` at the repo root, keyed by
rule + path + line *text*, so line-number drift never churns it);
``--baseline`` filters them, ``--write-baseline`` regenerates the file,
and ``--json`` emits machine-readable output for CI. Exit status is 1 iff
un-baselined, un-suppressed violations remain.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import pathlib
import re
import sys
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_BASELINE = "lint-baseline.json"
DEFAULT_PATHS = ("src/repro", "docs", "README.md")

_SUPPRESS_PY = re.compile(r"#\s*lint:\s*ok\s+([A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)")
_SUPPRESS_MD = re.compile(r"<!--\s*lint:\s*ok\s+([A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)")


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str          # repo-relative posix path
    line: int          # 1-based
    message: str
    line_text: str = ""

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    @property
    def baseline_key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.line_text.strip())


class Module:
    """One linted file: text + (for .py) parsed AST."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        if path.endswith(".py"):
            try:
                self.tree = ast.parse(text)
            except SyntaxError:
                self.tree = None

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


def _src(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:                # pragma: no cover - py<3.9 fallback
        return ast.dump(node)


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

RULES: Dict[str, "Rule"] = {}


def register_rule(cls):
    RULES[cls.id] = cls()
    return cls


class Rule:
    id = ""
    title = ""

    def applies(self, path: str) -> bool:
        raise NotImplementedError

    def check(self, mod: Module) -> Iterator[LintViolation]:
        raise NotImplementedError

    def _v(self, mod: Module, lineno: int, message: str) -> LintViolation:
        return LintViolation(self.id, mod.path, lineno, message,
                             mod.line_text(lineno))


@register_rule
class HiddenSyncRule(Rule):
    """SYNC01 — no hidden host<->device sync in serving hot phases."""

    id = "SYNC01"
    title = "hidden host<->device sync in a serving hot phase"

    SCOPE = ("src/repro/serving/scheduler.py", "src/repro/serving/staging.py",
             "src/repro/serving/session.py",
             "src/repro/serving/stream_source.py",
             "src/repro/serving/ingest.py",
             "src/repro/serving/autopilot.py",
             "src/repro/launch/batching.py")
    # stage/poll/dispatch-phase functions: must never wait on the device.
    # The ingest worker's drain path (drain/_poll_one/_poll_round/attach/
    # detach/has_pending) and the depth autopilot's evaluation path
    # (decide/observe/_apply_autopilot) run on or gate the stage critical
    # path — a hidden sync there stalls the grid exactly like one in
    # _stage_body would
    HOT_FUNCS = {"step", "submit", "push", "pop", "push_events", "pop_chunk",
                 "poll", "_stage", "_stage_body", "_poll_sources", "_admit",
                 "_dispatch", "_feed_tokens", "_replace_lanes", "tick",
                 "drain", "_poll_one", "_poll_round", "attach", "detach",
                 "has_pending", "decide", "observe", "_apply_autopilot",
                 "set_depth"}
    # names that (by repo convention) hold device arrays in these modules
    DEVICE_HINTS = ("deltas", "state", "metrics", "logits", "pre_mag",
                    "post_mag", "cache", "wc")
    ALWAYS_SYNC_ATTRS = ("item", "block_until_ready", "device_get")

    def applies(self, path: str) -> bool:
        return path in self.SCOPE

    def _mentions_device(self, node: ast.AST) -> bool:
        src = _src(node)
        return any(re.search(rf"\b{h}\b", src) for h in self.DEVICE_HINTS)

    def check(self, mod: Module) -> Iterator[LintViolation]:
        if mod.tree is None:
            return
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name not in self.HOT_FUNCS:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in self.ALWAYS_SYNC_ATTRS):
                    yield self._v(mod, node.lineno,
                                  f"`{_src(node)[:60]}` blocks on the device "
                                  f"inside hot-phase `{fn.name}` — only the "
                                  f"retire phase may wait")
                elif (isinstance(f, ast.Attribute)
                        and f.attr in ("asarray", "array")
                        and isinstance(f.value, ast.Name)
                        and f.value.id in ("np", "numpy")
                        and node.args and self._mentions_device(node.args[0])):
                    yield self._v(mod, node.lineno,
                                  f"`np.{f.attr}` on device state "
                                  f"(`{_src(node.args[0])[:50]}`) in hot-"
                                  f"phase `{fn.name}` forces a sync — fetch "
                                  f"at retire instead")
                elif (isinstance(f, ast.Name) and f.id in ("float", "int")
                        and node.args and self._mentions_device(node.args[0])):
                    yield self._v(mod, node.lineno,
                                  f"`{f.id}(...)` on device state "
                                  f"(`{_src(node.args[0])[:50]}`) in hot-"
                                  f"phase `{fn.name}` forces a sync — fetch "
                                  f"at retire instead")


def _growable_attrs(cls: ast.ClassDef) -> Dict[str, int]:
    """``{attr: lineno}`` for self attributes initialized as a bare list/
    dict/set (or a deque without maxlen) in __init__/__post_init__."""
    out: Dict[str, int] = {}
    for fn in cls.body:
        if not (isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                and fn.name in ("__init__", "__post_init__")):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
                value = node.value
            elif isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            else:
                continue
            for t in targets:
                if not (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    continue
                if isinstance(value, (ast.List, ast.Dict, ast.Set)) \
                        and not getattr(value, "elts", None) \
                        and not getattr(value, "keys", None):
                    out[t.attr] = node.lineno
                elif isinstance(value, ast.Call):
                    callee = value.func
                    nm = (callee.id if isinstance(callee, ast.Name)
                          else getattr(callee, "attr", ""))
                    if nm in ("list", "dict", "set"):
                        out[t.attr] = node.lineno
                    elif nm == "deque":
                        has_maxlen = any(kw.arg == "maxlen"
                                         for kw in value.keywords) \
                            or len(value.args) >= 2
                        if not has_maxlen:
                            out[t.attr] = node.lineno
    return out


_GROW_METHODS = ("append", "appendleft", "extend", "insert", "add",
                 "setdefault")


@register_rule
class UnboundedGrowthRule(Rule):
    """OBS01 — telemetry/obs containers must be bounded."""

    id = "OBS01"
    title = "unbounded container growth in obs/telemetry state"

    def applies(self, path: str) -> bool:
        return (path.startswith("src/repro/obs/")
                or path == "src/repro/serving/telemetry.py")

    def check(self, mod: Module) -> Iterator[LintViolation]:
        if mod.tree is None:
            return
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            growable = _growable_attrs(cls)
            if not growable:
                continue
            for fn in cls.body:
                if not (isinstance(fn, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))
                        and fn.name not in ("__init__", "__post_init__")):
                    continue
                for node in ast.walk(fn):
                    attr = self._grown_attr(node)
                    if attr and attr in growable:
                        yield self._v(
                            mod, node.lineno,
                            f"`self.{attr}` (initialized unbounded at line "
                            f"{growable[attr]}) grows in "
                            f"`{cls.name}.{fn.name}` — use a maxlen ring, "
                            f"a histogram, or registry counters (memory "
                            f"must be O(1) in steps/streams)")

    @staticmethod
    def _grown_attr(node: ast.AST) -> Optional[str]:
        # self.X.append(...) / extend / add / insert / setdefault
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            f = node.func
            if (f.attr in _GROW_METHODS and isinstance(f.value, ast.Attribute)
                    and isinstance(f.value.value, ast.Name)
                    and f.value.value.id == "self"):
                return f.value.attr
        # self.X[key] = ...
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Attribute)
                        and isinstance(t.value.value, ast.Name)
                        and t.value.value.id == "self"):
                    return t.value.attr
        return None


@register_rule
class UnlockedMutationRule(Rule):
    """OBS02 — shared obs state mutates only under its lock."""

    id = "OBS02"
    title = "mutation of shared obs state outside its lock"

    LOCK_ATTRS = ("_lock", "lock")
    MUTATORS = _GROW_METHODS + ("pop", "popleft", "remove", "clear",
                                "update", "discard")

    def applies(self, path: str) -> bool:
        return (path.startswith("src/repro/obs/")
                or path == "src/repro/serving/telemetry.py"
                or path == "src/repro/serving/ingest.py")

    def check(self, mod: Module) -> Iterator[LintViolation]:
        if mod.tree is None:
            return
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            if not self._has_lock(cls):
                continue
            for fn in cls.body:
                if not (isinstance(fn, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))
                        and fn.name not in ("__init__", "__post_init__")):
                    continue
                yield from self._walk(mod, cls, fn, fn.body,
                                      under_lock=False)

    def _has_lock(self, cls: ast.ClassDef) -> bool:
        for fn in cls.body:
            if (isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and fn.name in ("__init__", "__post_init__")):
                for node in ast.walk(fn):
                    if isinstance(node, ast.Assign):
                        for t in node.targets:
                            if (isinstance(t, ast.Attribute)
                                    and isinstance(t.value, ast.Name)
                                    and t.value.id == "self"
                                    and t.attr in self.LOCK_ATTRS):
                                return True
        return False

    def _is_lock_with(self, node: ast.With) -> bool:
        for item in node.items:
            e = item.context_expr
            if (isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name)
                    and e.value.id == "self" and e.attr in self.LOCK_ATTRS):
                return True
        return False

    def _walk(self, mod: Module, cls: ast.ClassDef, fn, body,
              under_lock: bool) -> Iterator[LintViolation]:
        for node in body:
            if isinstance(node, ast.With):
                inner = under_lock or self._is_lock_with(node)
                yield from self._walk(mod, cls, fn, node.body, inner)
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue          # nested defs: their own discipline
            if not under_lock:
                for desc in self._mutations(node):
                    yield self._v(
                        mod, desc[1],
                        f"`{desc[0]}` mutates `{cls.name}` state in "
                        f"`{fn.name}` outside `with self._lock` — shared "
                        f"obs state must mutate under its lock")
            # recurse into compound statements (if/for/try/...)
            for child_body in self._child_bodies(node):
                yield from self._walk(mod, cls, fn, child_body, under_lock)

    @staticmethod
    def _child_bodies(node: ast.AST):
        for field in ("body", "orelse", "finalbody"):
            b = getattr(node, field, None)
            if isinstance(b, list):
                yield b
        for h in getattr(node, "handlers", []) or []:
            yield h.body

    def _mutations(self, node: ast.AST) -> Iterator[Tuple[str, int]]:
        """(description, lineno) for depth-1 self-attribute mutations in
        this single statement (not recursing into child statement bodies —
        the caller handles those with lock tracking)."""
        def self_attr(t) -> Optional[str]:
            if (isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                return f"self.{t.attr}"
            if (isinstance(t, ast.Subscript)
                    and isinstance(t.value, ast.Attribute)
                    and isinstance(t.value.value, ast.Name)
                    and t.value.value.id == "self"):
                return f"self.{t.value.attr}[...]"
            return None

        for sub in self._depth1(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(sub, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (sub.targets if isinstance(sub, ast.Assign)
                           else [sub.target])
                for t in targets:
                    d = self_attr(t)
                    if d:
                        yield (f"{d} {'+' if isinstance(sub, ast.AugAssign) else ''}=",
                               sub.lineno)
            elif (isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in self.MUTATORS):
                d = self_attr(sub.func.value)
                if d:
                    yield (f"{d}.{sub.func.attr}()", sub.lineno)

    @staticmethod
    def _depth1(node: ast.AST) -> Iterator[ast.AST]:
        """Like ``ast.walk`` but stops at child statement bodies —
        ``_walk`` visits those itself with lock tracking, so a
        ``with self._lock:`` nested in a loop/try is honored instead of
        its contents being flagged (and double-counted) via the
        enclosing compound statement."""
        stack = [node]
        while stack:
            sub = stack.pop()
            yield sub
            for field, value in ast.iter_fields(sub):
                if field in ("body", "orelse", "finalbody", "handlers"):
                    continue
                if isinstance(value, ast.AST):
                    stack.append(value)
                elif isinstance(value, list):
                    stack.extend(v for v in value if isinstance(v, ast.AST))


@register_rule
class HostOnlyImportRule(Rule):
    """HOST01 — host-only modules never import the device runtime."""

    id = "HOST01"
    title = "jax import in a host-only module"

    SCOPE_PREFIXES = ("src/repro/obs/",)
    SCOPE_FILES = ("src/repro/serving/telemetry.py",
                   "src/repro/serving/staging.py",
                   "src/repro/serving/stream_source.py",
                   "src/repro/serving/ingest.py",
                   "src/repro/serving/autopilot.py",
                   "src/repro/analysis/lint.py")

    def applies(self, path: str) -> bool:
        return (any(path.startswith(p) for p in self.SCOPE_PREFIXES)
                or path in self.SCOPE_FILES)

    def check(self, mod: Module) -> Iterator[LintViolation]:
        if mod.tree is None:
            return
        for node in mod.tree.body:       # module level only — lazy is fine
            names = []
            if isinstance(node, ast.Import):
                names = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                names = [node.module]
            for name in names:
                if name == "jax" or name.startswith("jax."):
                    yield self._v(
                        mod, node.lineno,
                        f"module-level `import {name}` in a host-only "
                        f"module — import lazily inside the function that "
                        f"needs it, or move the device code out")


_FENCE_RE = re.compile(r"^```([^\n`]*)\n(.*?)^```\s*$", re.M | re.S)
_PYTHONISH = re.compile(
    r"^\s*(from\s+\w[\w.]*\s+import\s|import\s+\w|def\s+\w+\(|class\s+\w+\b)",
    re.M)


@register_rule
class DocsFenceRule(Rule):
    """DOC01 — python-looking docs fences must be tagged for the
    executable-docs tripwire."""

    id = "DOC01"
    title = "untagged python-looking docs code fence"

    def applies(self, path: str) -> bool:
        return path.endswith(".md") and (path.startswith("docs/")
                                         or path == "README.md")

    def check(self, mod: Module) -> Iterator[LintViolation]:
        for m in _FENCE_RE.finditer(mod.text):
            info, body = m.group(1).strip(), m.group(2)
            if info:
                continue
            if _PYTHONISH.search(body):
                lineno = mod.text[:m.start()].count("\n") + 1
                yield self._v(
                    mod, lineno,
                    "bare ``` fence with python-looking content dodges the "
                    "executable-docs check — tag it ```python (executed) "
                    "or ```python noexec (illustration)")


# --------------------------------------------------------------------------
# suppression, baseline, drivers
# --------------------------------------------------------------------------

def _suppressed(mod: Module, v: LintViolation) -> bool:
    pat = _SUPPRESS_MD if mod.path.endswith(".md") else _SUPPRESS_PY
    for lineno in (v.line, v.line - 1):
        m = pat.search(mod.line_text(lineno))
        if m and v.rule in re.split(r"\s*,\s*", m.group(1)):
            return True
    return False


def lint_module(mod: Module) -> List[LintViolation]:
    out = []
    for rule in RULES.values():
        if rule.applies(mod.path):
            out.extend(v for v in rule.check(mod) if not _suppressed(mod, v))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_source(relpath: str, text: str) -> List[LintViolation]:
    """Lint a source snippet as if it lived at ``relpath`` (repo-relative).
    The unit-test / fixture entry point."""
    return lint_module(Module(relpath, text))


def iter_files(root: pathlib.Path, paths: Sequence[str]) -> Iterator[pathlib.Path]:
    for p in paths:
        fp = root / p
        if fp.is_file():
            yield fp
        elif fp.is_dir():
            for child in sorted(fp.rglob("*")):
                if child.suffix in (".py", ".md") and child.is_file():
                    yield child


def lint_paths(root: pathlib.Path,
               paths: Sequence[str] = DEFAULT_PATHS) -> List[LintViolation]:
    out = []
    for fp in iter_files(root, paths):
        rel = fp.relative_to(root).as_posix()
        out.extend(lint_module(Module(rel, fp.read_text())))
    return out


def load_baseline(path: pathlib.Path) -> List[dict]:
    if not path.exists():
        return []
    doc = json.loads(path.read_text())
    return doc.get("entries", [])


def write_baseline(path: pathlib.Path,
                   violations: Sequence[LintViolation]) -> dict:
    doc = {
        "version": 1,
        "comment": ("accepted lint findings — keyed by (rule, path, line "
                    "text) so line drift never churns this file; add a "
                    "`reason` when you accept one (see docs/ANALYSIS.md)"),
        "entries": [{
            "rule": v.rule, "path": v.path,
            "line_text": v.line_text.strip(), "reason": ""}
            for v in violations],
    }
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return doc


def apply_baseline(violations: Sequence[LintViolation],
                   entries: Sequence[dict]
                   ) -> Tuple[List[LintViolation], List[dict]]:
    """(new_violations, stale_baseline_entries)."""
    known: Set[Tuple[str, str, str]] = {
        (e["rule"], e["path"], e["line_text"]) for e in entries}
    new = [v for v in violations if v.baseline_key not in known]
    hit = {v.baseline_key for v in violations}
    stale = [e for e in entries
             if (e["rule"], e["path"], e["line_text"]) not in hit]
    return new, stale


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="host-path lint (see docs/ANALYSIS.md for the rules)")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/dirs to lint, relative to --root")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="repo root (default: auto-detected)")
    ap.add_argument("--baseline", nargs="?", const=DEFAULT_BASELINE,
                    default=None, metavar="PATH",
                    help="filter findings through the checked-in baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results ('-' for stdout)")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.root).resolve()
    violations = lint_paths(root, args.paths)

    if args.write_baseline:
        bp = root / (args.baseline or DEFAULT_BASELINE)
        write_baseline(bp, violations)
        print(f"wrote {len(violations)} entries to {bp}")
        return 0

    stale: List[dict] = []
    if args.baseline is not None:
        entries = load_baseline(root / args.baseline)
        violations, stale = apply_baseline(violations, entries)

    if args.json:
        doc = {
            "schema": "repro-lint/1",
            "violations": [dataclasses.asdict(v) for v in violations],
            "stale_baseline": stale,
        }
        if args.json == "-":
            json.dump(doc, sys.stdout, indent=1, sort_keys=True)
            print()
        else:
            pathlib.Path(args.json).write_text(
                json.dumps(doc, indent=1, sort_keys=True) + "\n")

    for v in violations:
        print(v.render())
    for e in stale:
        print(f"stale baseline entry (fixed? remove it): "
              f"{e['rule']} {e['path']} `{e['line_text']}`")
    n = len(violations)
    print(f"{n} violation(s)" + (" — lint clean" if n == 0 else ""))
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
