"""Static analysis subsystem: jaxpr contract checking + host-path lint.

Two analyzers (see docs/ANALYSIS.md):

* ``repro.analysis.jaxpr_contracts`` — ``check(fn, args, contracts)``
  walks a callable's ClosedJaxpr (recursing into scan/while/cond/pjit/
  shard_map) and verifies named structural contracts: ``no_collectives``,
  ``slot_separable``, ``mask_free``, ``no_dense_deltas``,
  ``no_factor_carries``, ``dtype_discipline``, ``compile_count``.
* ``repro.analysis.lint`` — AST rules over the host path
  (``python -m repro.analysis.lint``): hidden device syncs in hot phases,
  unbounded obs/telemetry containers, un-locked shared-state mutation,
  jax imports in host-only modules, untagged docs fences.

``repro.analysis.registry`` binds contract sets to the real entrypoints
(the serving chunk fn in every layout, the raw engine chunk step, the
batcher decode step); import it explicitly — it pulls in the serving
stack, which this package root deliberately does not.
"""
from repro.analysis.jaxpr_contracts import (COLLECTIVE_PRIMITIVES, Contract,
                                            ContractViolationError, Report,
                                            Violation, all_avals,
                                            assert_chunk_carry_slot_separable,
                                            check, compile_count,
                                            dtype_discipline, iter_eqns,
                                            iter_jaxprs, mask_free,
                                            no_collectives, no_dense_deltas,
                                            no_dense_leaves,
                                            no_factor_carries, slot_separable)
_LINT_EXPORTS = ("RULES", "LintViolation", "lint_paths", "lint_source")


def __getattr__(name):
    # lint symbols resolve lazily so `python -m repro.analysis.lint` does not
    # import the module twice (once via this package root, once as __main__)
    if name in _LINT_EXPORTS:
        from repro.analysis import lint as _lint
        return getattr(_lint, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "COLLECTIVE_PRIMITIVES", "Contract", "ContractViolationError", "Report",
    "Violation", "all_avals", "assert_chunk_carry_slot_separable", "check",
    "compile_count", "dtype_discipline", "iter_eqns", "iter_jaxprs",
    "mask_free", "no_collectives", "no_dense_deltas", "no_dense_leaves",
    "no_factor_carries", "slot_separable",
    "RULES", "LintViolation", "lint_paths", "lint_source",
]
