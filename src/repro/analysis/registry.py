"""Contract registry: the repo's real entrypoints bound to contract sets.

Each entry names one production entrypoint plus the invariants its callers
rely on; ``check_all()`` runs every set on a small-but-real configuration
(compact AND dense delta layouts, sharded and unsharded, factors on and
off). CI's ``static-analysis`` step runs this module
(``python -m repro.analysis.registry``) so a change that breaks a hot-path
contract — a collective sneaking into the shard-mapped step, a dense mask
leaking into the compact jaxpr, a factor accumulator surviving
``want_factors=False`` — fails the build with the contract's name, not as
an 8-device parity diff three tests later.

Entries are built lazily (registering costs nothing at import), each
returning ``(fn, args, contracts, kwargs)`` for
:func:`repro.analysis.jaxpr_contracts.check`.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis import jaxpr_contracts as jc

_REG: Dict[str, Callable[[], tuple]] = {}

# small-but-real geometry shared by the SNN entries; S is distinct from the
# chunk length, layer count and n_out so slot_separable cannot pass
# vacuously (see its docstring)
_S, _C = 4, 5


def register(name: str):
    def deco(build: Callable[[], tuple]):
        _REG[name] = build
        return build
    return deco


def names() -> List[str]:
    return sorted(_REG)


def check_entry(name: str) -> jc.Report:
    fn, args, contracts, kwargs = _REG[name]()
    return jc.check(fn, args, contracts, kwargs=kwargs, name=name)


def check_all(only: Optional[Sequence[str]] = None) -> Dict[str, jc.Report]:
    return {n: check_entry(n) for n in names()
            if only is None or n in only}


def summary(reports: Optional[Dict[str, jc.Report]] = None) -> dict:
    """Compact roll-up for the benchmark artifact's ``contracts_checked``
    field: how many entrypoints/contracts ran and whether all held."""
    reports = check_all() if reports is None else reports
    return {
        "entrypoints": sorted(reports),
        "contracts": sum(len(r.contracts) for r in reports.values()),
        "violations": sum(len(r.violations) for r in reports.values()),
        "ok": all(r.ok for r in reports.values()),
    }


# --------------------------------------------------------------------------
# shared builders
# --------------------------------------------------------------------------

def _snn_cfg():
    from repro.core.snn import SNNConfig
    return SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=8)


def _snn_inputs(cfg, *, compact: bool, chunk_len: int = _C,
                n_slots: int = _S):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import snn

    params = snn.init_params(jax.random.PRNGKey(0), cfg)
    deltas = snn.init_stream_deltas(cfg, n_slots, compact=compact)
    state = snn.init_stream_state(cfg, n_slots)
    rng = np.random.default_rng(0)
    events = jnp.asarray(
        rng.random((chunk_len, n_slots, cfg.n_in)) < 0.25, jnp.float32)
    valid = jnp.ones((chunk_len, n_slots), bool)
    amask = jnp.ones((n_slots,), bool)
    return params, deltas, state, events, valid, amask


def _chunk_entry(*, mesh=None, want_factors: bool, compact: bool,
                 chunk_len: int = _C, n_slots: int = _S):
    from repro.core import snn
    from repro.serving.adapt import AdaptConfig, make_chunk_fn

    cfg = _snn_cfg()
    params, deltas, state, events, valid, amask = _snn_inputs(
        cfg, compact=compact, chunk_len=chunk_len, n_slots=n_slots)
    exec_params = snn.serving_params(params, cfg) if compact else params
    fn = make_chunk_fn(cfg, AdaptConfig(), mesh=mesh,
                       want_factors=want_factors)
    contracts = [
        jc.no_collectives(),
        jc.slot_separable(
            n_slots,
            exempt=(".pre_mag", ".post_mag") if want_factors else ()),
        jc.dtype_discipline(),
        jc.compile_count(),
    ]
    if compact:
        contracts += [jc.mask_free(cfg), jc.no_dense_deltas(cfg, n_slots)]
    if not want_factors:
        contracts += [jc.no_factor_carries(cfg, n_slots,
                                           chunk_len=chunk_len)]
    return fn, (exec_params, deltas, state, events, valid, amask), \
        contracts, None


# --------------------------------------------------------------------------
# entries
# --------------------------------------------------------------------------

@register("serving.chunk_fn[compact,factors]")
def _chunk_compact_factors():
    """The default serving hot path: mask-free exec params, compact deltas,
    DSST factors slot-reduced on device."""
    return _chunk_entry(want_factors=True, compact=True)


@register("serving.chunk_fn[compact,frozen]")
def _chunk_compact_frozen():
    """Frozen-topology fleet: factors compiled out of the chunk scan."""
    return _chunk_entry(want_factors=False, compact=True)


@register("serving.chunk_fn[dense]")
def _chunk_dense():
    """The dense-fallback A/B layout (no mask-free claim, but the
    zero-collective / slot-separable / compile-once contracts still bind)."""
    return _chunk_entry(want_factors=True, compact=False)


@register("serving.chunk_fn[tier=interactive]")
def _chunk_tier_interactive():
    """The interactive QoS tier's geometry: a short chunk grid (small
    chunk_len bounds per-window latency). Same compact exec rep and
    contract set as the default hot path — the tiers differ only in
    trace-time shape, never in program structure."""
    return _chunk_entry(want_factors=True, compact=True,
                        chunk_len=3, n_slots=4)


@register("serving.chunk_fn[tier=bulk]")
def _chunk_tier_bulk():
    """The bulk QoS tier's geometry: a long chunk grid (large chunk_len
    amortizes dispatch overhead for throughput streams)."""
    return _chunk_entry(want_factors=True, compact=True,
                        chunk_len=12, n_slots=4)


@register("serving.chunk_fn[sharded]")
def _chunk_sharded():
    """The slot-axis shard_map path — THE zero-collectives claim, checked
    structurally instead of via 8-device parity alone. Runs on however
    many devices the host has (1 in the default test env); the contract
    walks the shard_map sub-jaxpr either way."""
    from repro.launch.mesh import make_serving_mesh
    return _chunk_entry(mesh=make_serving_mesh(), want_factors=True,
                        compact=True)


@register("snn.run_chunk[compact]")
def _run_chunk_compact():
    """The raw (unjitted) engine chunk step on the compact layout: the
    per-slot factor metrics keep their S axis here (slot reduction happens
    in the serving wrapper, not the engine)."""
    from repro.core import snn

    cfg = _snn_cfg()
    params, deltas, state, events, valid, _ = _snn_inputs(cfg, compact=True)
    sp = snn.serving_params(params, cfg)

    def run_chunk_compact(p, d, s, e, v):
        return snn.run_chunk(p, d, s, e, v, cfg)

    contracts = [jc.no_collectives(), jc.slot_separable(_S),
                 jc.mask_free(cfg), jc.no_dense_deltas(cfg, _S),
                 jc.dtype_discipline()]
    return run_chunk_compact, (sp, deltas, state, events, valid), \
        contracts, None


@register("snn.run_chunk[dense]")
def _run_chunk_dense():
    from repro.core import snn

    cfg = _snn_cfg()
    params, deltas, state, events, valid, _ = _snn_inputs(cfg, compact=False)

    def run_chunk_dense(p, d, s, e, v):
        return snn.run_chunk(p, d, s, e, v, cfg)

    contracts = [jc.no_collectives(), jc.slot_separable(_S),
                 jc.dtype_discipline()]
    return run_chunk_dense, (params, deltas, state, events, valid), \
        contracts, None


@register("launch.decode_step")
def _decode_step():
    """The continuous batcher's jitted one-token decode: slot (batch)
    separability is what makes slot multiplexing sound; the global cache
    ``pos`` scalar is the one sanctioned slot-reduced output."""
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import transformer as T

    cfg = C.get_reduced("phi3_medium_14b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _S
    cache = T.init_cache(cfg, batch, 32)
    tokens = jnp.zeros((batch,), jnp.int32)

    def decode_step(p, c, t):
        return T.decode_step(p, c, t, cfg)

    contracts = [jc.no_collectives(), jc.dtype_discipline(),
                 jc.slot_separable(batch, exempt=("pos",))]
    return decode_step, (params, cache, tokens), contracts, None


# --------------------------------------------------------------------------
# CLI (the CI static-analysis step)
# --------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.registry",
        description="run every registered entrypoint contract set")
    ap.add_argument("entries", nargs="*", help="entry names (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list registered entries and exit")
    args = ap.parse_args(argv)

    if args.list:
        for n in names():
            print(n)
        return 0

    reports = check_all(only=args.entries or None)
    bad = 0
    for name in sorted(reports):
        r = reports[name]
        status = "PASS" if r.ok else "FAIL"
        print(f"{status} {name} ({', '.join(r.contracts)})")
        for v in r.violations:
            bad += 1
            print(f"  {v}")
    s = summary(reports)
    print(f"{len(reports)} entrypoints, {s['contracts']} contracts, "
          f"{s['violations']} violation(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
