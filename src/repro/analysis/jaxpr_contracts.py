"""Static jaxpr contract analyzer: named invariants checked on traced code.

The repo's headline claims are protected by *structural* properties of the
compiled hot path, not by any particular run passing: the serving chunk
step must stay free of cross-device collectives (that is what makes the
slot-axis ``shard_map`` bit-identical to one device), the compact layout
must never materialize a dense ``[L, Kmax, N]`` mask or ``[S, L, Kmax, N]``
delta tensor (the 3.8x memory claim), ``want_factors=False`` must compile
the DSST factor accumulators out of the chunk scan entirely, and every
per-stream quantity must keep its slot axis end to end (slot separability).

Each of those used to live as a one-off assert somewhere — a hand-rolled
jaxpr walker in one test file, a trace-time shape assert in the engine, an
indirect 8-device parity check. This module makes them first-class:

* :func:`check` traces a callable once (``jax.make_jaxpr``), walks the
  resulting ``ClosedJaxpr`` — recursing into ``scan`` / ``while`` /
  ``cond`` / ``pjit`` / ``shard_map`` sub-jaxprs — and evaluates a list of
  named :class:`Contract` objects against it, returning a :class:`Report`.
* Contract factories (:func:`no_collectives`, :func:`slot_separable`,
  :func:`mask_free`, :func:`no_dense_deltas`, :func:`no_factor_carries`,
  :func:`dtype_discipline`, :func:`compile_count`) build the repo's
  standard contracts; ``repro.analysis.registry`` binds contract *sets* to
  the real entrypoints and is run by CI's static-analysis step.

Everything here is static — ``check`` never executes the target on real
data (the one exception is the explicitly *dynamic* :func:`compile_count`
contract, which drives the entrypoint to observe its trace counter).
The trace-time tree assert the engine calls from inside ``scan_chunk``
(:func:`assert_chunk_carry_slot_separable`) lives here too, so the engine
and the analyzer enforce one definition of slot separability.
"""
from __future__ import annotations

import dataclasses
from collections import Counter as _Counter
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import jax
from jax import tree_util as jtu

# Cross-device communication primitives. Any of these inside the serving
# chunk step would make the slot-axis shard_map results depend on the
# device count — the exact failure mode the zero-collectives contract
# forbids. Names are matched after stripping a trailing version digit
# (``psum2`` -> ``psum``), so jax renames don't silently blind the check.
# ``pbroadcast`` is deliberately absent: shard_map's check_rep machinery
# inserts it as a device-local replication-accounting no-op, so flagging
# it would false-positive on communication-free bodies.
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "pmax", "pmin", "pmean", "ppermute", "pshuffle",
    "all_gather", "all_to_all", "pgather", "reduce_scatter", "psum_scatter",
    "pdot",
})


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------

def _as_jaxpr(obj):
    """Normalize ClosedJaxpr | Jaxpr -> Jaxpr (None if neither)."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(obj, "eqns"):
        return obj
    return None


def _sub_jaxprs(eqn) -> Iterator[Any]:
    """Sub-jaxprs hanging off one equation's params (scan/while/cond/pjit/
    shard_map/custom_* — anything that stores a Jaxpr or ClosedJaxpr,
    scalar or in a tuple like ``cond``'s branches)."""
    for val in eqn.params.values():
        for item in (val if isinstance(val, (tuple, list)) else (val,)):
            sub = _as_jaxpr(item)
            if sub is not None:
                yield sub


def iter_jaxprs(jaxpr) -> Iterator[Any]:
    """The jaxpr and every (transitively) nested sub-jaxpr, each once."""
    top = _as_jaxpr(jaxpr)
    stack, seen = [top], set()
    while stack:
        jx = stack.pop()
        if id(jx) in seen:
            continue
        seen.add(id(jx))
        yield jx
        for eqn in jx.eqns:
            stack.extend(_sub_jaxprs(eqn))


def iter_eqns(jaxpr, _path: Tuple[str, ...] = ()) -> Iterator[Tuple[Any, Tuple[str, ...]]]:
    """Yield ``(eqn, path)`` for every equation at any nesting depth;
    ``path`` is the chain of enclosing primitive names (e.g.
    ``("pjit", "shard_map", "scan")``)."""
    top = _as_jaxpr(jaxpr)
    for eqn in top.eqns:
        yield eqn, _path
        inner_path = _path + (eqn.primitive.name,)
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, inner_path)


def all_avals(jaxpr) -> Iterator[Tuple[Any, str]]:
    """``(aval, role)`` for every abstract value anywhere in the jaxpr:
    constvars/invars of the jaxpr and each sub-jaxpr, plus every equation's
    in/out vars (literals included via ``.aval``)."""
    for jx in iter_jaxprs(jaxpr):
        for v in jx.constvars:
            yield v.aval, "const"
        for v in jx.invars:
            yield v.aval, "input"
        for eqn in jx.eqns:
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is not None:
                    yield aval, "eqn-in"
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None:
                    yield aval, "eqn-out"


# --------------------------------------------------------------------------
# report plumbing
# --------------------------------------------------------------------------

class ContractViolationError(AssertionError):
    """Raised by :meth:`Report.raise_if_violations`. An ``AssertionError``
    subclass so callers that wrapped the old ad-hoc asserts keep working."""


@dataclasses.dataclass(frozen=True)
class Violation:
    contract: str
    message: str

    def __str__(self) -> str:
        return f"[{self.contract}] {self.message}"


@dataclasses.dataclass
class Report:
    """Outcome of :func:`check`: which contracts ran, what they found."""
    target: str
    contracts: Tuple[str, ...]
    violations: List[Violation]

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_if_violations(self) -> "Report":
        if self.violations:
            lines = "\n".join(f"  {v}" for v in self.violations)
            raise ContractViolationError(
                f"{self.target}: {len(self.violations)} contract "
                f"violation(s)\n{lines}")
        return self

    def __str__(self) -> str:
        status = ("OK" if self.ok
                  else f"{len(self.violations)} violation(s)")
        head = f"{self.target}: {status} ({', '.join(self.contracts)})"
        if self.ok:
            return head
        return head + "\n" + "\n".join(f"  {v}" for v in self.violations)


@dataclasses.dataclass(frozen=True)
class Contract:
    """A named check over a traced callable. ``run`` receives the
    :class:`_Ctx` (lazy jaxpr / output-shape access) and returns
    violations; an empty list means the contract holds."""
    name: str
    run: Callable[["_Ctx"], List[Violation]]


class _Ctx:
    """Lazily-traced view of ``(fn, args, kwargs)`` shared by the contracts
    of one ``check`` call: one ``make_jaxpr`` and one ``eval_shape``, no
    matter how many contracts inspect them."""

    def __init__(self, fn, args: tuple, kwargs: dict):
        self.fn, self.args, self.kwargs = fn, args, kwargs
        self._closed = None
        self._out_shape = None

    @property
    def closed_jaxpr(self):
        if self._closed is None:
            self._closed = jax.make_jaxpr(self.fn)(*self.args, **self.kwargs)
        return self._closed

    @property
    def jaxpr(self):
        return self.closed_jaxpr.jaxpr

    @property
    def out_shape(self):
        if self._out_shape is None:
            self._out_shape = jax.eval_shape(self.fn, *self.args,
                                             **self.kwargs)
        return self._out_shape


def check(fn, args: Sequence[Any], contracts: Sequence[Contract], *,
          kwargs: Optional[dict] = None, name: Optional[str] = None) -> Report:
    """Statically verify ``contracts`` against ``fn`` traced on ``args``.

    ``fn`` may be jitted or plain — ``jax.make_jaxpr`` recurses through
    ``pjit`` either way. Returns a :class:`Report`; call
    ``.raise_if_violations()`` to turn findings into a
    :class:`ContractViolationError` (tests) or inspect ``.violations``
    (CI's registry runner).
    """
    ctx = _Ctx(fn, tuple(args), dict(kwargs or {}))
    violations: List[Violation] = []
    for c in contracts:
        violations.extend(c.run(ctx))
    return Report(
        target=name or getattr(fn, "__name__", None) or repr(fn),
        contracts=tuple(c.name for c in contracts),
        violations=violations)


# --------------------------------------------------------------------------
# contract factories
# --------------------------------------------------------------------------

def _base_prim_name(name: str) -> str:
    return name[:-1] if name and name[-1].isdigit() else name


def no_collectives(axis: Optional[str] = None) -> Contract:
    """No cross-device collective primitive anywhere in the jaxpr
    (recursively — in particular not inside a slot-axis ``shard_map``).
    With ``axis`` given, only collectives touching that named axis count;
    default flags any collective at any depth."""
    def run(ctx: _Ctx) -> List[Violation]:
        out = []
        for eqn, path in iter_eqns(ctx.jaxpr):
            nm = eqn.primitive.name
            if (nm not in COLLECTIVE_PRIMITIVES
                    and _base_prim_name(nm) not in COLLECTIVE_PRIMITIVES):
                continue
            axes = (eqn.params.get("axes") or eqn.params.get("axis_name")
                    or eqn.params.get("axis_index_groups") or ())
            if isinstance(axes, (str, int)):
                axes = (axes,)
            axes = tuple(axes)
            if axis is not None and axes and axis not in axes:
                continue
            where = " > ".join(path) if path else "<top level>"
            out.append(Violation(
                "no_collectives",
                f"collective `{nm}` over axes {axes} under {where} — the "
                f"slot-sharded step must be communication-free"))
        return out
    return Contract("no_collectives", run)


def slot_separable(n_slots: int, *, exempt: Sequence[str] = ()) -> Contract:
    """Every output leaf keeps an axis of extent ``n_slots`` within its
    first two dims — the static half of the slot-separability contract
    (the dynamic half is the engine's trace-time carry assert, which
    wraps :func:`assert_chunk_carry_slot_separable` below). A reduction
    or reshape that drops the slot axis shows up here as an output whose
    leading dims no longer carry ``n_slots``.

    ``exempt``: keystr substrings for deliberately slot-reduced outputs
    (e.g. the serving chunk fn's ordered-slot-summed ``pre_mag`` /
    ``post_mag`` DSST factors, or a decode cache's global ``pos`` scalar).
    Pick ``n_slots`` distinct from the other leading extents (chunk len,
    layer count) or the check degrades to vacuously true.
    """
    def run(ctx: _Ctx) -> List[Violation]:
        out = []
        leaves, _ = jtu.tree_flatten_with_path(ctx.out_shape)
        for path, leaf in leaves:
            key = jtu.keystr(path) or "<result>"
            if any(e in key for e in exempt):
                continue
            shape = tuple(getattr(leaf, "shape", ()))
            if n_slots not in shape[:2]:
                out.append(Violation(
                    "slot_separable",
                    f"output {key} shape {shape} lost the slot axis "
                    f"(extent {n_slots} not within the first two dims)"))
        return out
    return Contract("slot_separable", run)


_DTYPE_SHORT = {"float32": "f32", "float64": "f64", "float16": "f16",
                "bfloat16": "bf16", "int32": "i32", "int64": "i64",
                "bool": "pred"}


def no_dense_leaves(shapes: Sequence[Sequence[int]], *,
                    dtypes: Sequence[str] = ("float32",),
                    contract_name: str = "no_dense_leaves") -> Contract:
    """No aval of any forbidden ``(shape, dtype)`` anywhere in the jaxpr —
    not a constvar, not an input, not an intermediate. Belt and braces: the
    traversal is cross-checked against the printed jaxpr text, so a const
    hiding in a sub-jaxpr a future jax version stops exposing still trips
    the string scan."""
    forbidden = {tuple(int(d) for d in s) for s in shapes}
    want_dtypes = tuple(dtypes)

    def run(ctx: _Ctx) -> List[Violation]:
        out, seen = [], set()
        for aval, role in all_avals(ctx.jaxpr):
            shape = tuple(getattr(aval, "shape", ()))
            dt = str(getattr(aval, "dtype", ""))
            if shape in forbidden and dt in want_dtypes:
                key = (role, dt, shape)
                if key not in seen:
                    seen.add(key)
                    out.append(Violation(
                        contract_name,
                        f"{role} aval {dt}{list(shape)} — dense layout "
                        f"leaked into the compact hot path"))
        flagged = {k[2] for k in seen}
        txt = str(ctx.closed_jaxpr)
        for shape in forbidden - flagged:
            for dt in want_dtypes:
                pat = f"{_DTYPE_SHORT.get(dt, dt)}[{','.join(map(str, shape))}]"
                if pat in txt:
                    out.append(Violation(
                        contract_name,
                        f"printed jaxpr contains `{pat}` (missed by the "
                        f"traversal — report this walker gap)"))
        return out
    return Contract(contract_name, run)


def mask_free(cfg) -> Contract:
    """Compact serving never materializes the dense connection mask
    ``[L, Kmax, N]`` (cfg needs ``n_layers`` / ``n_hidden`` /
    ``layer_fanins`` — ``core.snn.SNNConfig`` shaped, but duck-typed)."""
    k_max = max(cfg.layer_fanins)
    return no_dense_leaves([(cfg.n_layers, k_max, cfg.n_hidden)],
                           contract_name="mask_free")


def no_dense_deltas(cfg, n_slots: int) -> Contract:
    """Compact serving never materializes the dense per-stream delta tensor
    — neither slot-leading ``[S, L, Kmax, N]`` (public layout) nor
    layer-leading ``[L, S, Kmax, N]`` (engine layout)."""
    k_max = max(cfg.layer_fanins)
    return no_dense_leaves(
        [(n_slots, cfg.n_layers, k_max, cfg.n_hidden),
         (cfg.n_layers, n_slots, k_max, cfg.n_hidden)],
        contract_name="no_dense_deltas")


def no_factor_carries(cfg, n_slots: int, *, chunk_len: Optional[int] = None,
                      max_state_carries: int = 4) -> Contract:
    """With ``want_factors=False`` the DSST ``pre_mag`` / ``post_mag``
    accumulators are compiled OUT of the chunk scan — not zeroed, absent.

    The engine's time scan legitimately carries exactly
    ``max_state_carries`` ``[L, S, n_hidden]`` f32 arrays (the
    ``LayerState`` leaves: v, tr, tr_pc, tr_cc); the factor accumulators
    would add a ``[L, S, k_max]`` and one more ``[L, S, n_hidden]`` on
    top. Works for uniform geometries (``k_max == n_hidden``) where a pure
    shape check cannot distinguish state from accumulator — the *count*
    can. ``chunk_len`` narrows the check to the scan of that length (the
    time scan); None checks every scan. ``n_slots`` is the per-shard slot
    count — under a sharded mesh pass ``S // n_devices``.
    """
    L, N = cfg.n_layers, cfg.n_hidden
    k_max = max(cfg.layer_fanins)
    allowed: Dict[Tuple[int, ...], int] = {(L, n_slots, N): max_state_carries}
    if k_max != N:
        allowed[(L, n_slots, k_max)] = 0

    def run(ctx: _Ctx) -> List[Violation]:
        out = []
        for eqn, _path in iter_eqns(ctx.jaxpr):
            if eqn.primitive.name != "scan":
                continue
            if chunk_len is not None and eqn.params.get("length") != chunk_len:
                continue
            lo = eqn.params["num_consts"]
            carries = [v.aval for v in
                       eqn.invars[lo:lo + eqn.params["num_carry"]]]
            got = _Counter(tuple(a.shape) for a in carries
                           if str(getattr(a, "dtype", "")) == "float32")
            for shape, max_n in allowed.items():
                if got.get(shape, 0) > max_n:
                    out.append(Violation(
                        "no_factor_carries",
                        f"scan(length={eqn.params.get('length')}) carries "
                        f"{got[shape]} f32 arrays of shape {list(shape)} "
                        f"(expected <= {max_n} LayerState leaves) — the "
                        f"DSST factor accumulators were not compiled out"))
        return out
    return Contract("no_factor_carries", run)


def dtype_discipline(forbid: Sequence[str] = ("float64", "complex128")
                     ) -> Contract:
    """No silently-promoted wide dtype anywhere in the jaxpr. The repo runs
    with x64 disabled, so an f64 aval means someone re-enabled it or a
    host constant leaked through unconverted."""
    forbid = tuple(forbid)

    def run(ctx: _Ctx) -> List[Violation]:
        out, seen = [], set()
        for aval, role in all_avals(ctx.jaxpr):
            dt = str(getattr(aval, "dtype", ""))
            if dt in forbid:
                key = (dt, tuple(getattr(aval, "shape", ())))
                if key not in seen:
                    seen.add(key)
                    out.append(Violation(
                        "dtype_discipline",
                        f"{role} aval {dt}{list(key[1])} — silent wide-"
                        f"dtype promotion"))
        return out
    return Contract("dtype_discipline", run)


def compile_count(max_traces: int = 1, runs: int = 2) -> Contract:
    """DYNAMIC contract: the entrypoint traces at most ``max_traces`` times
    across ``runs`` identical calls — the "compile once, stream forever"
    guarantee (``adapt.make_chunk_fn``'s public ``n_traces()`` counter is
    the hook; a target without one fails the contract explicitly rather
    than passing vacuously). The only contract that executes the target."""
    def run(ctx: _Ctx) -> List[Violation]:
        counter = getattr(ctx.fn, "n_traces", None)
        if counter is None:
            return [Violation(
                "compile_count",
                "target exposes no n_traces() trace counter — cannot "
                "verify the single-compilation guarantee")]
        before = counter()
        for _ in range(runs):
            ctx.fn(*ctx.args, **ctx.kwargs)
        grew = counter() - before
        if grew > max_traces:
            return [Violation(
                "compile_count",
                f"entrypoint traced {grew}x across {runs} identical calls "
                f"(max {max_traces}) — it is retracing inside the hot "
                f"loop")]
        return []
    return Contract("compile_count", run)


# --------------------------------------------------------------------------
# the engine's trace-time tree assert (shared definition)
# --------------------------------------------------------------------------

def assert_chunk_carry_slot_separable(carry, outs, *, C: int, S: int,
                                      n_layers: int,
                                      want_factors: bool) -> None:
    """The chunk step's zero-collective contract, checked on the concrete
    scan carry/output trees at trace time: every per-stream quantity keeps
    its slot axis through the scan. A reduction over slots — which would
    silently break the slot-axis ``shard_map`` in serving/adapt.py — shows
    up as a dropped ``S`` dimension here. ``engine._assert_slot_separable``
    is a thin wrapper over this (same error shape: a bare ``assert`` whose
    message is the offending shape), and the static
    :func:`slot_separable` contract checks the same property on jaxpr
    output avals without running the trace."""
    layers, x_tr, ss_mean, t_w, samp, dls, *acc = carry
    for leaf in jtu.tree_leaves(layers):
        assert leaf.shape[:2] == (n_layers, S), leaf.shape
    assert x_tr.shape[0] == S, x_tr.shape
    assert ss_mean.shape == (n_layers, S), ss_mean.shape
    assert t_w.shape == (S,) and samp.shape == (S,), (t_w.shape, samp.shape)
    assert dls.shape[:2] == (n_layers, S), dls.shape
    assert len(acc) == (2 if want_factors else 0), len(acc)
    for a in acc:
        assert a.shape[:2] == (n_layers, S), a.shape
    for name, leaf in outs.items():
        assert leaf.shape[:2] == (C, S), (name, leaf.shape)
