"""Zero-dependency span tracing for the serving hot path.

A :class:`Tracer` records *spans* — named wall-time intervals over
``time.perf_counter()`` — into a bounded, thread-safe ring buffer. Spans
nest: each carries a hierarchical ``span_id``/``parent_id`` pair derived
from a per-thread open-span stack, so a Chrome ``trace_event`` dump
(``obs.export.chrome_trace``) reconstructs the call tree per thread.

Design constraints, pinned by ``tests/test_obs_serving.py``:

* **Never touches the jitted computation.** Spans wrap host phases that
  are *already* synchronous (stage packing, the retire-time metrics
  fetch); the tracer holds no device handles and issues no transfers, so
  tracing on vs. off produces bit-identical stream trajectories and an
  unchanged serving jaxpr.
* **Bounded.** The ring holds at most ``capacity`` finished spans; older
  spans are dropped (and counted in ``n_dropped``) — an always-on tracer
  is O(1) in steps, like the metrics registry it rides next to.
* **Cheap when off.** A disabled tracer (or the shared :data:`NULL_TRACER`)
  hands back a singleton no-op context manager: no allocation, no lock.

``annotate=True`` additionally enters a ``jax.profiler.TraceAnnotation``
for every span, so host phases line up with device lanes in a TensorBoard
/ Perfetto profile. It is opt-in (and a no-op where the profiler is
unavailable) because it is the one feature that touches jax at all.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished span (immutable record in the tracer's ring)."""
    name: str
    span_id: int
    parent_id: Optional[int]     # None for a root span
    t0_s: float                  # perf_counter at __enter__
    dur_s: float                 # wall duration
    thread: str                  # recording thread's name
    attrs: Tuple[Tuple[str, Any], ...]   # sorted (key, value) pairs

    def attr(self, key: str, default=None):
        """Value of attribute ``key`` (spans store attrs as sorted pairs)."""
        for k, v in self.attrs:
            if k == key:
                return v
        return default


class _NullSpan:
    """Shared no-op context manager: the disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    """An open span: context manager that records into its tracer on exit."""
    __slots__ = ("_tracer", "_name", "_attrs", "_t0", "_id", "_parent", "_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._ann = None

    def set(self, **attrs) -> "_SpanCtx":
        """Attach attributes to the open span (e.g. counts known mid-phase)."""
        self._attrs.update(attrs)
        return self

    def __enter__(self) -> "_SpanCtx":
        tr = self._tracer
        stack = tr._stack()
        self._parent = stack[-1] if stack else None
        self._id = next(tr._ids)
        stack.append(self._id)
        if tr.annotate and tr._annotation is not None:
            self._ann = tr._annotation(self._name)
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] == self._id:
            stack.pop()
        tr._record(Span(
            name=self._name, span_id=self._id, parent_id=self._parent,
            t0_s=self._t0, dur_s=t1 - self._t0,
            thread=threading.current_thread().name,
            attrs=tuple(sorted(self._attrs.items()))))
        return False


class Tracer:
    """Bounded, thread-safe span recorder.

    Args:
      capacity: ring-buffer size in finished spans; the oldest are dropped
        beyond it (``n_dropped`` counts them).
      enabled:  False makes :meth:`span` return a shared no-op context
        manager — the tracer records nothing and costs one attribute read.
      annotate: also wrap each span in ``jax.profiler.TraceAnnotation``
        (ignored if the profiler is unavailable).
    """

    def __init__(self, capacity: int = 4096, enabled: bool = True,
                 annotate: bool = False):
        if capacity < 1:
            raise ValueError(f"tracer capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self.annotate = annotate
        self.n_recorded = 0
        self.n_dropped = 0
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._annotation = None
        if annotate:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation = TraceAnnotation
            except Exception:                      # pragma: no cover
                self._annotation = None

    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs):
        """Context manager timing one named interval; nests hierarchically.

        ``attrs`` become the span's attributes (more via ``.set(...)``).
        """
        if not self.enabled:
            return _NULL_SPAN
        return _SpanCtx(self, name, attrs)

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self._ring) == self.capacity:
                self.n_dropped += 1
            self._ring.append(span)
            self.n_recorded += 1

    # -- reading -------------------------------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Span]:
        """Snapshot of retained spans, oldest first (optionally by name)."""
        with self._lock:
            out = list(self._ring)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()


NULL_TRACER = Tracer(capacity=1, enabled=False)
"""Shared disabled tracer: the default for uninstrumented callers. It
never records (``span()`` short-circuits on ``enabled``), so sharing the
instance across schedulers is safe."""
