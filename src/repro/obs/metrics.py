"""Labeled metrics registry: counters, gauges, fixed-bucket histograms.

Prometheus-shaped but dependency-free: a :class:`MetricsRegistry` holds
metric *families* (one name + help + kind + label names), each family
holds one child per label-value combination, and ``obs.export`` renders
the whole registry as Prometheus text exposition or a JSON snapshot.

The histogram is the load-bearing piece: it replaces the serving
telemetry's old unbounded ``step_latencies_s`` list. Buckets are fixed at
construction (log-spaced by default), so memory is **O(buckets), not
O(observations)**, while ``sum``/``count`` stay exact and
:meth:`Histogram.percentile` recovers p50/p99 by linear interpolation
inside the owning bucket — within one bucket's relative width of the
exact value (``tests/test_obs.py`` pins the tolerance; the default
latency buckets are spaced ~10% apart).

Counters are monotone *by construction*: a negative increment raises
instead of silently un-counting — the property the CI Prometheus smoke
scrapes for.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def log_buckets(lo: float, hi: float, per_decade: int = 24) -> Tuple[float, ...]:
    """Log-spaced bucket upper bounds covering ``[lo, hi]``.

    ``per_decade=24`` gives ~10% spacing — the percentile-estimate
    relative-error bound for values inside the covered range.
    """
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


def linear_buckets(lo: float, hi: float, n: int) -> Tuple[float, ...]:
    """``n`` evenly spaced bucket upper bounds ending at ``hi``."""
    if n < 1 or not hi > lo:
        raise ValueError(f"need n >= 1 and hi > lo, got ({lo}, {hi}, {n})")
    w = (hi - lo) / n
    return tuple(lo + w * (i + 1) for i in range(n))


# step()/phase latencies: 1 µs .. 60 s at ~10% spacing (188 buckets)
LATENCY_BUCKETS_S = log_buckets(1e-6, 60.0, per_decade=24)
# per-step host/device overlap ratio lives in [0, 1]
RATIO_BUCKETS = linear_buckets(0.0, 1.0, 50)
# bounded-queue occupancy (e.g. chunks drained per ingest poll window);
# capacities are small integers, so 4-wide linear buckets to 128 suffice
QUEUE_DEPTH_BUCKETS = linear_buckets(0.0, 128.0, 32)


class Counter:
    """Monotone child: ``inc`` of a negative amount raises."""
    __slots__ = ("_value", "_lock")
    kind = "counter"

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increments must be >= 0, got {amount}")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Set-to-current-value child (the one non-monotone kind)."""
    __slots__ = ("_value", "_lock")
    kind = "gauge"

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket child: O(buckets) memory, exact sum/count, interpolated
    percentiles. ``buckets`` are increasing upper bounds; observations above
    the last land in the implicit +inf bucket (reported at the last finite
    bound by :meth:`percentile` — widen the buckets if that matters)."""
    __slots__ = ("buckets", "_counts", "_sum", "_count", "_lock")
    kind = "histogram"

    def __init__(self, buckets: Sequence[float]):
        b = tuple(float(x) for x in buckets)
        if not b or any(y <= x for x, y in zip(b, b[1:])):
            raise ValueError("histogram buckets must be strictly increasing")
        self.buckets = b
        self._counts = [0] * (len(b) + 1)       # +1: the +inf bucket
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        lo, hi = 0, len(self.buckets)
        while lo < hi:                           # first bucket with v <= ub
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            self._counts[lo] += 1
            self._sum += v
            self._count += 1

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def count(self) -> int:
        return self._count

    def bucket_counts(self) -> List[int]:
        """Per-bucket counts (last entry is the +inf overflow bucket)."""
        with self._lock:
            return list(self._counts)

    def percentile(self, q: float) -> float:
        """Estimated ``q``-th percentile (0..100), linearly interpolated
        inside the owning bucket; 0.0 with no observations."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        rank = q / 100.0 * total
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                if i >= len(self.buckets):       # overflow bucket
                    return self.buckets[-1]
                lo = self.buckets[i - 1] if i > 0 else 0.0
                frac = (rank - cum) / c
                return lo + (self.buckets[i] - lo) * frac
            cum += c
        return self.buckets[-1]


class Family:
    """One metric name: a child per label-value tuple (created on use)."""

    def __init__(self, name: str, help: str, kind: str,
                 labelnames: Tuple[str, ...], make_child):
        self.name, self.help, self.kind = name, help, kind
        self.labelnames = labelnames
        self._make_child = make_child
        self._children: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()

    def labels(self, **labelvalues):
        """The child for this label-value combination."""
        if set(labelvalues) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(labelvalues)}")
        key = tuple(str(labelvalues[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                # one child per label set (standard Prometheus semantics);
                # lint: ok OBS01 — label cardinality is caller-bounded
                child = self._children[key] = self._make_child()
        return child

    def samples(self) -> List[Tuple[Tuple[str, ...], object]]:
        """[(label_values, child)] sorted by label values."""
        with self._lock:
            return sorted(self._children.items())

    # label-less families proxy straight to their single child
    def _solo(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labeled {self.labelnames}; "
                             "use .labels(...)")
        return self.labels()

    def inc(self, amount: float = 1.0) -> None:
        self._solo().inc(amount)

    def set(self, value: float) -> None:
        self._solo().set(value)

    def observe(self, value: float) -> None:
        self._solo().observe(value)

    @property
    def value(self) -> float:
        return self._solo().value

    def percentile(self, q: float) -> float:
        return self._solo().percentile(q)

    @property
    def sum(self) -> float:
        return self._solo().sum

    @property
    def count(self) -> int:
        return self._solo().count

    def total(self) -> float:
        """Sum of all children's values (counters/gauges)."""
        return sum(c.value for _, c in self.samples())


class MetricsRegistry:
    """Create-or-get metric families; the unit ``obs.export`` renders.

    Getting an existing name validates kind/labels match — two subsystems
    can share a registry without silently shadowing each other's metrics.
    """

    def __init__(self):
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()

    def _family(self, name: str, help: str, kind: str,
                labels: Sequence[str], make_child) -> Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labels = tuple(labels)
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                # families are code-defined (one per metric name in source);
                # lint: ok OBS01 — the registry cannot grow unbounded
                fam = self._families[name] = Family(
                    name, help, kind, labels, make_child)
                if not labels:
                    # Prometheus convention: a label-less metric exists at
                    # 0 from registration, so scrapes see it before first
                    # use (rates/absence alerts work from step one)
                    fam.labels()
            elif fam.kind != kind or fam.labelnames != labels:
                raise ValueError(
                    f"metric {name} already registered as {fam.kind}"
                    f"{fam.labelnames}, not {kind}{labels}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._family(name, help, "counter", labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._family(name, help, "gauge", labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Family:
        b = tuple(buckets) if buckets is not None else LATENCY_BUCKETS_S
        return self._family(name, help, "histogram", labels,
                            lambda: Histogram(b))

    def collect(self) -> List[Family]:
        """All families, name-sorted (the exporters' iteration order)."""
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def get(self, name: str) -> Optional[Family]:
        with self._lock:
            return self._families.get(name)

    def snapshot(self) -> dict:
        """JSON-able dump: per family, kind/help and every child's value
        (histograms as count/sum/p50/p99 — the artifact form, not the
        full bucket vector)."""
        out = {}
        for fam in self.collect():
            samples = []
            for values, child in fam.samples():
                rec = {"labels": dict(zip(fam.labelnames, values))}
                if fam.kind == "histogram":
                    rec.update(count=child.count, sum=child.sum,
                               p50=child.percentile(50),
                               p99=child.percentile(99))
                else:
                    rec["value"] = child.value
                samples.append(rec)
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "samples": samples}
        return out
