"""Fleet observability: phase-level tracing, metrics, exporters.

Three zero-dependency layers (see ``docs/OBSERVABILITY.md``):

* ``obs.trace`` — bounded thread-safe span tracer over monotonic clocks,
  wired through every phase of the serving hot path;
* ``obs.metrics`` — labeled counter/gauge/fixed-bucket-histogram registry
  that ``serving.telemetry.FleetTelemetry`` is built on;
* ``obs.export`` — Prometheus text exposition, JSONL event log, and a
  Chrome ``trace_event`` dump of spans.

Hard contract: instrumentation never touches the jitted computation and
never adds host↔device syncs — tracing on vs. off is bit-identical
(pinned in ``tests/test_obs_serving.py``).
"""
from .export import (chrome_trace, parse_prometheus_text, prometheus_text,
                     read_jsonl, span_records, write_chrome_trace,
                     write_jsonl)
from .metrics import (LATENCY_BUCKETS_S, RATIO_BUCKETS, Counter, Family,
                      Gauge, Histogram, MetricsRegistry, linear_buckets,
                      log_buckets)
from .trace import NULL_TRACER, Span, Tracer

__all__ = [
    "Counter", "Family", "Gauge", "Histogram", "LATENCY_BUCKETS_S",
    "MetricsRegistry", "NULL_TRACER", "RATIO_BUCKETS", "Span", "Tracer",
    "chrome_trace", "linear_buckets", "log_buckets", "parse_prometheus_text",
    "prometheus_text", "read_jsonl", "span_records", "write_chrome_trace",
    "write_jsonl",
]
