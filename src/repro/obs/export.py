"""Exporters: Prometheus text exposition, JSONL event log, Chrome trace.

Three read-only views over the same in-process state:

* :func:`prometheus_text` renders a :class:`~repro.obs.metrics.MetricsRegistry`
  in the Prometheus text exposition format (``# HELP``/``# TYPE`` headers,
  ``name{label="v"} value`` samples, histograms as cumulative
  ``_bucket{le=...}`` + ``_sum`` + ``_count``) — scrape it from a debug
  endpoint or dump it after a run; the CI smoke asserts required families
  are present and counters never decrease between scrapes.
* :func:`write_jsonl` / :func:`span_records` append structured events —
  one JSON object per line — the greppable long-term log (the benchmark
  artifact uses the same snapshot dict, see ``benchmarks/run.py --json``).
* :func:`chrome_trace` converts tracer spans into the Chrome
  ``trace_event`` JSON format: load the file at ``chrome://tracing`` or
  https://ui.perfetto.dev to see stage/dispatch/retire lanes per thread,
  pipelined steps overlapping, and topology epochs as long blocks.

All three are pure functions of already-recorded host state: exporting
never touches devices, so it is safe at any point of a serving run.

Format goldens are pinned in ``tests/test_obs.py``.
"""
from __future__ import annotations

import json
from typing import IO, Iterable, List, Optional, Sequence, Union

from .metrics import MetricsRegistry
from .trace import Span, Tracer


def _fmt(v: float) -> str:
    """Prometheus sample value: integral floats render without exponent."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return f"{int(f)}"
    return repr(f)


def _esc(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labelstr(names: Sequence[str], values: Sequence[str],
              extra: Sequence[tuple] = ()) -> str:
    pairs = [f'{n}="{_esc(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_esc(str(v))}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def prometheus_text(registry: MetricsRegistry) -> str:
    """The registry in Prometheus text exposition format (one scrape)."""
    lines: List[str] = []
    for fam in registry.collect():
        if fam.help:
            lines.append(f"# HELP {fam.name} {_esc(fam.help)}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for values, child in fam.samples():
            if fam.kind == "histogram":
                cum = 0
                counts = child.bucket_counts()
                for ub, c in zip(child.buckets, counts):
                    cum += c
                    le = _labelstr(fam.labelnames, values, [("le", _fmt(ub))])
                    lines.append(f"{fam.name}_bucket{le} {cum}")
                cum += counts[-1]
                le = _labelstr(fam.labelnames, values, [("le", "+Inf")])
                lines.append(f"{fam.name}_bucket{le} {cum}")
                ls = _labelstr(fam.labelnames, values)
                lines.append(f"{fam.name}_sum{ls} {_fmt(child.sum)}")
                lines.append(f"{fam.name}_count{ls} {child.count}")
            else:
                ls = _labelstr(fam.labelnames, values)
                lines.append(f"{fam.name}{ls} {_fmt(child.value)}")
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """``{sample_name_with_labels: value}`` from one text scrape — the
    minimal parser the monotonicity smoke (and tests) diff scrapes with."""
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        out[name] = float(value)
    return out


# -- JSONL -------------------------------------------------------------------

def span_records(spans: Iterable[Span]) -> List[dict]:
    """Spans as flat JSON-able dicts (the JSONL form of the trace)."""
    return [{
        "kind": "span", "name": s.name, "span_id": s.span_id,
        "parent_id": s.parent_id, "t0_s": s.t0_s, "dur_s": s.dur_s,
        "thread": s.thread, **dict(s.attrs),
    } for s in spans]


def write_jsonl(path_or_file: Union[str, IO], records: Iterable[dict],
                append: bool = True) -> int:
    """Write one JSON object per line; returns the number written.

    ``append=True`` (default) lets successive runs accumulate into one
    log; pass a file object to control the handle yourself.
    """
    n = 0
    if hasattr(path_or_file, "write"):
        f, close = path_or_file, False
    else:
        f, close = open(path_or_file, "a" if append else "w"), True
    try:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
            n += 1
    finally:
        if close:
            f.close()
    return n


def read_jsonl(path: str) -> List[dict]:
    """Load every record of a JSONL log (the test/analysis helper)."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# -- Chrome trace_event ------------------------------------------------------

def chrome_trace(spans_or_tracer: Union[Tracer, Iterable[Span]],
                 pid: int = 0) -> dict:
    """Spans as a Chrome ``trace_event`` document (complete ``"X"`` events).

    Timestamps are microseconds relative to the earliest span, one trace
    row (tid) per recording thread, span attributes under ``args`` —
    open the JSON at ``chrome://tracing`` / ui.perfetto.dev.
    """
    spans = (spans_or_tracer.spans()
             if isinstance(spans_or_tracer, Tracer) else list(spans_or_tracer))
    t_base = min((s.t0_s for s in spans), default=0.0)
    tids = {}
    events: List[dict] = []
    for s in spans:
        tid = tids.setdefault(s.thread, len(tids))
        events.append({
            "name": s.name, "ph": "X", "pid": pid, "tid": tid,
            "ts": (s.t0_s - t_base) * 1e6, "dur": s.dur_s * 1e6,
            "args": {**dict(s.attrs), "span_id": s.span_id,
                     "parent_id": s.parent_id},
        })
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": thread}} for thread, tid in tids.items()]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       spans_or_tracer: Union[Tracer, Iterable[Span]],
                       pid: int = 0) -> None:
    """Dump :func:`chrome_trace` to ``path`` (a ``.json`` timeline file)."""
    with open(path, "w") as f:
        json.dump(chrome_trace(spans_or_tracer, pid=pid), f)
