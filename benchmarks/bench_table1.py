"""Table I: SoTA comparison — our modeled numbers in the paper's metrics
next to the published figures for ElfCore and its competitors.

Measured-on-silicon values can't be reproduced on CPU; what we *can* compute
exactly are the structural quantities (memory cut, NCE, SOP counts) and the
modeled power from counted events × the paper's energy constants.
"""
from __future__ import annotations

from repro.core import sparsity as sp
from repro.core.energy import network_capacity_efficiency

PAPER = {
    # name: (neurons_scale, area_mm2, pj_per_sop, nce_published)
    "elfcore": (None, 0.62, 2.4, 1926),
    "anp_i_isscc23": (None, 1.25, 1.5, 825),
    "reckon_isscc22": (None, 0.45, 5.3, 328),
}


def run(quick: bool = True):
    rows = []
    # --- memory cut at the chip's own scale (512-512-512-16, 80% sparse)
    spec = sp.paper_spec_4groups(512, 0.8)
    bits_h1 = sp.memory_bits(512, 512, spec, weight_bits=8)
    dense_total = 2 * bits_h1["dense_bits"] + 2 * 512 * 16 * 8
    sparse_total = 2 * bits_h1["compact_bits"] + 2 * 512 * 16 * 8
    rows.append({"name": "table1/weight_memory_cut", "us_per_call": 0.0,
                 "derived": (f"value_only_cut={spec.sparsity:.2f};"
                             f"with_index_cut={1 - sparse_total / dense_total:.2f};"
                             f"paper_claim=3.8x_vs_sota=~{1 - 1 / 3.8:.2f}")})

    # --- NCE: back out the implied NN-scale from the published NCEs, then
    # verify our formula reproduces the published ordering and ratios.
    for name, (_, area, pj, nce_pub) in PAPER.items():
        implied_scale = nce_pub * area * pj
        ours = network_capacity_efficiency(implied_scale, area, pj)
        rows.append({"name": f"table1/nce_{name}", "us_per_call": 0.0,
                     "derived": f"published={nce_pub};formula_roundtrip={ours:.0f}"})

    # --- energy-efficiency ratios the paper headlines
    rows.append({"name": "table1/headline_ratios", "us_per_call": 0.0,
                 "derived": ("infer_energy_vs_isscc24=16x(paper);"
                             "learn_power_vs_isscc22=4.1x(paper);"
                             "mem_saving_same_scale=3.8x(paper);"
                             "our_modeled_uW=see_fig7_rows")})
    return rows
