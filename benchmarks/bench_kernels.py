"""Per-kernel structural benchmarks: VMEM working sets, grid work, HBM
traffic from the BlockSpec schedules (the TPU-honest numbers; wall-clock of
interpret mode is meaningless). Correctness itself is pytest's job."""
from __future__ import annotations

from repro.kernels.flash_attn.ops import hbm_bytes, xla_score_path_bytes

VMEM_BYTES = 128 * 1024 * 1024        # v5e per-core VMEM


def run(quick: bool = True):
    rows = []

    # nm_spmm: tile work and VMEM at the production shape (d_model 8192 x
    # d_ff tile, 2:8 over 128-blocks)
    bm = bk = bo = 128
    k, o, n, m = 8192, 8192, 2, 8
    tiles_dense = (k // bk) * (o // bo)
    tiles_sparse = tiles_dense * n // m
    vmem = (bm * bk + bk * bo + bm * bo * 4 // 2) * 2   # x + w + f32 acc
    rows.append({"name": "kernels/nm_spmm_8192", "us_per_call": 0.0,
                 "derived": (f"tiles={tiles_sparse}/{tiles_dense};"
                             f"vmem_per_step_B={vmem};"
                             f"fits_vmem={vmem < VMEM_BYTES}")})

    # lif: one fused pass vs 4 unfused elementwise round trips
    bn = 512 * 512
    rows.append({"name": "kernels/lif_fused", "us_per_call": 0.0,
                 "derived": (f"hbm_bytes_fused={3*bn*4 + 3*bn*4};"
                             f"hbm_bytes_unfused={4*2*3*bn*4};"
                             f"traffic_cut={1 - (6*bn*4)/(24*bn*4):.2f}")})

    # wu_outer: update bytes scale with density (compact layout only)
    dense_up = 512 * 512 * 4
    sparse_up = dense_up * 2 // 8
    rows.append({"name": "kernels/wu_outer_sparse_updates", "us_per_call": 0.0,
                 "derived": f"bytes_written={sparse_up}/{dense_up} (n:m=2:8)"})

    # flash attention: BlockSpec-exact traffic vs unfused score path at the
    # deepseek train cell's per-device slice
    fl = hbm_bytes(16, 4096, 4, 128)
    xla = xla_score_path_bytes(16, 4096, 4, 128)
    rows.append({"name": "kernels/flash_attn_traffic_4k", "us_per_call": 0.0,
                 "derived": (f"flash_B={fl:.3e};score_path_B={xla:.3e};"
                             f"cut={1 - fl/xla:.2f}")})
    fl32 = hbm_bytes(2, 32768, 4, 128)
    xla32 = xla_score_path_bytes(2, 32768, 4, 128)
    rows.append({"name": "kernels/flash_attn_traffic_32k", "us_per_call": 0.0,
                 "derived": (f"flash_B={fl32:.3e};score_path_B={xla32:.3e};"
                             f"cut={1 - fl32/xla32:.2f}")})
    return rows
