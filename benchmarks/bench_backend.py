"""Backend seam comparison: the same timestep engine, different kernels.

``core/engine.py`` routes its three inner ops (forward current, fused LIF,
WU outer product) through ``SNNConfig.backend``. This module drives one
jitted training step and one serving chunk step under

* ``ref``               — pure jnp on dense masked weights, and
* ``pallas-interpret``  — the Pallas kernels in emulation mode (what CPU CI
                          can check; on a TPU host ``pallas`` runs the real
                          kernels and this becomes a true perf comparison),

reporting per-step latency and the max |Δ| between the two trajectories —
the CSV analogue of tests/test_engine_backends.py. Shapes are deliberately
tiny: interpret mode unrolls every kernel grid point into the trace.

``--density`` sweeps the serving chunk step's delta layout at the kernel
level: at each N:M density the same chunk fn is timed with compact
``[S, L, J, T, bk, bo]`` deltas + mask-free ``{"wc", "idx"}`` params vs
the dense ``[S, L, Kmax, N]`` baseline, reporting per-step latency and
the exact bytes each layout holds (params + deltas).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.snn import (SNNConfig, init_params, init_state,
                            init_stream_deltas, init_stream_state,
                            make_train_fn, serving_params)
from repro.serving.adapt import make_chunk_fn

BASE = SNNConfig(n_in=16, n_hidden=16, n_layers=2, n_out=4, t_steps=6)
BACKENDS = ("ref", "pallas-interpret")

CLI_FLAGS = "--density"


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    del quick
    params = init_params(jax.random.PRNGKey(0), BASE)
    ev = jnp.asarray((np.random.default_rng(0).random(
        (BASE.t_steps, 8, BASE.n_in)) < 0.3).astype(np.float32))
    lab = jnp.asarray(np.arange(8) % BASE.n_out)
    evc = jnp.asarray((np.random.default_rng(1).random(
        (BASE.t_steps, 4, BASE.n_in)) < 0.3).astype(np.float32))
    valid = jnp.ones((BASE.t_steps, 4), bool)
    amask = jnp.ones((4,), bool)

    rows, train_out, serve_out = [], {}, {}
    for backend in BACKENDS:
        cfg = dataclasses.replace(BASE, backend=backend)
        step = make_train_fn(cfg)
        (p2, _, m), dt_tr = _time(step, params, init_state(cfg, 8), ev, lab)
        train_out[backend] = (np.asarray(m.logits),
                              np.asarray(p2["hidden"]["w"]))

        chunk = make_chunk_fn(cfg)
        (dl, _, cm), dt_sv = _time(
            chunk, params, init_stream_deltas(cfg, 4),
            init_stream_state(cfg, 4), evc, valid, amask)
        serve_out[backend] = (np.asarray(cm.logits), np.asarray(dl))
        rows.append({"name": f"backend/train_{backend}", "us_per_call": dt_tr,
                     "derived": f"sop_wu={float(m.sop_wu):.0f}"})
        rows.append({"name": f"backend/serve_{backend}", "us_per_call": dt_sv,
                     "derived": f"compiles={chunk.n_traces()}"})

    diff_tr = max(float(np.abs(a - b).max()) for a, b in
                  zip(train_out["ref"], train_out["pallas-interpret"]))
    diff_sv = max(float(np.abs(a - b).max()) for a, b in
                  zip(serve_out["ref"], serve_out["pallas-interpret"]))
    assert diff_tr < 1e-4 and diff_sv < 1e-4, (diff_tr, diff_sv)
    rows.append({"name": "backend/parity", "us_per_call": 0.0,
                 "derived": f"train_maxdiff={diff_tr:.2e};"
                            f"serve_maxdiff={diff_sv:.2e}"})
    return rows


def run_density(quick: bool = True):
    """Chunk-step latency + exact bytes held, compact vs dense, per
    N:M density. ``n_in = n_hidden = 32`` gives eighth-density
    granularity (m = 8 per 4-group fan-in split)."""
    densities = [0.125, 0.25, 0.5] if quick else [0.125, 0.25, 0.375,
                                                  0.5, 0.75]
    rng = np.random.default_rng(2)
    rows = []
    for density in densities:
        cfg = dataclasses.replace(BASE, n_in=32, n_hidden=32,
                                  sparsity=1.0 - density)
        params = init_params(jax.random.PRNGKey(0), cfg)
        evc = jnp.asarray((rng.random((cfg.t_steps, 4, cfg.n_in)) < 0.3)
                          .astype(np.float32))
        valid = jnp.ones((cfg.t_steps, 4), bool)
        amask = jnp.ones((4,), bool)
        state = init_stream_state(cfg, 4)
        chunk = make_chunk_fn(cfg)

        sp = serving_params(params, cfg)       # mask-free {"wc","idx",...}
        dc = init_stream_deltas(cfg, 4, compact=True)
        _, dt_c = _time(chunk, sp, dc, state, evc, valid, amask)
        bytes_c = sum(int(np.asarray(v).nbytes) for v in sp.values()) \
            + int(dc.nbytes)

        dd = init_stream_deltas(cfg, 4, compact=False)
        _, dt_d = _time(chunk, params, dd, state, evc, valid, amask)
        bytes_d = sum(int(np.asarray(leaf).nbytes) for leaf in
                      jax.tree_util.tree_leaves(params)) + int(dd.nbytes)

        spec = cfg.spec(cfg.n_in)
        rows.append({
            "name": f"backend/density{spec.n / spec.m:.3f}",
            "us_per_call": dt_c,
            "derived": (f"dense_us={dt_d:.1f}"
                        f" rel={dt_d / dt_c:.2f}"
                        f" bytes={bytes_c}"
                        f" dense_bytes={bytes_d}"
                        f" delta_bytes={int(dc.nbytes)}"
                        f" dense_delta_bytes={int(dd.nbytes)}"),
        })
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--density", action="store_true",
                    help="sweep compact-vs-dense delta layouts over N:M "
                         "densities (latency + exact bytes held)")
    args = ap.parse_args()
    rows = run_density(quick=False) if args.density else run(quick=True)
    for row in rows:
        print(row)
