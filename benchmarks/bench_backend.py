"""Backend seam comparison: the same timestep engine, different kernels.

``core/engine.py`` routes its three inner ops (forward current, fused LIF,
WU outer product) through ``SNNConfig.backend``. This module drives one
jitted training step and one serving chunk step under

* ``ref``               — pure jnp on dense masked weights, and
* ``pallas-interpret``  — the Pallas kernels in emulation mode (what CPU CI
                          can check; on a TPU host ``pallas`` runs the real
                          kernels and this becomes a true perf comparison),

reporting per-step latency and the max |Δ| between the two trajectories —
the CSV analogue of tests/test_engine_backends.py. Shapes are deliberately
tiny: interpret mode unrolls every kernel grid point into the trace.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.snn import (SNNConfig, init_params, init_state,
                            init_stream_deltas, init_stream_state,
                            make_train_fn)
from repro.serving.adapt import make_chunk_fn

BASE = SNNConfig(n_in=16, n_hidden=16, n_layers=2, n_out=4, t_steps=6)
BACKENDS = ("ref", "pallas-interpret")


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    del quick
    params = init_params(jax.random.PRNGKey(0), BASE)
    ev = jnp.asarray((np.random.default_rng(0).random(
        (BASE.t_steps, 8, BASE.n_in)) < 0.3).astype(np.float32))
    lab = jnp.asarray(np.arange(8) % BASE.n_out)
    evc = jnp.asarray((np.random.default_rng(1).random(
        (BASE.t_steps, 4, BASE.n_in)) < 0.3).astype(np.float32))
    valid = jnp.ones((BASE.t_steps, 4), bool)
    amask = jnp.ones((4,), bool)

    rows, train_out, serve_out = [], {}, {}
    for backend in BACKENDS:
        cfg = dataclasses.replace(BASE, backend=backend)
        step = make_train_fn(cfg)
        (p2, _, m), dt_tr = _time(step, params, init_state(cfg, 8), ev, lab)
        train_out[backend] = (np.asarray(m.logits),
                              np.asarray(p2["hidden"]["w"]))

        chunk = make_chunk_fn(cfg)
        (dl, _, cm), dt_sv = _time(
            chunk, params, init_stream_deltas(cfg, 4),
            init_stream_state(cfg, 4), evc, valid, amask)
        serve_out[backend] = (np.asarray(cm.logits), np.asarray(dl))
        rows.append({"name": f"backend/train_{backend}", "us_per_call": dt_tr,
                     "derived": f"sop_wu={float(m.sop_wu):.0f}"})
        rows.append({"name": f"backend/serve_{backend}", "us_per_call": dt_sv,
                     "derived": f"compiles={chunk.n_traces()}"})

    diff_tr = max(float(np.abs(a - b).max()) for a, b in
                  zip(train_out["ref"], train_out["pallas-interpret"]))
    diff_sv = max(float(np.abs(a - b).max()) for a, b in
                  zip(serve_out["ref"], serve_out["pallas-interpret"]))
    assert diff_tr < 1e-4 and diff_sv < 1e-4, (diff_tr, diff_sv)
    rows.append({"name": "backend/parity", "us_per_call": 0.0,
                 "derived": f"train_maxdiff={diff_tr:.2e};"
                            f"serve_maxdiff={diff_sv:.2e}"})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
