"""Benchmark harness: one module per paper table/figure + the roofline.

``PYTHONPATH=src python -m benchmarks.run [--full]`` prints
``name,us_per_call,derived`` CSV. ``--json out.json`` additionally
writes a machine-readable results artifact (schema below) so successive
runs accumulate a benchmark trajectory instead of scrolling away.
Modules:

  fig3  — async SerDes functional stand-in (packing/delay buffer)
  fig4  — OSSL ablations (PC/CC/depth/WU-locking)
  fig5  — DSST factorized sorting + accuracy restoration
  fig6  — input-stationary sparse forward path
  fig7  — five tasks: accuracy + modeled µW vs paper numbers, + depth sweep
  table1— memory cut / NCE / headline ratios
  serving — concurrent event-stream serving: throughput/latency/energy,
            incl. live-topology-evolution vs frozen baseline and the
            hot-path A/B (the module's --evolve / --pipeline / --factors
            CLI modes run the focused sweeps; --dryrun lists them)
  backend — engine backend seam: ref vs pallas-interpret step + parity
  roofline — per-(arch×shape×mesh) terms from dry-run artifacts (if present)

``--dryrun`` only verifies every module imports and registers a ``run``
callable — the CI smoke step that keeps registration from rotting.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

ARTIFACT_SCHEMA = "repro-bench/1"


def write_artifact(path: str, rows: list, *, failed: int = 0,
                   argv=None, contracts_checked=None) -> dict:
    """Write the ``--json`` results artifact; returns the document.

    Schema ``repro-bench/1``: top-level ``schema``/``created_unix_s``/
    ``argv``/``failed``/``contracts_checked`` plus ``rows`` — each row
    carries the CSV triple (``name``, ``us_per_call``, ``derived``)
    verbatim and, when a module attached them, structured extras:
    ``metrics`` (a flat dict of derived numbers, e.g. the serving rows'
    overlap ratio and per-phase p50/p99) and ``obs`` (a
    ``MetricsRegistry.snapshot()`` of the run). ``contracts_checked`` is
    ``repro.analysis.registry.summary()`` — which entrypoint contract
    sets held when the numbers were taken (``None`` if the registry
    could not run).
    """
    doc = {
        "schema": ARTIFACT_SCHEMA,
        "created_unix_s": time.time(),
        "argv": list(sys.argv if argv is None else argv),
        "failed": int(failed),
        "contracts_checked": contracts_checked,
        "rows": [{
            "name": r["name"],
            "us_per_call": float(r["us_per_call"]),
            "derived": str(r["derived"]),
            **({"metrics": r["metrics"]} if "metrics" in r else {}),
            **({"obs": r["obs"]} if "obs" in r else {}),
        } for r in rows],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="longer training runs")
    ap.add_argument("--only", default="", help="comma list of module names")
    ap.add_argument("--dryrun", action="store_true",
                    help="verify benchmark registration only (CI smoke)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write a machine-readable results artifact")
    args = ap.parse_args()
    quick = not args.full

    from . import (bench_backend, bench_fig3_serdes, bench_fig4_ossl,
                   bench_fig5_dsst, bench_fig6_datapath, bench_fig7_tasks,
                   bench_kernels, bench_serving_streams, bench_table1,
                   roofline)
    modules = {
        "fig3": bench_fig3_serdes, "fig4": bench_fig4_ossl,
        "fig5": bench_fig5_dsst, "fig6": bench_fig6_datapath,
        "fig7": bench_fig7_tasks, "table1": bench_table1,
        "kernels": bench_kernels, "serving": bench_serving_streams,
        "backend": bench_backend, "roofline": roofline,
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    if args.dryrun:
        bad = [k for k, m in modules.items()
               if not callable(getattr(m, "run", None))]
        for k in sorted(modules):
            status = "BROKEN" if k in bad else "REGISTERED"
            # modules with focused CLI modes advertise them (CLI_FLAGS) so
            # the dryrun doubles as the flag index — e.g. serving lists its
            # --devices / --evolve / --pipeline / --factors A/B sweeps
            flags = getattr(modules[k], "CLI_FLAGS", "")
            print(f"{k},0.00,{status}" + (f" {flags}" if flags else ""))
        if bad:
            sys.exit(1)
        return

    print("name,us_per_call,derived")
    failed = 0
    collected = []
    for key, mod in modules.items():
        try:
            for row in mod.run(quick=quick):
                print(f"{row['name']},{row['us_per_call']:.2f},{row['derived']}")
                collected.append(row)
        except Exception:
            failed += 1
            print(f"{key},0.00,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
            collected.append({"name": key, "us_per_call": 0.0,
                              "derived": "ERROR"})
    if args.json:
        # stamp the artifact with the contract-registry result: benchmark
        # numbers only mean something if the hot path's structural
        # invariants held when they were taken
        try:
            from repro.analysis import registry as _registry
            contracts = _registry.summary()
        except Exception:
            traceback.print_exc(file=sys.stderr)
            contracts = None
        # written even on partial failure (failed > 0 is recorded in the
        # artifact) so a flaky module never costs the whole trajectory point
        write_artifact(args.json, collected, failed=failed,
                       contracts_checked=contracts)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
