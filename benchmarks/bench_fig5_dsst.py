"""Fig. 5: DSST efficiency + accuracy restoration.

(a) Sorting cost: dense synapse-level regrow scoring vs the paper's
    factorized neuron-level scoring (one sort per group, reused across all
    output neurons) — wall time and asymptotic op counts.
(b) Accuracy: static sparse vs DSST (sparse-to-sparse) vs dense, end-to-end
    on a synthetic task (the paper: DSST ≈ dense − ~2 %, ≫ static).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsst, sparsity as sp
from repro.core.dsst import DSSTConfig
from repro.core.snn import (SNNConfig, accuracy, init_params, init_state,
                            make_eval_fn, make_train_fn)
from repro.data.events import make_task


def _time(fn, *args, reps=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def sorting_cost(k=512, o=512):
    spec = sp.paper_spec_4groups(k, 0.8)
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    mask = sp.random_unit_mask(keys[0], spec, k, o)
    wsc = sp.unit_scores(jnp.abs(jax.random.normal(keys[1], (k, o))), spec, k, o)
    pre = jnp.abs(jax.random.normal(keys[2], (k,)))
    post = jnp.abs(jax.random.normal(keys[3], (o,)))
    kk = 4

    dense_fn = jax.jit(lambda m, w, g: dsst.prune_regrow(m, w, g, spec, kk)[0])
    fact_fn = jax.jit(lambda m, w, p_, q_: dsst.prune_regrow_factored(
        m, w, p_, q_, spec, kk)[0])
    gsc = sp.unit_scores(jnp.abs(jnp.outer(pre, post)), spec, k, o)

    t_dense = _time(dense_fn, mask, wsc, gsc)
    t_fact = _time(fact_fn, mask, wsc, pre, post)
    # sorted-element counts: synapse-level sorts K*O keys, neuron-level K + O
    return [
        {"name": "fig5/sort_dense_synapse_level", "us_per_call": t_dense,
         "derived": f"keys_sorted={k*o}"},
        {"name": "fig5/sort_factored_neuron_level", "us_per_call": t_fact,
         "derived": f"keys_sorted={k + o};speedup={t_dense / t_fact:.2f}x"},
    ]


def accuracy_comparison(quick=True):
    steps = 120 if quick else 400
    task = make_task("shd_kws", n_in=64, t_steps=20)
    results = {}
    for name, kw in [
        ("dense", dict(dense=True)),
        ("static_sparse", dict(dsst_enabled=False)),
        ("dsst", dict()),
    ]:
        cfg = SNNConfig(n_in=64, n_hidden=64, n_out=10, t_steps=20,
                        dsst=DSSTConfig(period=10, prune_frac=0.25), **kw)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_state(cfg, batch=16)
        step = make_train_fn(cfg)
        rng = np.random.default_rng(1)
        t0 = time.perf_counter()
        for i in range(steps):
            ev, lab = task.sample(rng, 16)
            params, state, _ = step(params, state, jnp.asarray(ev), jnp.asarray(lab))
        dt = (time.perf_counter() - t0) / steps * 1e6
        ev, lab = task.sample(np.random.default_rng(999), 128)
        ef = make_eval_fn(cfg)
        _, m = ef(params, init_state(cfg, batch=128), jnp.asarray(ev))
        acc = float(accuracy(m.logits, jnp.asarray(lab)))
        results[name] = (acc, dt)
    rows = []
    for name, (acc, dt) in results.items():
        rows.append({"name": f"fig5/train_{name}", "us_per_call": dt,
                     "derived": f"acc={acc:.3f}"})
    gap_dense = results["dense"][0] - results["dsst"][0]
    gain_static = results["dsst"][0] - results["static_sparse"][0]
    rows.append({"name": "fig5/dsst_restores_accuracy", "us_per_call": 0.0,
                 "derived": f"dsst_vs_dense_gap={gap_dense:+.3f};"
                            f"dsst_vs_static_gain={gain_static:+.3f}"})
    return rows


def run(quick: bool = True):
    return sorting_cost() + accuracy_comparison(quick)
