"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch × shape × mesh), seconds per step, TPU v5e constants:

    compute    = HLO_FLOPs_global / (chips × 197e12)      [bf16 MXU peak]
    memory     = HLO_bytes_per_device / 819e9             [HBM BW]
    collective = wire_bytes_per_device / 50e9             [per-link ICI BW]

``HLO_FLOPs_global = flops_per_device × chips`` (cost_analysis reports the
per-device SPMD module; probe-corrected for scan bodies, see dryrun.py).
Wire bytes use the ring model per collective (dryrun.parse_collectives).

Derived:
* MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (prefill,
  decode) — the "useful" flops;
* utilisation = MODEL_FLOPS / HLO_FLOPs_global (catches remat/redundancy);
* bound = max(compute, memory, collective): the step-time floor;
* MFU_bound = (MODEL_FLOPS / (chips·peak)) / bound — the MFU the step would
  achieve *at* its binding roofline: the score we hillclimb in §Perf.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_ART = os.path.join(os.path.dirname(__file__), "artifacts")


def model_flops(arch: str, shape: str) -> float:
    import repro.configs as C
    cfg = C.get_config(C.normalize(arch.replace("-", "_")))
    n = cfg.active_param_count()
    sh = C.SHAPES[shape]
    if sh.kind == "train":
        return 6.0 * n * sh.tokens
    if sh.kind == "prefill":
        return 2.0 * n * sh.tokens
    return 2.0 * n * sh.global_batch      # decode: one token per sequence


def analyze(rec: Dict) -> Optional[Dict]:
    if "skipped" in rec:
        return None
    chips = rec["n_devices"]
    flops_global = rec["flops_per_device"] * chips
    compute = flops_global / (chips * PEAK_FLOPS)
    memory = rec["bytes_per_device"] / HBM_BW
    coll = rec["collective_wire_bytes_per_device"] / ICI_BW
    mf = model_flops(rec["arch"], rec["shape"])
    bound = max(compute, memory, coll)
    dom = ("compute" if bound == compute else
           "memory" if bound == memory else "collective")
    util = mf / flops_global if flops_global else 0.0
    mfu_bound = (mf / (chips * PEAK_FLOPS)) / bound if bound else 0.0
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dom, "model_flops": mf, "hlo_flops_global": flops_global,
        "useful_ratio": util, "mfu_bound": mfu_bound,
        "mem_per_dev_GB": rec.get("memory", {}).get("peak_estimate_bytes", 0) / 1e9,
    }
    out["lever"] = _lever(out)
    return out


def _lever(r: Dict) -> str:
    if r["dominant"] == "collective":
        return ("shrink TP payloads (comm-avoiding sharding / gradient "
                "compression on the DP axis) or overlap collectives with MXU work")
    if r["dominant"] == "memory":
        if "decode" in r["shape"] or r["shape"] == "long_500k":
            return ("decode is weight/KV-streaming bound: shrink resident bytes "
                    "(N:M compact weights, KV window/quantisation) or raise batch")
        return ("cut HBM traffic: fuse softmax/loss chunks, avoid f32 logit "
                "materialisation, rematerialise less")
    return "already MXU-bound: raise useful_ratio (less remat/redundant compute)"


def load_all(art_dir: str = _ART, tag: str = "") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        r = analyze(json.load(open(f)))
        if r:
            rows.append(r)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
           "| dominant | useful | MFU@bound | mem/dev GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                 f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
                 f"| {r['collective_s']*1e3:.2f} | **{r['dominant']}** "
                 f"| {r['useful_ratio']:.2f} | {r['mfu_bound']*100:.1f}% "
                 f"| {r['mem_per_dev_GB']:.1f} |\n")
    return hdr + body


def run(quick: bool = True):
    out = []
    for tag in ("", "opt"):
        for r in load_all(tag=tag):
            label = f"roofline{'_' + tag if tag else ''}"
            out.append({"name": f"{label}/{r['arch']}/{r['shape']}/{r['mesh']}",
                        "us_per_call": max(r["compute_s"], r["memory_s"],
                                           r["collective_s"]) * 1e6,
                        "derived": (f"dom={r['dominant']};"
                                    f"mfu_bound={r['mfu_bound']:.3f};"
                                    f"mem_dev_GB={r['mem_per_dev_GB']:.1f}")})
    return out


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    rows = load_all(tag=sys.argv[1] if len(sys.argv) > 1 else "")
    print(markdown_table(rows))
