"""Fig. 6: accelerated forward data paths.

(a) CPU-measurable effect of structured sparsity on the forward matmul:
    dense x@W vs compact gather-matmul (the paper's input-stationary sparse
    path; FLOPs and weight traffic scale with n/m).
(b) The Pallas kernel's work accounting (grid iterations × MXU tile work —
    structural, since interpret-mode timing is meaningless).
(c) Dual-path reuse: spikes and traces share one gathered activation tile.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import sparsity as sp
from repro.kernels.nm_spmm import ops as nm_ops


def _timeit(fn, *a, reps=30):
    fn(*a)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    k = o = 2048
    b = 256
    n, m, bk, bo = 2, 8, 128, 128
    spec = sp.NMSpec(n=n, m=m, block=bk, out_tile=o)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.normal(keys[0], (k, o), jnp.float32)
    x = jax.random.normal(keys[1], (b, k), jnp.float32)
    umask = sp.random_unit_mask(keys[2], spec, k, o)      # [KB, 1] shared
    rows_idx = jnp.where(jnp.repeat(umask[:, 0], bk))[0].astype(jnp.int32)
    w_compact = w[rows_idx]

    dense = jax.jit(lambda x, w: x @ w)
    sparse = jax.jit(lambda x, wc, r: jnp.take(x, r, axis=-1) @ wc)

    t_d = _timeit(dense, x, w)
    t_s = _timeit(sparse, x, w_compact, rows_idx)

    bits = sp.memory_bits(k, o, sp.NMSpec(n, m, bk, o))
    rows = [
        {"name": "fig6/forward_dense_2048", "us_per_call": t_d,
         "derived": f"flops={2*b*k*o:.3e}"},
        {"name": "fig6/forward_nm_sparse_2048", "us_per_call": t_s,
         "derived": (f"flops={2*b*k*o*n//m:.3e};speedup={t_d/t_s:.2f}x;"
                     f"weight_mem_cut={bits['reduction']:.2f}")},
    ]

    # Pallas kernel structural accounting (small shape, interpret-validated)
    kk, oo, bkk, boo = 256, 256, 32, 32
    spec2 = sp.NMSpec(2, 8, block=bkk, out_tile=boo)
    mask2 = sp.random_unit_mask(jax.random.PRNGKey(1), spec2, kk, oo)
    wc, idx = nm_ops.make_compact(jax.random.normal(jax.random.PRNGKey(2), (kk, oo)),
                                  mask2, bkk, boo)
    j, t = idx.shape
    grid_iters_sparse = (64 // 32) * j * t
    grid_iters_dense = (64 // 32) * (oo // boo) * (kk // bkk)
    rows.append({"name": "fig6/pallas_grid_iterations", "us_per_call": 0.0,
                 "derived": (f"sparse_tiles={grid_iters_sparse};"
                             f"dense_tiles={grid_iters_dense};"
                             f"ratio={grid_iters_sparse/grid_iters_dense:.2f}")})

    # dual forward path: one gather serves both spikes and traces
    spikes = (jax.random.uniform(jax.random.PRNGKey(3), (b, k)) < 0.1).astype(jnp.float32)
    traces = jax.random.uniform(jax.random.PRNGKey(4), (b, k))
    dual = jax.jit(lambda s, tr, wc, r: (jnp.take(s, r, -1) @ wc,
                                         jnp.take(tr, r, -1) @ wc))
    t_dual = _timeit(dual, spikes, traces, w_compact, rows_idx)
    rows.append({"name": "fig6/dual_path_sparse", "us_per_call": t_dual,
                 "derived": f"vs_2x_single={t_dual/(2*t_s):.2f}"})
    return rows
