"""Fig. 7 / Table I: the five tasks end-to-end — accuracy, gating skip rate,
and modeled power at the chip's operating point (core/energy.py).

Paper claims validated *relatively* (DESIGN.md §3): DSST at 80 % sparsity
cuts learn/infer energy vs dense with small accuracy cost; IA/SS gating cuts
WU energy beyond zero-skipping; all-task modeled power < 50 µW @ 0.6 V.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsst import DSSTConfig
from repro.core.energy import OperatingPoint, report
from repro.core.gating import GatingConfig, skip_rate
from repro.core.snn import (SNNConfig, accuracy, init_params, init_state,
                            make_eval_fn, make_train_fn)
from repro.data.events import TASK_NAMES, make_task

PAPER_POWER_UW = {"gesture": (32.3, 49.2), "nmnist": (28.7, 42.9),
                  "shd_kws": (25.1, 40.5), "eeg_emotion": (20.3, 31.2),
                  "nav_cue": (17.6, 27.8)}


def _train_eval(task, cfg, steps, batch=16, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    state = init_state(cfg, batch=batch)
    step = make_train_fn(cfg)
    rng = np.random.default_rng(seed + 1)
    sop_f = sop_w = sop_off = 0.0
    t0 = time.perf_counter()
    for i in range(steps):
        ev, lab = task.sample(rng, batch)
        params, state, m = step(params, state, jnp.asarray(ev), jnp.asarray(lab))
        sop_f += float(m.sop_forward)
        sop_w += float(m.sop_wu)
        sop_off += float(m.sop_wu_offered)
    dt = (time.perf_counter() - t0) / steps * 1e6
    ef = make_eval_fn(cfg)
    ev, lab = task.sample(np.random.default_rng(9999), 128)
    st_e = init_state(cfg, batch=128)
    _, me = ef(params, st_e, jnp.asarray(ev))
    acc = float(accuracy(me.logits, jnp.asarray(lab)))
    n_ts = steps * cfg.t_steps
    learn = report(sop_f / steps / batch, sop_w / steps / batch,
                   sop_off / steps / batch, cfg.t_steps)
    infer = report(float(me.sop_forward) / 128, 0, 0, cfg.t_steps)
    return {"acc": acc, "us_per_sample": dt / batch,
            "learn_uW": learn.power_w * 1e6, "infer_uW": infer.power_w * 1e6,
            "wu_skip": learn.wu_skip_rate, "gate_skip": float(skip_rate(state.gate))}


def depth_sweep(quick: bool = True):
    """Fig. 7 depth study on the layer-stacked engine: n_layers ∈ {1,2,3,4}.

    One lax.scan over the [L, ...] layer axis (core/engine.py), so depth
    changes neither trace size nor compile time — only runtime.
    """
    steps = 60 if quick else 200
    task = make_task("shd_kws", n_in=64, t_steps=20)
    rows = []
    for depth in (1, 2, 3, 4):
        cfg = SNNConfig(n_in=64, n_hidden=64, n_out=10, t_steps=20,
                        n_layers=depth,
                        dsst=DSSTConfig(period=10, prune_frac=0.25))
        r = _train_eval(task, cfg, steps)
        rows.append({
            "name": f"fig7/depth{depth}",
            "us_per_call": r["us_per_sample"],
            "derived": (f"acc={r['acc']:.3f};learn_uW={r['learn_uW']:.1f};"
                        f"wu_skip={r['wu_skip']:.2f}")})
    return rows


def run(quick: bool = True):
    steps = 100 if quick else 300
    n_in, t_steps = 64, 20           # reduced chip (full 512x50 in examples/)
    rows = []
    for name in TASK_NAMES:
        task = make_task(name, n_in=n_in, t_steps=t_steps)
        n_out = max(task.n_classes, 4)
        base = dict(n_in=n_in, n_hidden=64, n_out=n_out, t_steps=t_steps,
                    dsst=DSSTConfig(period=10, prune_frac=0.25))
        sparse = _train_eval(task, SNNConfig(**base), steps)
        dense = _train_eval(task, SNNConfig(dense=True, **base), steps)
        nogate = _train_eval(
            task, SNNConfig(gating=GatingConfig(enabled=False), **base), steps)
        p_inf, p_learn = PAPER_POWER_UW[name]
        rows.append({
            "name": f"fig7/{name}", "us_per_call": sparse["us_per_sample"],
            "derived": (f"acc={sparse['acc']:.3f};acc_dense={dense['acc']:.3f};"
                        f"learn_uW={sparse['learn_uW']:.1f};"
                        f"infer_uW={sparse['infer_uW']:.1f};"
                        f"paper_uW={p_inf}/{p_learn};"
                        f"learn_power_cut_vs_dense="
                        f"{1 - sparse['learn_uW'] / dense['learn_uW']:.2f};"
                        f"gating_power_cut_vs_zk="
                        f"{1 - sparse['learn_uW'] / max(nogate['learn_uW'], 1e-9):.2f};"
                        f"wu_skip={sparse['wu_skip']:.2f}")})
    return rows + depth_sweep(quick)
