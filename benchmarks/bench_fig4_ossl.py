"""Fig. 4: OSSL ablations — concurrent PC+CC vs PC-only vs CC-only, and the
depth study enabled by the bypass readout (1 vs 2 hidden layers).

Also measures the WU-locking claim structurally: in local mode every layer's
update depends only on its own forward quantities, so the critical path per
timestep is 1 layer-update regardless of depth (vs backprop's L)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsst import DSSTConfig
from repro.core.snn import (SNNConfig, accuracy, init_params, init_state,
                            make_eval_fn, make_train_fn)
from repro.data.events import make_task


def _acc(cfg, task, steps, seed=0):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    state = init_state(cfg, batch=16)
    step = make_train_fn(cfg)
    rng = np.random.default_rng(seed + 1)
    t0 = time.perf_counter()
    for _ in range(steps):
        ev, lab = task.sample(rng, 16)
        params, state, _ = step(params, state, jnp.asarray(ev), jnp.asarray(lab))
    dt = (time.perf_counter() - t0) / steps * 1e6
    ev, lab = task.sample(np.random.default_rng(999), 128)
    ef = make_eval_fn(cfg)
    _, m = ef(params, init_state(cfg, batch=128), jnp.asarray(ev))
    return float(accuracy(m.logits, jnp.asarray(lab))), dt


def run(quick: bool = True):
    steps = 100 if quick else 300
    task = make_task("shd_kws", n_in=64, t_steps=20)
    base = dict(n_in=64, n_hidden=64, n_out=10, t_steps=20,
                dsst=DSSTConfig(period=10, prune_frac=0.25))
    rows = []
    for name, kw in [
        ("pc_and_cc", dict(cc_weight=1.0)),
        ("pc_only", dict(cc_weight=0.0)),
        ("cc_dominant", dict(cc_weight=4.0)),
        ("readout_only", dict(lr=0.0)),
        ("depth1", dict(n_layers=1)),
        ("depth2", dict(n_layers=2)),
    ]:
        cfg = SNNConfig(**{**base, **kw})
        acc, dt = _acc(cfg, task, steps)
        rows.append({"name": f"fig4/{name}", "us_per_call": dt,
                     "derived": f"acc={acc:.3f}"})

    # WU-locking: layer-parallel local updates — critical path depth is O(1)
    rows.append({"name": "fig4/wu_locking", "us_per_call": 0.0,
                 "derived": "local_rule_critical_path_layers=1;"
                            "backprop_critical_path_layers=n_layers"})
    return rows
