"""Serving: concurrent event streams through one jitted slot-grid step.

Throughput (events/s, timesteps/s) and p50/p99 grid-step latency vs the
number of concurrent streams, with the per-stream energy rollup priced at
the chip's 0.6 V operating point. Hard guarantee checked here: after the
first compilation, multiplexing any number of streams through the fixed
slot grid triggers **zero recompilation** (jit cache size stays 1) — the
serving analogue of the continuous batcher's static-shape discipline.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.snn import SNNConfig, init_params
from repro.data.events import make_task
from repro.serving import (ArrivalConfig, FleetTelemetry, StreamScheduler,
                           StreamSession, TaskStreamSource)

N_IN, N_HIDDEN, T_STEPS = 64, 64, 20
CHUNK_LEN = 10


def _drive(n_streams: int, n_slots: int, n_windows: int, seed: int = 0):
    cfg = SNNConfig(n_in=N_IN, n_hidden=N_HIDDEN, n_layers=2, n_out=10,
                    t_steps=T_STEPS)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    task = make_task("gesture", n_in=N_IN, t_steps=T_STEPS, seed=seed)
    sched = StreamScheduler(params, cfg, n_slots=n_slots, chunk_len=CHUNK_LEN)
    arrival = ArrivalConfig(min_chunk=4, max_chunk=CHUNK_LEN, mean_gap_s=1e-4)
    for sid in range(n_streams):
        sched.submit(StreamSession(
            sid=sid,
            source=TaskStreamSource(task, n_windows=n_windows, seed=sid,
                                    arrival=arrival)))
    sched.step()                     # warmup step compiles the grid
    compiles_after_warmup = sched.n_compiles
    # measured window excludes warmup on both sides of the rate: fresh
    # telemetry drops the warmup step's latency AND its counted events
    sched.telemetry = FleetTelemetry()
    done = sched.run_until_drained()
    assert len(done) == n_streams, (len(done), n_streams)
    assert compiles_after_warmup == 1 and sched.n_compiles == 1, \
        f"slot-grid step recompiled: {sched.n_compiles} variants"
    return sched


def run(quick: bool = True):
    rows = []
    cases = [(8, 8, 2), (32, 32, 2)] if quick else \
        [(8, 8, 4), (32, 32, 4), (64, 32, 4)]
    for n_streams, n_slots, n_windows in cases:
        sched = _drive(n_streams, n_slots, n_windows)
        r = sched.telemetry.rollup()
        per = sched.telemetry.per_stream()
        mean_uw = float(np.mean([p["power_uW"] for p in per]))
        rows.append({
            "name": f"serving/streams{n_streams}_slots{n_slots}",
            "us_per_call": r["p50_ms"] * 1e3,
            "derived": (f"events/s={r['events_per_s']:.0f}"
                        f" ts/s={r['timesteps_per_s']:.0f}"
                        f" p99_ms={r['p99_ms']:.2f}"
                        f" util={sched.utilization:.2f}"
                        f" skip={r['wu_skip_rate']:.2f}"
                        f" stream_uW={mean_uw:.1f}"
                        f" compiles={sched.n_compiles}"),
        })
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
