"""Serving: concurrent event streams through one jitted slot-grid step.

Throughput (events/s, timesteps/s) and p50/p99 grid-step latency vs the
number of concurrent streams, with the per-stream energy rollup priced at
the chip's 0.6 V operating point. Hard guarantee checked here: after the
first compilation, multiplexing any number of streams through the fixed
slot grid triggers **zero recompilation** (jit cache size stays 1) — the
serving analogue of the continuous batcher's static-shape discipline.

``--devices N`` sweeps the sharded slot grid: the same workload is driven
with the slot axis sharded over 1, 2, ..., N host devices (each count in a
fresh subprocess, since XLA pins the device count at init) and events/s
scaling vs the 1-device baseline is reported. On a CPU host the "devices"
share physical cores, so this validates the sharded path's overhead and
mechanics rather than demonstrating real speedup — on a multi-chip host
the same sweep reports true slot-throughput scaling.

``--evolve EVERY`` drives the same workload with and without the live
topology service (DSST prune/regrow epochs every EVERY grid steps, hot
streams folded into the base) and reports events/s for both plus epoch
count and mask-change fraction — the cost of evolving connectivity under
traffic. The hard guarantee extends: topology swaps included, the grid
step still compiles exactly once. A quick with/without pair also runs as
part of the default ``run()`` so the harness tracks it.

Rows carry the observability signals next to throughput: the per-phase
stage/dispatch/retire p50/p99 walls (``phase_ms=...``) and — for the
pipelined A/B rows — the measured host/device **overlap ratio**
(``overlap=``, ~1 host-bound / ~0 device-bound; docs/OBSERVABILITY.md).
Under ``benchmarks.run --json`` each row additionally ships a structured
``metrics`` dict and a full ``obs`` registry snapshot.

``--pipeline on|off`` / ``--factors on|off`` A/B the serving hot path
against the serial baseline (pipeline off, DSST factors compiled in):
double-buffered event staging overlaps host chunk packing with device
compute, and ``want_factors=off`` compiles the O(S·(K+N))-per-timestep
DSST factor accumulators out of the chunk scan. Rows report events/s for
the baseline and the configured mode plus their ratio; trajectories are
bit-identical across all four combinations (pinned in
``tests/test_serving_pipeline.py``). A quick A/B pair also rides in the
default ``run()`` rows.

``--density quick|full`` A/Bs the compact ``[S, L, J, T, bk, bo]`` delta
layout (the hot-path default — only kept N:M blocks are stored and the
chunk jaxpr carries no dense mask) against the dense ``[S, L, Kmax, N]``
baseline at each N:M density: events/s for both plus the **measured**
weight-state footprint from the ``serving_bytes_held`` gauge. Compact
delta bytes scale ~linearly with density (the paper's "3.8× reduced
on-chip memory" analogue); dense bytes stay flat. A single quick pair
also rides in the default ``run()`` rows.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core.snn import SNNConfig, init_params
from repro.data.events import make_task
from repro.serving import (AERStreamSource, ArrivalConfig, AutopilotConfig,
                           FleetTelemetry, IngestConfig, StreamScheduler,
                           StreamSession, TaskStreamSource, TierConfig,
                           TopologyService, TopologyServiceConfig)

N_IN, N_HIDDEN, T_STEPS = 64, 64, 20
CHUNK_LEN = 10

# printed by ``benchmarks.run --dryrun`` so the module's focused CLI modes
# are discoverable (and their registration can't rot silently)
CLI_FLAGS = ("--devices N | --evolve EVERY | --pipeline on|off "
             "| --factors on|off | --density quick|full "
             "| --tiers on|off --adaptive on|off [--json PATH]")

# the QoS A/B's traffic: AER-packed chunks (real decode cost at poll) on
# jittered Poisson arrivals — the shape async ingestion is for
QOS_ARRIVAL = ArrivalConfig(min_chunk=3, max_chunk=CHUNK_LEN + 3,
                            mean_gap_s=1e-3, start_jitter_s=0.01)


def _drive(n_streams: int, n_slots: int, n_windows: int, seed: int = 0,
           mesh=None, evolve_every: int = 0, merge_top: int = 2,
           pipeline: int = 0, want_factors=None, tracer=None,
           sparsity=None, compact=None, ingest=None, autopilot=None,
           tiers=None, tier_of=None, aer: bool = False, arrival=None):
    cfg = SNNConfig(n_in=N_IN, n_hidden=N_HIDDEN, n_layers=2, n_out=10,
                    t_steps=T_STEPS,
                    **({} if sparsity is None else {"sparsity": sparsity}))
    params = init_params(jax.random.PRNGKey(seed), cfg)
    task = make_task("gesture", n_in=N_IN, t_steps=T_STEPS, seed=seed)
    topo = None
    if evolve_every:
        topo = TopologyService(cfg, TopologyServiceConfig(
            epoch_every=evolve_every, merge_top=merge_top))
    sched = StreamScheduler(params, cfg, n_slots=n_slots, chunk_len=CHUNK_LEN,
                            mesh=mesh, topology=topo, pipeline_depth=pipeline,
                            want_factors=want_factors, tracer=tracer,
                            compact=compact, ingest=ingest,
                            autopilot=autopilot, tiers=tiers)
    arrival = arrival or ArrivalConfig(min_chunk=4, max_chunk=CHUNK_LEN,
                                       mean_gap_s=1e-4)
    Source = AERStreamSource if aer else TaskStreamSource
    for sid in range(n_streams):
        sched.submit(StreamSession(
            sid=sid,
            source=Source(task, n_windows=n_windows, seed=sid,
                          arrival=arrival)),
            tier=tier_of(sid) if tier_of is not None else None)
    sched.step()                     # warmup step compiles the grid(s)
    sched.flush()                    # ...and lands its bookkeeping (pipeline)
    compiles_after_warmup = sched.n_compiles
    # measured window excludes warmup on both sides of the rate: fresh
    # telemetry drops the warmup step's latency AND its counted events
    # (topology epochs keep counting in the service itself)
    sched.telemetry = FleetTelemetry()
    done = sched.run_until_drained()
    sched.close()                    # stop the ingest worker, if any
    assert len(done) == n_streams, (len(done), n_streams)
    assert compiles_after_warmup == 1 and sched.n_compiles == 1, \
        f"slot-grid step recompiled: {sched.n_compiles} variants"
    return sched


def _phase_str(tel) -> str:
    """Compact per-phase p50/p99 for the derived column, e.g.
    ``phase_ms=stage:0.4/1.1,dispatch:0.2/0.5,retire:0.3/0.9``."""
    ph = tel.phase_percentiles()
    parts = [f"{p}:{d['p50_ms']:.2f}/{d['p99_ms']:.2f}"
             for p, d in sorted(ph.items())]
    return "phase_ms=" + ",".join(parts) if parts else "phase_ms=none"


def _row_extras(sched) -> dict:
    """Structured extras for the ``--json`` artifact: the obs-derived
    numbers (overlap ratio, per-phase p50/p99) plus a full registry
    snapshot of the run's metrics."""
    tel = sched.telemetry
    r = tel.rollup()
    metrics = {
        "events_per_s": r["events_per_s"],
        "timesteps_per_s": r["timesteps_per_s"],
        "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
        "overlap_ratio": r["overlap_ratio"],
        "grid_steps": r["grid_steps"],
        "compiles": sched.n_compiles,
    }
    for phase, d in tel.phase_percentiles().items():
        metrics[f"phase_{phase}_p50_ms"] = d["p50_ms"]
        metrics[f"phase_{phase}_p99_ms"] = d["p99_ms"]
        metrics[f"phase_{phase}_total_s"] = d["total_s"]
    return {"metrics": metrics, "obs": tel.registry.snapshot()}


def run(quick: bool = True):
    rows = []
    frozen_baseline = None
    cases = [(8, 8, 2), (32, 32, 2)] if quick else \
        [(8, 8, 4), (32, 32, 4), (64, 32, 4)]
    for n_streams, n_slots, n_windows in cases:
        sched = _drive(n_streams, n_slots, n_windows)
        if (n_streams, n_slots, n_windows) == _evolve_case(quick):
            frozen_baseline = sched      # reused by the evolve row below
        r = sched.telemetry.rollup()
        per = sched.telemetry.per_stream()
        mean_uw = float(np.mean([p["power_uW"] for p in per]))
        rows.append({
            "name": f"serving/streams{n_streams}_slots{n_slots}",
            "us_per_call": r["p50_ms"] * 1e3,
            "derived": (f"events/s={r['events_per_s']:.0f}"
                        f" ts/s={r['timesteps_per_s']:.0f}"
                        f" p99_ms={r['p99_ms']:.2f}"
                        f" util={sched.utilization:.2f}"
                        f" skip={r['wu_skip_rate']:.2f}"
                        f" stream_uW={mean_uw:.1f}"
                        f" {_phase_str(sched.telemetry)}"
                        f" compiles={sched.n_compiles}"),
            **_row_extras(sched),
        })
    rows += run_evolve(quick=quick, frozen=frozen_baseline)
    rows += run_ab(quick=quick)
    rows += run_density(quick=True, densities=[0.2])
    rows += run_qos(quick=True)
    return rows


# ---------------------------------------------------------------------------
# --density: compact vs dense delta layout across N:M densities
# ---------------------------------------------------------------------------

def run_density(quick: bool = True, densities=None):
    """Same workload in the compact ``[S, L, J, T, bk, bo]`` layout vs the
    dense ``[S, L, Kmax, N]`` baseline at each N:M density. Reports
    events/s for both (``rel`` >= 1.0 means compact does not regress) and
    the *measured* weight-state footprint from the ``serving_bytes_held``
    gauge — compact delta bytes must scale ~linearly with density while
    the dense baseline stays flat."""
    if densities is None:
        densities = [0.125, 0.25, 0.5] if quick else [0.125, 0.2, 0.25,
                                                      0.5, 0.75]
    n_streams, n_slots, n_windows = (8, 8, 2) if quick else (32, 32, 2)
    rows = []
    for density in densities:
        sparsity = 1.0 - density
        dense = _drive(n_streams, n_slots, n_windows, sparsity=sparsity,
                       compact=False)
        comp = _drive(n_streams, n_slots, n_windows, sparsity=sparsity,
                      compact=True)
        rd, rc = dense.telemetry.rollup(), comp.telemetry.rollup()
        bd, bc = dense.telemetry.bytes_held(), comp.telemetry.bytes_held()
        spec = comp.cfg.spec(N_IN)
        actual = spec.n / spec.m          # the realized N:M density
        rel = rc["events_per_s"] / rd["events_per_s"] \
            if rd["events_per_s"] else 0.0
        rows.append({
            "name": f"serving/density{actual:.3f}_streams{n_streams}",
            "us_per_call": rc["p50_ms"] * 1e3,
            "derived": (f"events/s={rc['events_per_s']:.0f}"
                        f" dense_events/s={rd['events_per_s']:.0f}"
                        f" rel={rel:.2f}"
                        f" delta_bytes={bc['deltas']:.0f}"
                        f" dense_delta_bytes={bd['deltas']:.0f}"
                        f" param_bytes={bc['params']:.0f}"
                        f" dense_param_bytes={bd['params']:.0f}"
                        f" compiles={comp.n_compiles}"),
            **_row_extras(comp),
        })
    return rows


# ---------------------------------------------------------------------------
# --pipeline / --factors: hot-path A/B vs the serial baseline
# ---------------------------------------------------------------------------

def run_ab(quick: bool = True, pipeline: bool = True, factors: bool = False):
    """Baseline (serial staging, DSST factors compiled in) vs the configured
    hot path on the same workload. ``rel`` >= 1.0 means the configured mode
    is at least as fast; the pipelined/factor-free path must not regress
    (per-stream trajectories are bit-identical either way — only *when*
    host work happens changes, never what the device computes)."""
    n_streams, n_slots, n_windows = (8, 8, 2) if quick else (32, 32, 4)
    base = _drive(n_streams, n_slots, n_windows, pipeline=0,
                  want_factors=True)
    # (pipeline=off, factors=on) IS the baseline — don't drive the same
    # config twice just to print a noise-around-1.0 ratio
    conf = base if (not pipeline and factors) else _drive(
        n_streams, n_slots, n_windows,
        pipeline=1 if pipeline else 0, want_factors=factors)
    rb = base.telemetry.rollup()
    rc = conf.telemetry.rollup()
    rel = rc["events_per_s"] / rb["events_per_s"] \
        if rb["events_per_s"] else 0.0
    tag = (f"pipe{'on' if pipeline else 'off'}_"
           f"fac{'on' if factors else 'off'}")
    return [{
        "name": f"serving/hotpath_{tag}_streams{n_streams}",
        "us_per_call": rc["p50_ms"] * 1e3,
        "derived": (f"events/s={rc['events_per_s']:.0f}"
                    f" baseline_events/s={rb['events_per_s']:.0f}"
                    f" rel={rel:.2f}"
                    f" overlap={rc['overlap_ratio']:.2f}"
                    f" p99_ms={rc['p99_ms']:.2f}"
                    f" baseline_p99_ms={rb['p99_ms']:.2f}"
                    f" {_phase_str(conf.telemetry)}"
                    f" compiles={conf.n_compiles}"),
        **_row_extras(conf),
    }]


# ---------------------------------------------------------------------------
# --tiers / --adaptive: QoS tiers + async ingestion + adaptive depth A/B
# ---------------------------------------------------------------------------

def run_qos(quick: bool = True, tiers: bool = True, adaptive: bool = True):
    """Three-way A/B on jittered AER traffic (decode cost at every poll):

    * ``qos_base`` — the single-grid serial reference: inline polling,
      pipeline depth 0;
    * ``qos_async`` — async ingest worker plus (``adaptive=on``) the
      depth autopilot, same single grid; ``rel`` >= 1.0 means moving
      decode off the critical path and deepening under a host-bound
      signal bought fleet throughput;
    * ``qos_tiers`` — the same fleet split over an ``interactive``
      (short-chunk) and a ``bulk`` (long-chunk) grid, ingest + autopilot
      on; reports per-tier p50/p99, the chosen-depth timeline, and
      interactive p99 against the single-grid baseline's p99.

    Trajectories are bit-identical across all three (pinned in
    tests/test_serving_qos.py) — this measures wall-clock shape only.
    """
    n_streams, n_slots, n_windows = (8, 8, 2) if quick else (32, 16, 4)
    kw = dict(aer=True, arrival=QOS_ARRIVAL)
    ap_cfg = AutopilotConfig(decide_every=2, hold_steps=4, warmup_obs=1) \
        if adaptive else None

    base = _drive(n_streams, n_slots, n_windows, **kw)
    rb = base.telemetry.rollup()
    rows = [{
        "name": f"serving/qos_base_streams{n_streams}",
        "us_per_call": rb["p50_ms"] * 1e3,
        "derived": (f"events/s={rb['events_per_s']:.0f}"
                    f" p99_ms={rb['p99_ms']:.2f}"
                    f" {_phase_str(base.telemetry)}"
                    f" compiles={base.n_compiles}"),
        **_row_extras(base),
    }]

    asyn = _drive(n_streams, n_slots, n_windows, ingest=IngestConfig(),
                  autopilot=ap_cfg, pipeline=0 if adaptive else 1, **kw)
    ra = asyn.telemetry.rollup()
    rel = ra["events_per_s"] / rb["events_per_s"] \
        if rb["events_per_s"] else 0.0
    timeline = (list(map(list, asyn.autopilot.timeline))
                if asyn.autopilot is not None else [])
    row = {
        "name": (f"serving/qos_async_"
                 f"{'adaptive' if adaptive else 'fixed'}"
                 f"_streams{n_streams}"),
        "us_per_call": ra["p50_ms"] * 1e3,
        "derived": (f"events/s={ra['events_per_s']:.0f}"
                    f" baseline_events/s={rb['events_per_s']:.0f}"
                    f" rel={rel:.2f}"
                    f" depth={ra['pipeline_depth']:.0f}"
                    f" depth_changes={ra['depth_changes']}"
                    f" ingest_chunks={ra['ingest_chunks']}"
                    f" overlap={ra['overlap_ratio']:.2f}"
                    f" compiles={asyn.n_compiles}"),
        **_row_extras(asyn),
    }
    row["metrics"].update(baseline_events_per_s=rb["events_per_s"],
                          baseline_p99_ms=rb["p99_ms"], rel=rel,
                          depth_timeline=timeline,
                          depth_changes=ra["depth_changes"],
                          ingest_chunks=ra["ingest_chunks"],
                          ingest_queue_peak=ra["ingest_queue_peak"])
    rows.append(row)

    if not tiers:
        return rows
    half = max(2, n_slots // 2)
    tier_cfgs = [TierConfig("interactive", chunk_len=4, n_slots=half),
                 TierConfig("bulk", chunk_len=CHUNK_LEN + 6, n_slots=half)]
    tiered = _drive(n_streams, n_slots, n_windows, tiers=tier_cfgs,
                    tier_of=lambda sid: "interactive" if sid % 2 else "bulk",
                    ingest=IngestConfig(), autopilot=ap_cfg,
                    pipeline=0 if adaptive else 1, **kw)
    rt = tiered.telemetry.rollup()
    lat = tiered.telemetry.tier_percentiles()
    rel_t = rt["events_per_s"] / rb["events_per_s"] \
        if rb["events_per_s"] else 0.0
    int_p99 = lat.get("interactive", {}).get("p99_ms", 0.0)
    bulk_p99 = lat.get("bulk", {}).get("p99_ms", 0.0)
    timeline = (list(map(list, tiered.autopilot.timeline))
                if tiered.autopilot is not None else [])
    row = {
        "name": f"serving/qos_tiers_streams{n_streams}",
        "us_per_call": rt["p50_ms"] * 1e3,
        "derived": (f"events/s={rt['events_per_s']:.0f}"
                    f" baseline_events/s={rb['events_per_s']:.0f}"
                    f" rel={rel_t:.2f}"
                    f" interactive_p99_ms={int_p99:.2f}"
                    f" bulk_p99_ms={bulk_p99:.2f}"
                    f" baseline_p99_ms={rb['p99_ms']:.2f}"
                    f" depth_changes={rt['depth_changes']}"
                    f" ingest_chunks={rt['ingest_chunks']}"
                    f" compiles={tiered.n_compiles}"),
        **_row_extras(tiered),
    }
    row["metrics"].update(baseline_events_per_s=rb["events_per_s"],
                          baseline_p99_ms=rb["p99_ms"], rel=rel_t,
                          tier_interactive_p50_ms=lat.get(
                              "interactive", {}).get("p50_ms", 0.0),
                          tier_interactive_p99_ms=int_p99,
                          tier_bulk_p50_ms=lat.get("bulk", {}).get(
                              "p50_ms", 0.0),
                          tier_bulk_p99_ms=bulk_p99,
                          depth_timeline=timeline,
                          depth_changes=rt["depth_changes"],
                          ingest_chunks=rt["ingest_chunks"],
                          ingest_queue_peak=rt["ingest_queue_peak"])
    rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# --evolve EVERY: live topology epochs vs a frozen-topology baseline
# ---------------------------------------------------------------------------

def _evolve_case(quick: bool):
    return (8, 8, 2) if quick else (32, 32, 4)


def run_evolve(quick: bool = True, every: int = 0, frozen=None):
    """Same workload, frozen topology vs live DSST epochs every ``every``
    grid steps; reports the throughput cost and the connectivity churn.
    ``every=0`` picks a cadence the workload actually reaches (the quick
    case drains in ~8 grid steps, the full case in ~13 — an ``every``
    beyond that measures a frozen fleet twice). ``frozen`` reuses an
    already-driven baseline scheduler for the same case instead of
    re-driving it."""
    if not every:
        every = 4 if quick else 6
    n_streams, n_slots, n_windows = _evolve_case(quick)
    frozen = frozen or _drive(n_streams, n_slots, n_windows)
    live = _drive(n_streams, n_slots, n_windows, evolve_every=every)
    rf = frozen.telemetry.rollup()
    rl = live.telemetry.rollup()
    svc = live.topology
    assert svc.epoch_idx > 0, \
        f"every={every} exceeds the workload's grid steps: zero epochs ran"
    mask_change = float(np.mean([e.mask_change for e in svc.events]))
    slowdown = rl["events_per_s"] / rf["events_per_s"] \
        if rf["events_per_s"] else 0.0
    return [{
        "name": f"serving/evolve{every}_streams{n_streams}",
        "us_per_call": rl["p50_ms"] * 1e3,
        "derived": (f"events/s={rl['events_per_s']:.0f}"
                    f" frozen_events/s={rf['events_per_s']:.0f}"
                    f" rel={slowdown:.2f}"
                    f" epochs={svc.epoch_idx}"
                    f" mask_change={mask_change:.4f}"
                    f" pruned={sum(e.pruned for e in svc.events)}"
                    f" merged={sum(len(e.merged_slots) for e in svc.events)}"
                    f" compiles={live.n_compiles}"),
        **_row_extras(live),
    }]


# ---------------------------------------------------------------------------
# --devices N: slot-throughput scaling of the sharded grid
# ---------------------------------------------------------------------------

SWEEP_STREAMS, SWEEP_SLOTS, SWEEP_WINDOWS = 64, 64, 2


def _child_one_device_count(n_devices: int) -> None:
    """Runs inside a subprocess whose XLA_FLAGS pinned ``n_devices``."""
    from repro.launch.mesh import make_serving_mesh
    mesh = make_serving_mesh(n_devices) if n_devices > 1 else None
    sched = _drive(SWEEP_STREAMS, SWEEP_SLOTS, SWEEP_WINDOWS, mesh=mesh)
    r = sched.telemetry.rollup()
    print(json.dumps({
        "devices": n_devices, "n_slots": sched.n_slots,
        "events_per_s": r["events_per_s"],
        "timesteps_per_s": r["timesteps_per_s"],
        "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
        "compiles": sched.n_compiles,
    }))


def run_devices_sweep(max_devices: int):
    """Spawn one subprocess per device count (1, 2, 4, ..., max_devices)
    and report events/s scaling of the sharded slot grid."""
    counts, d = [], 1
    while d < max_devices:
        counts.append(d)
        d *= 2
    counts.append(max_devices)
    rows, base = [], None
    for n in counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_serving_streams",
             "--_child", str(n)],
            capture_output=True, text=True, env=env, timeout=1200)
        if out.returncode != 0:
            raise RuntimeError(f"devices={n} child failed:\n{out.stderr}")
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        if base is None:
            base = rec["events_per_s"]
        rows.append({
            "name": f"serving/devices{n}_slots{rec['n_slots']}",
            "us_per_call": rec["p50_ms"] * 1e3,
            "derived": (f"events/s={rec['events_per_s']:.0f}"
                        f" scale_x={rec['events_per_s'] / base:.2f}"
                        f" ts/s={rec['timesteps_per_s']:.0f}"
                        f" p99_ms={rec['p99_ms']:.2f}"
                        f" compiles={rec['compiles']}"),
        })
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="sweep the sharded slot grid over 1..N host devices")
    ap.add_argument("--evolve", type=int, default=0, metavar="EVERY",
                    help="live topology epochs every EVERY grid steps, "
                         "vs a frozen-topology baseline")
    ap.add_argument("--pipeline", choices=["on", "off"], default=None,
                    help="A/B the double-buffered staging pipeline against "
                         "the serial baseline")
    ap.add_argument("--factors", choices=["on", "off"], default=None,
                    help="A/B compiling the DSST factor accumulators out of "
                         "the chunk scan (off) vs in (on)")
    ap.add_argument("--density", choices=["quick", "full"], default=None,
                    help="A/B the compact delta layout against the dense "
                         "baseline across N:M densities (events/s + "
                         "measured bytes held)")
    ap.add_argument("--tiers", choices=["on", "off"], default=None,
                    help="A/B QoS tiers (interactive + bulk chunk grids) "
                         "against the single-grid baseline on jittered "
                         "AER traffic")
    ap.add_argument("--adaptive", choices=["on", "off"], default=None,
                    help="enable the occupancy-driven pipeline-depth "
                         "autopilot in the QoS A/B (off: fixed depth 1)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write the rows as a repro-bench/1 artifact")
    ap.add_argument("--_child", type=int, default=0, help=argparse.SUPPRESS)
    args = ap.parse_args()
    rows = None
    if args._child:
        _child_one_device_count(args._child)
    elif args.tiers is not None or args.adaptive is not None:
        rows = run_qos(quick=True, tiers=(args.tiers != "off"),
                       adaptive=(args.adaptive != "off"))
    elif args.density:
        rows = run_density(quick=(args.density == "quick"))
    elif args.devices:
        rows = run_devices_sweep(args.devices)
    elif args.evolve:
        rows = run_evolve(quick=False, every=args.evolve)
    elif args.pipeline is not None or args.factors is not None:
        # unspecified halves stay at the baseline setting, so each flag can
        # be A/B'd in isolation or combined (--pipeline on --factors off)
        rows = run_ab(quick=False,
                      pipeline=(args.pipeline == "on"),
                      factors=(args.factors != "off"))
    else:
        for row in run(quick=True):
            print(row)
    if rows is not None:
        print("name,us_per_call,derived")
        for row in rows:
            print(f"{row['name']},{row['us_per_call']:.2f},{row['derived']}")
        if args.json:
            from benchmarks.run import write_artifact
            write_artifact(args.json, rows)
