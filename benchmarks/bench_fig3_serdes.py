"""Fig. 3 stand-in: the async SerDes *function* (not its circuits — DESIGN.md
§9): 30-bit event-packet framing throughput, and the 4-slot spatiotemporal
delay buffer. The paper's 54 % link-energy claim is circuit-level and is
reported as a constant, not re-measured."""
from __future__ import annotations

import time

import numpy as np

from repro.data.events import DelayBuffer, pack_events, unpack_events


def _timeit(fn, reps=50):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    spikes = (rng.random((512, 512)) < 0.1).astype(np.float32)
    t_pack = _timeit(lambda: pack_events(spikes))
    packets = pack_events(spikes)
    t_unpack = _timeit(lambda: unpack_events(packets, 512))
    events_per_s = spikes.size / (t_pack * 1e-6)

    buf = DelayBuffer(512)
    t_delay = _timeit(lambda: buf.push(spikes[0]))

    # densities matter: event-driven links only carry active words
    rows = [{"name": "fig3/pack_512ts", "us_per_call": t_pack,
             "derived": f"bits_per_s={events_per_s:.3e};payload_bits=30"},
            {"name": "fig3/unpack_512ts", "us_per_call": t_unpack,
             "derived": "lossless=True"},
            {"name": "fig3/delay_buffer_push", "us_per_call": t_delay,
             "derived": "slots=4"},
            {"name": "fig3/paper_link_energy", "us_per_call": 0.0,
             "derived": "paper_claim=54%_better_than_sota;not_reproducible_on_cpu"}]
    return rows
