"""Observability smoke: scrape a live pipelined serving run, twice.

Runs a short double-buffered serving loop with a span tracer attached,
takes a Prometheus text scrape mid-run and again after the fleet drains,
and asserts the contract the exporters promise operators:

* every required serving metric family is present in the scrape;
* counters are monotone — no sample of a ``*_total``/``*_count``/
  ``*_bucket`` series ever decreases between scrapes;
* the span trace carries exactly one stage/dispatch/retire span per
  grid step (per-phase attribution survives pipelining);
* the whole run compiled the chunk step exactly once.

This is the CI obs smoke (exit 0 + ``OK`` on success):

    PYTHONPATH=src python examples/obs_smoke.py
"""
import numpy as np
import jax

from repro.core.snn import SNNConfig, init_params
from repro.obs import Tracer, parse_prometheus_text, prometheus_text
from repro.serving import ReplaySource, StreamScheduler, StreamSession

REQUIRED_FAMILIES = (
    "serving_grid_steps_total",
    "serving_step_latency_seconds",
    "serving_phase_seconds",
    "serving_flush_seconds_total",
    "serving_overlap_ratio",
    "serving_overlap_hidden_seconds_total",
    "serving_device_wait_seconds_total",
    "serving_stream_timesteps_total",
    "serving_stream_events_in_total",
    "serving_stream_windows_total",
)

# sample-name suffixes that must never decrease between scrapes
_MONOTONE = ("_total", "_count", "_bucket")


def monotone_samples(parsed: dict) -> dict:
    return {k: v for k, v in parsed.items()
            if any(suffix in k for suffix in _MONOTONE)}


def main():
    cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tracer = Tracer(capacity=65536)
    sched = StreamScheduler(params, cfg, n_slots=3, chunk_len=6,
                            pipeline_depth=1, tracer=tracer)
    rng = np.random.default_rng(0)
    for sid in range(5):
        spikes = (rng.random(((3 + sid % 2) * cfg.t_steps, cfg.n_in))
                  < 0.3).astype(np.float32)
        sched.submit(StreamSession(sid=sid, source=ReplaySource(spikes),
                                   adapt=(sid % 2 == 0)))

    # scrape 1: mid-run, with steps in flight
    for _ in range(4):
        sched.step()
    first = parse_prometheus_text(prometheus_text(sched.telemetry.registry))

    missing = [f for f in REQUIRED_FAMILIES
               if not any(k.startswith(f) for k in first)]
    assert not missing, f"missing metric families mid-run: {missing}"

    # scrape 2: drained — every monotone series must be >= scrape 1
    sched.run_until_drained()
    second = parse_prometheus_text(prometheus_text(sched.telemetry.registry))
    regressed = [k for k, v in monotone_samples(first).items()
                 if second.get(k, float("-inf")) < v]
    assert not regressed, f"counters decreased between scrapes: {regressed}"

    steps = sched.grid.stats["steps"]
    assert second["serving_grid_steps_total"] == steps
    for name in ("sched.stage", "sched.dispatch", "sched.retire"):
        owned = sorted(s.attr("grid_step") for s in tracer.spans(name))
        assert owned == list(range(1, steps + 1)), (name, owned)
    assert sched.n_compiles == 1
    assert 0.0 < sched.telemetry.overlap_ratio() <= 1.0

    roll = sched.telemetry.rollup()
    print(f"grid steps {steps} | events/s {roll['events_per_s']:.0f} | "
          f"overlap {roll['overlap_ratio']:.2f} | "
          f"p50/p99 {roll['p50_ms']:.2f}/{roll['p99_ms']:.2f} ms | "
          f"monotone series checked {len(monotone_samples(first))}")
    print("OK")


if __name__ == "__main__":
    main()
