"""The paper, end-to-end: ElfCore's (512)-512-512-16 SNN learning a gesture
stream online — no labels for the hidden layers (OSSL), sparse-to-sparse
connectivity learning (DSST), activity-gated weight updates, and the modeled
power at the chip's 0.6 V / 20 MHz operating point.

    PYTHONPATH=src python examples/snn_ossl_demo.py [--full-size] [--samples 200]

Default runs the reduced (64-neuron) chip for CPU speed; --full-size runs
the real 512-512-512-16 network (slower).
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.elfcore_snn import CONFIG, reduced          # noqa: E402
from repro.core.energy import OperatingPoint, report           # noqa: E402
from repro.core.gating import skip_rate                        # noqa: E402
from repro.core.snn import (accuracy, init_params, init_state,  # noqa: E402
                            make_eval_fn, make_train_fn)
from repro.data.events import make_task                        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--samples", type=int, default=200)
    ap.add_argument("--task", default="gesture")
    args = ap.parse_args()

    cfg = CONFIG if args.full_size else reduced(t_steps=20)
    task = make_task(args.task, n_in=cfg.n_in, t_steps=cfg.t_steps)
    import dataclasses
    cfg = dataclasses.replace(cfg, n_out=max(task.n_classes, cfg.n_out))

    print(f"network ({cfg.n_in})-{cfg.n_hidden}-{cfg.n_hidden}-{cfg.n_out}, "
          f"{cfg.sparsity:.0%} sparse, {cfg.t_steps} TS/sample, task={args.task}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, batch=16)
    step = make_train_fn(cfg)
    eval_fn = make_eval_fn(cfg)
    rng = np.random.default_rng(1)

    sop_f = sop_w = sop_off = 0.0
    t0 = time.time()
    for i in range(args.samples):
        ev, lab = task.sample(rng, 16)
        params, state, m = step(params, state, jnp.asarray(ev), jnp.asarray(lab))
        sop_f += float(m.sop_forward); sop_w += float(m.sop_wu)
        sop_off += float(m.sop_wu_offered)
        if i % 50 == 0 or i == args.samples - 1:
            ev_e, lab_e = task.sample(np.random.default_rng(7), 64)
            _, me = eval_fn(params, init_state(cfg, batch=64), jnp.asarray(ev_e))
            acc = float(accuracy(me.logits, jnp.asarray(lab_e)))
            print(f"  sample {i:4d}: eval acc {acc:.3f}  "
                  f"gate open {float(m.gate_open_frac):.2f}  "
                  f"local loss {float(m.local_loss):+.3f}")
    wall = time.time() - t0

    per_sample = args.samples * 16
    rep = report(sop_f / per_sample, sop_w / per_sample, sop_off / per_sample,
                 cfg.t_steps, OperatingPoint.low_power())
    print(f"\nmodeled power @0.6V/20MHz: {rep.power_w*1e6:.1f} µW "
          f"(paper: <50 µW all tasks)")
    print(f"WU skip rate (gating): {rep.wu_skip_rate:.2f} "
          f"(gate-level: {float(skip_rate(state.gate)):.2f})")
    print(f"wall time: {wall:.1f}s for {args.samples} samples")


if __name__ == "__main__":
    main()
