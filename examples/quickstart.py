"""Quickstart: train a tiny LM dense vs block-N:M sparse (DSST) in ~2 min on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]
"""
import argparse
import sys

import jax

sys.path.insert(0, "src")

import repro.configs as C                                    # noqa: E402
from repro.configs.base import SparsityConfig                # noqa: E402
from repro.core.gating import GatingConfig                   # noqa: E402
from repro.data.pipeline import PipelineConfig, TokenPipeline  # noqa: E402
from repro.launch.train import TrainHParams, run_training    # noqa: E402
from repro.optim import AdamWConfig                          # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    base = C.get_reduced("stablelm_12b")
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps)

    runs = {
        "dense": (base, TrainHParams(opt=opt)),
        "nm_sparse+dsst+gating": (
            base.with_sparsity(SparsityConfig(n=1, m=2, block=8,
                                              targets=("mlp",), mode="masked")),
            TrainHParams(opt=opt, gating=GatingConfig(), dsst_every=10)),
    }
    for name, (cfg, hp) in runs.items():
        pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=64,
                                            global_batch=8))
        _, hist = run_training(cfg, hp, pipe, args.steps, log_every=10)
        print(f"[{name}] loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
              f"({sum(hist['step_time'])/len(hist['step_time'])*1e3:.0f} ms/step)")
    print("done — sparse run stores 50% of MLP weights and skips gated updates.")


if __name__ == "__main__":
    main()
