"""Event-stream serving demo: many live SNN sessions on one slot grid.

Eight gesture streams arrive asynchronously (Poisson chunk arrivals) and
are multiplexed onto a 4-slot grid: one jitted chunk step advances every
active stream, the activity-dependent gate decides per stream when its
OSSL delta absorbs an update, and telemetry prices each stream at the
chip's 0.6 V operating point.  A ``TopologyService`` keeps DSST alive
under this traffic: every 10 grid steps the hottest stream's adaptation is
folded into the shared base and a prune/regrow epoch evolves the N:M
topology — with zero recompilation of the chunk step.  The scheduler runs
with ``pipeline_depth=1``: host event staging for step t+1 overlaps the
device compute of step t (bit-identical results to the serial path).

    PYTHONPATH=src python examples/stream_serving_demo.py
"""
import jax

from repro.core.snn import SNNConfig, init_params
from repro.data.events import make_task
from repro.serving import (AdaptConfig, ArrivalConfig, StreamScheduler,
                           StreamSession, TaskStreamSource, TopologyService,
                           TopologyServiceConfig, delta_norms)


def main():
    cfg = SNNConfig(n_in=64, n_hidden=64, n_layers=2, n_out=10, t_steps=20)
    params = init_params(jax.random.PRNGKey(0), cfg)
    task = make_task("gesture", n_in=cfg.n_in, t_steps=cfg.t_steps)

    topo = TopologyService(cfg, TopologyServiceConfig(epoch_every=10,
                                                      merge_top=1))
    sched = StreamScheduler(params, cfg, n_slots=4, chunk_len=8,
                            adapt=AdaptConfig(delta_clip=0.5),
                            topology=topo, pipeline_depth=1)
    arrival = ArrivalConfig(min_chunk=4, max_chunk=10, mean_gap_s=0.003)
    for sid in range(8):
        sched.submit(StreamSession(
            sid=sid,
            source=TaskStreamSource(task, n_windows=3, seed=sid,
                                    arrival=arrival),
            adapt=(sid % 2 == 0)))   # every other stream serves frozen

    done = sched.run_until_drained()

    print(f"retired {len(done)} streams | grid steps "
          f"{sched.grid.stats['steps']} | utilization "
          f"{sched.utilization:.2f} | compiled variants {sched.n_compiles}")
    print(f"{'sid':>3} {'adapt':>5} {'windows':>7} {'pred labels':>12} "
          f"{'skip':>6} {'uW':>7} {'|delta|':>8}")
    for sess in sorted(done, key=lambda s: s.sid):
        c = sched.telemetry.stream(sess.sid)
        e = c.energy()
        dn = sum(float((d ** 2).sum()) for d in sess.final_deltas) ** 0.5
        labels = ",".join(str(p.label) for p in sess.predictions)
        print(f"{sess.sid:>3} {str(sess.adapt):>5} {c.windows:>7} "
              f"{labels:>12} {c.wu_skip_rate:>6.2f} {e['power_uW']:>7.1f} "
              f"{dn:>8.4f}")

    r = sched.telemetry.rollup()
    print(f"\nfleet: {r['events_per_s']:.0f} events/s | "
          f"p50 {r['p50_ms']:.1f} ms / p99 {r['p99_ms']:.1f} ms per grid "
          f"step | WU skip {r['wu_skip_rate']:.2f} | modeled "
          f"{r['fleet_energy']['power_uW']:.1f} uW")
    print(f"topology: {r['topology_epochs']} live epochs | "
          f"{r['topology_pruned']} pruned / {r['topology_regrown']} regrown "
          f"| mask change {r['topology_mask_change_mean']:.4f} | "
          f"{r['streams_merged']} hot streams folded into the base")


if __name__ == "__main__":
    main()
