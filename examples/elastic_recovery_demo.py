"""Fault-tolerance drill: kill the training 'fleet' twice, watch it resume
bitwise-identically from checkpoints; flag a straggling replica.

    PYTHONPATH=src python examples/elastic_recovery_demo.py
"""
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

import repro.configs as C                                       # noqa: E402
from repro.data.pipeline import PipelineConfig, synthetic_lm_batch  # noqa: E402
from repro.launch.train import TrainHParams, init_train_state, make_train_step  # noqa: E402
from repro.optim import AdamWConfig                             # noqa: E402
from repro.runtime.fault_tolerance import (HeartbeatMonitor,    # noqa: E402
                                           run_with_recovery)


def main():
    cfg = C.get_reduced("phi3_medium_14b")
    hp = TrainHParams(opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100))
    pcfg = PipelineConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    step_jit = jax.jit(make_train_step(cfg, hp))

    def step_fn(state, step):
        params, opt, ss = state
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_lm_batch(pcfg, step).items()}
        params, opt, ss, m = step_jit(params, opt, ss, batch)
        return (params, opt, ss), {"loss": float(m["loss"])}

    init = init_train_state(jax.random.PRNGKey(0), cfg, hp)

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        print("reference run (no failures)...")
        ref, _ = run_with_recovery(step_fn, init, 30, d1, ckpt_every=10)
        print("faulty run: nodes lost at steps 12 and 23...")
        out, log = run_with_recovery(step_fn, init, 30, d2, ckpt_every=10,
                                     fail_at={12: 1, 23: 1})
        print(f"  restarts: {log['restarts']}, restored from {log['restored_from']}")
        same = all(bool(jnp.array_equal(a, b)) for a, b in
                   zip(jax.tree.leaves(ref), jax.tree.leaves(out)))
        print(f"  final states bitwise identical: {same}")
        assert same

    mon = HeartbeatMonitor(8)
    rng = np.random.default_rng(0)
    for _ in range(10):
        for r in range(8):
            mon.record(r, (2.4 if r == 3 else 1.0) + rng.normal() * 0.02)
    print(f"straggler policy flags replicas: {mon.stragglers()} (injected: [3])")


if __name__ == "__main__":
    main()
