"""End-to-end training driver (deliverable b): any pool arch, any size.

    # ~100M-param model, a few hundred steps (the deliverable spec);
    # heavy on CPU — this is the config a TPU host would run:
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # CPU-friendly smoke of the same driver:
    PYTHONPATH=src python examples/train_lm.py --preset cpu-small --steps 60

    # any assigned arch at reduced size, with the paper's add-ons:
    PYTHONPATH=src python examples/train_lm.py --arch mixtral_8x7b --reduced \
        --sparse --gating --mode local --steps 40

Checkpoints + auto-resume: pass --ckpt-dir and re-run the same command after
killing it mid-run; training continues from the last step (bitwise).
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import repro.configs as C                                      # noqa: E402
from repro.configs.base import ModelConfig, SparsityConfig     # noqa: E402
from repro.core.gating import GatingConfig                     # noqa: E402
from repro.data.pipeline import PipelineConfig, TokenPipeline  # noqa: E402
from repro.launch.train import TrainHParams, run_training      # noqa: E402
from repro.optim import AdamWConfig                            # noqa: E402

PRESETS = {
    # ~104M params: 12L d=768 llama-style
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab=32000, dtype="float32", remat=False),
    # ~8M params: CPU smoke of the same driver
    "cpu-small": ModelConfig(name="lm-8m", family="dense", n_layers=4,
                             d_model=256, n_heads=4, n_kv_heads=2, d_ff=688,
                             vocab=4096, dtype="float32", remat=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default=None)
    ap.add_argument("--arch", default=None, help="assigned arch id")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mode", choices=["backprop", "local"], default="backprop")
    ap.add_argument("--sparse", action="store_true",
                    help="block-N:M (2:8) on MLPs with DSST")
    ap.add_argument("--gating", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.arch:
        cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
        cfg = dataclasses.replace(cfg, dtype="float32") if args.reduced else cfg
    else:
        cfg = PRESETS[args.preset or "cpu-small"]
    if args.sparse:
        block = 8 if cfg.d_ff <= 1024 else 128
        cfg = cfg.with_sparsity(SparsityConfig(n=2, m=8, block=block,
                                               targets=("mlp",), mode="masked"))
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mode={args.mode} sparse={bool(cfg.sparsity)} gating={args.gating}")

    hp = TrainHParams(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                        total_steps=args.steps),
        mode=args.mode,
        gating=GatingConfig() if args.gating else None,
        dsst_every=25 if args.sparse else 0)
    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=args.seq,
                                        global_batch=args.batch))

    def cb(step, m):
        if step % 10 == 0:
            print(f"  step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gate {float(m['gate_frac']):.2f}")

    _, hist = run_training(cfg, hp, pipe, args.steps, ckpt_dir=args.ckpt_dir,
                           log_every=max(1, args.steps // 20), callback=cb)
    print(f"final: loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}, "
          f"{sum(hist['step_time'])/len(hist['step_time'])*1e3:.0f} ms/step")


if __name__ == "__main__":
    main()
