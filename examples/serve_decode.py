"""Batched serving: prefill a prompt batch, decode with KV/SSM caches.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral_8x7b --new 24
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

import repro.configs as C                       # noqa: E402
from repro.launch.serve import generate         # noqa: E402
from repro.models import transformer as T       # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(params, cfg, prompt, args.new,
                   temperature=args.temperature)
    dt = time.time() - t0
    toks = args.batch * args.new
    print(f"arch={cfg.name} family={cfg.family} "
          f"cache_len={T.cache_len(cfg, args.prompt_len + args.new)}")
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
