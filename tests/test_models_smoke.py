"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.launch.train import TrainHParams, make_train_step, init_train_state
from repro.models import transformer as T


def _batch(cfg, b=2, s=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    out = {"labels": jax.random.randint(ks[0], (b, s), 0, cfg.vocab)}
    if cfg.frontend:
        out["embeds"] = jax.random.normal(ks[1], (b, s, cfg.frontend_dim),
                                          jnp.float32)
    else:
        out["tokens"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab)
    return out


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = C.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    logits, aux = T.forward(params, cfg, tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"))
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert aux["ia"].shape == (cfg.n_layers,)
    assert aux["pooled"].shape == (cfg.n_layers, cfg.d_model)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = C.get_reduced(arch)
    hp = TrainHParams()
    params, opt, ss = init_train_state(jax.random.PRNGKey(0), cfg, hp)
    step = jax.jit(make_train_step(cfg, hp))
    batch = _batch(cfg)
    l0 = None
    for i in range(3):
        params, opt, ss, m = step(params, opt, ss, batch)
        assert not bool(jnp.isnan(m["loss"])), arch
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0 + 1.0   # sane trajectory on repeated batch


@pytest.mark.parametrize("arch", ["deepseek_67b", "qwen2_vl_2b", "moonshot_v1_16b_a3b",
                                  "musicgen_large", "zamba2_1p2b"])
def test_probe_mode_matches_scan(arch):
    """Cost-probe (unrolled) forward must be numerically identical to scan."""
    cfg = C.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    a, _ = T.forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"), probe=False)
    b, _ = T.forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"), probe=True)
    assert float(jnp.abs(a - b).max()) < 1e-5


@pytest.mark.parametrize("arch", ["phi3_medium_14b", "mixtral_8x7b",
                                  "mamba2_2p7b", "zamba2_1p2b"])
def test_decode_matches_forward(arch):
    cfg = C.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits, _ = T.forward(params, cfg, tokens=toks)
    cache = T.init_cache(cfg, b, s)
    for t in range(s):
        lg, cache = T.decode_step(params, cache, toks[:, t], cfg)
        err = float(jnp.abs(lg - logits[:, t]).max())
        assert err < 1e-4, (arch, t, err)


def test_swa_masks_long_range():
    """Mixtral's sliding window: tokens beyond the window are invisible.
    (capacity_factor raised so MoE never drops — a dropped-token shift is
    the one legitimate long-range interaction in a capacity MoE)."""
    import dataclasses
    cfg = dataclasses.replace(C.get_reduced("mixtral_8x7b"),
                              moe_capacity_factor=16.0)  # swa_window=8
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    s = 24
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab)   # differ outside window
    l1, _ = T.forward(params, cfg, tokens=t1)
    l2, _ = T.forward(params, cfg, tokens=t2)
    assert float(jnp.abs(l1[:, -1] - l2[:, -1]).max()) < 1e-5


def test_mamba2_ssd_duality_long():
    """Chunked-parallel SSD == token-by-token recurrence over 4 chunks."""
    cfg = C.get_reduced("mamba2_2p7b")   # ssm_chunk=8
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    logits, _ = T.forward(params, cfg, tokens=toks)
    cache = T.init_cache(cfg, b, s)
    for t in range(s):
        lg, cache = T.decode_step(params, cache, toks[:, t], cfg)
    assert float(jnp.abs(lg - logits[:, -1]).max()) < 1e-4


def test_local_mode_no_cross_block_grads():
    """OSSL local mode: block-0 params receive no gradient from the final CE
    (only from their own local loss) — the WU-locking removal, verified."""
    cfg = C.get_reduced("stablelm_12b")
    params = T.init_params(jax.random.PRNGKey(0), cfg, local_heads=True)
    batch = _batch(cfg)

    def ce_only(p):
        logits, _ = T.forward(p, cfg, tokens=batch["tokens"], local_mode=True)
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   batch["labels"][..., None], -1)[..., 0]
        return (logz - gold).mean()

    g = jax.grad(ce_only, allow_int=True)(params)
    blk = g["layers"]["attn"]["wq"]["w"]
    assert float(jnp.abs(blk).max()) == 0.0      # CE never reaches blocks
    assert float(jnp.abs(g["lm_head"]).max()) > 0  # readout does learn
