"""Fault tolerance: bitwise-deterministic recovery, straggler policy."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault_tolerance import (HeartbeatMonitor, StragglerPolicy,
                                           run_with_recovery)


def _step_fn():
    """A state-dependent, data-indexed step (mimics train: state + step)."""
    @jax.jit
    def f(state, step):
        data = jax.random.normal(jax.random.PRNGKey(step), (4,))
        return state * 0.99 + data.sum()
    def step_fn(state, step):
        return f(state, jnp.asarray(step)), {}
    return step_fn


def test_recovery_bitwise_identical(tmp_path):
    fn = _step_fn()
    ref, _ = run_with_recovery(fn, jnp.float32(1.0), 25, str(tmp_path / "a"),
                               ckpt_every=5)
    out, log = run_with_recovery(fn, jnp.float32(1.0), 25, str(tmp_path / "b"),
                                 ckpt_every=5, fail_at={7: 1, 18: 2})
    assert log["restarts"] == 3
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_recovery_resumes_from_latest(tmp_path):
    fn = _step_fn()
    _, log = run_with_recovery(fn, jnp.float32(0.0), 22, str(tmp_path),
                               ckpt_every=10, fail_at={15: 1})
    assert log["restored_from"] == [9]


def test_straggler_detection():
    mon = HeartbeatMonitor(8, StragglerPolicy(threshold=1.5, min_steps=3))
    rng = np.random.default_rng(0)
    for _ in range(10):
        for r in range(8):
            base = 1.0 if r != 5 else 2.5     # replica 5 is slow
            mon.record(r, base + rng.normal() * 0.02)
    assert mon.stragglers() == [5]
    assert 5 not in mon.healthy_replicas()


def test_no_false_positives_uniform():
    mon = HeartbeatMonitor(4)
    for _ in range(10):
        for r in range(4):
            mon.record(r, 1.0)
    assert mon.stragglers() == []


def test_elastic_remesh_changes_sharding():
    from repro.runtime.fault_tolerance import elastic_remesh
    from jax.sharding import Mesh, PartitionSpec as P
    devs = np.asarray(jax.devices())
    mesh1 = Mesh(devs.reshape(1, -1)[:, :1], ("data", "model"))
    tree = {"w": jnp.ones((8, 8))}
    out = elastic_remesh(tree, mesh1, lambda path: P())
    assert out["w"].sharding.mesh.shape["data"] == 1
    np.testing.assert_array_equal(out["w"], tree["w"])
