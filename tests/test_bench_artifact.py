"""The ``benchmarks/run.py --json`` machine-readable results artifact.

Two layers: :func:`benchmarks.run.write_artifact` as a unit (schema,
row passthrough, optional structured extras, partial-failure recording),
and the real CLI end-to-end — run one quick module with ``--json`` in a
subprocess and consume the artifact the way a trajectory-tracking script
would.
"""
import json
import os
import subprocess
import sys

from benchmarks.run import ARTIFACT_SCHEMA, write_artifact

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_write_artifact_schema_and_extras(tmp_path):
    path = str(tmp_path / "out.json")
    rows = [
        {"name": "a/b", "us_per_call": 12.5, "derived": "x=1"},
        {"name": "serving/hotpath", "us_per_call": 3.0, "derived": "y=2",
         "metrics": {"events_per_s": 100.0, "overlap_ratio": 0.4,
                     "phase_stage_p50_ms": 0.1},
         "obs": {"serving_grid_steps_total": {"type": "counter",
                                              "samples": []}}},
    ]
    doc = write_artifact(path, rows, failed=1, argv=["bench", "--json", path],
                         contracts_checked={"entrypoints": ["e"],
                                            "contracts": 3, "violations": 0,
                                            "ok": True})
    on_disk = json.load(open(path))
    assert on_disk == json.loads(json.dumps(doc))   # what's returned is written
    assert on_disk["schema"] == ARTIFACT_SCHEMA == "repro-bench/1"
    assert on_disk["failed"] == 1
    assert on_disk["argv"] == ["bench", "--json", path]
    assert on_disk["contracts_checked"]["ok"] is True
    assert on_disk["created_unix_s"] > 0
    r0, r1 = on_disk["rows"]
    assert r0 == {"name": "a/b", "us_per_call": 12.5, "derived": "x=1"}
    assert r1["metrics"]["overlap_ratio"] == 0.4
    assert "serving_grid_steps_total" in r1["obs"]
    # extra row keys beyond the contract never leak into the artifact
    doc2 = write_artifact(path, [{"name": "n", "us_per_call": 1,
                                  "derived": "", "junk": object()}])
    assert set(doc2["rows"][0]) == {"name", "us_per_call", "derived"}


def test_cli_json_artifact_end_to_end(tmp_path):
    """``python -m benchmarks.run --only table1 --json out.json`` produces
    an artifact that agrees with the CSV on stdout."""
    path = str(tmp_path / "bench.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "table1",
         "--json", path],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    doc = json.load(open(path))
    assert doc["schema"] == ARTIFACT_SCHEMA
    assert doc["failed"] == 0
    assert doc["rows"], "table1 produced no rows"
    # the contract-registry stamp: every real entrypoint's contract set
    # held when these numbers were taken
    cc = doc["contracts_checked"]
    assert cc["ok"] is True and cc["violations"] == 0
    assert cc["contracts"] > 0 and cc["entrypoints"]
    csv_lines = [l for l in out.stdout.strip().splitlines()
                 if l and not l.startswith("name,")]
    assert len(doc["rows"]) == len(csv_lines)
    for row, line in zip(doc["rows"], csv_lines):
        assert line.startswith(f"{row['name']},")
        assert {"name", "us_per_call", "derived"} <= set(row)
        assert isinstance(row["us_per_call"], float)
