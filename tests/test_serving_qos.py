"""Tiered QoS serving: async ingestion, per-tier grids, adaptive depth.

The load-bearing property for every feature here is *bit-identity*:
async ingestion, QoS tiers and the depth autopilot change when host work
happens and how the fleet is laid out — never what the device computes
for any stream. Each section pins one leg:

* ingest on == ingest off, chunk for chunk, at every tick (the worker
  replays the virtual clock exactly);
* an exhausted source with a queued tail chunk still retires exactly
  once, with the tail fed (the EOS-exactly-once regression);
* queue depth never exceeds the configured capacity (backpressure parks
  the producer instead of growing memory);
* the autopilot never oscillates on a noisy signal, stays in bounds, and
  an adaptive run is bit-identical to every fixed depth it visited;
* a tiered fleet matches per-tier single-grid references, and 8-device
  sharded tiered/adaptive runs match 1-device serial references.
"""
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.core.snn import SNNConfig, init_params
from repro.data.events import make_task
from repro.serving import (AERStreamSource, ArrivalConfig, AutopilotConfig,
                           DepthAutopilot, IngestConfig, IngestWorker,
                           ReplaySource, SessionStatus, StreamScheduler,
                           StreamSession, TaskStreamSource, TierConfig)
from repro.serving.staging import InFlight, StagedChunk, StagingPipeline

CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# jittered arrivals: ragged chunks, bursty gaps — the traffic shape the
# async-ingestion A/B is about
_JITTER = ArrivalConfig(min_chunk=3, max_chunk=13, mean_gap_s=0.004,
                        start_jitter_s=0.02)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _events(seed, t, rate=0.25):
    rng = np.random.default_rng(seed)
    return (rng.random((t, CFG.n_in)) < rate).astype(np.float32)


def _mixed_sessions(n=4):
    """A fleet mixing replay, jittered-task and AER-packed sources."""
    task = make_task("gesture", n_in=CFG.n_in, t_steps=CFG.t_steps)
    out = []
    for sid in range(n):
        if sid % 3 == 0:
            src = ReplaySource(_events(sid, (2 + sid % 2) * CFG.t_steps,
                                       rate=0.25 + 0.03 * sid), chunk_len=7)
        elif sid % 3 == 1:
            src = TaskStreamSource(task, n_windows=2, seed=sid,
                                   arrival=_JITTER)
        else:
            src = AERStreamSource(task, n_windows=2, seed=sid,
                                  arrival=_JITTER)
        out.append(StreamSession(sid=sid, source=src, adapt=(sid % 2 == 0)))
    return out


def _run_fleet(params, sessions, **kw):
    sched = StreamScheduler(params, CFG, **kw)
    for s in sessions:
        sched.submit(s)
    done = {s.sid: s for s in sched.run_until_drained()}
    sched.close()
    return done, sched


def _assert_fleet_identical(a, b):
    """Bit-for-bit per-stream identity: predictions, final deltas, fed
    timesteps. (Exact equality, not allclose — these paths must not
    change device arithmetic at all.)"""
    assert set(a) == set(b)
    for sid in a:
        sa, sb = a[sid], b[sid]
        assert sa.timesteps_fed == sb.timesteps_fed, sid
        assert len(sa.predictions) == len(sb.predictions), sid
        for pa, pb in zip(sa.predictions, sb.predictions):
            np.testing.assert_array_equal(pa.logits, pb.logits)
        np.testing.assert_array_equal(sa.final_deltas, sb.final_deltas)


# ------------------------------------------------- async ingestion parity

def test_ingest_bit_identical_to_serial(params):
    """The whole point of the determinism contract: moving source polling
    to the worker thread changes nothing a stream observes."""
    ref, _ = _run_fleet(params, _mixed_sessions(), n_slots=2, chunk_len=6)
    got, sched = _run_fleet(params, _mixed_sessions(), n_slots=2,
                            chunk_len=6, ingest=True)
    _assert_fleet_identical(ref, got)
    st = sched.ingest.stats()
    assert st["chunks_queued"] > 0          # the worker actually worked
    assert st["attached"] == 0              # every stream detached at retire
    assert sched.telemetry.tier_rollup()["ingest_chunks"] > 0


def test_ingest_with_pipelining_bit_identical(params):
    ref, _ = _run_fleet(params, _mixed_sessions(), n_slots=2, chunk_len=6)
    got, _ = _run_fleet(params, _mixed_sessions(), n_slots=2, chunk_len=6,
                        ingest=True, pipeline_depth=2)
    _assert_fleet_identical(ref, got)


def test_aer_source_poll_identical_to_dense():
    """AER pack/densify round trip is exact: an AERStreamSource releases
    the same chunks at the same virtual times as its dense twin."""
    task = make_task("nav_cue", n_in=CFG.n_in, t_steps=CFG.t_steps)
    dense = TaskStreamSource(task, n_windows=3, seed=5, arrival=_JITTER)
    aer = AERStreamSource(task, n_windows=3, seed=5, arrival=_JITTER)
    assert aer.n_timesteps == dense.n_timesteps
    np.testing.assert_array_equal(aer.labels, dense.labels)
    now = 0.0
    while not dense.exhausted:
        now += 0.002
        a, d = aer.poll(now), dense.poll(now)
        assert len(a) == len(d)
        for ca, cd in zip(a, d):
            np.testing.assert_array_equal(ca, cd)
    assert aer.exhausted


# ------------------------------------------------------- EOS exactly once

def test_eos_exactly_once_with_lookahead(params):
    """Lookahead polling flips ``source.exhausted`` while the tail chunk
    still sits in the worker queue. The session must NOT retire until the
    tail is fed — and must retire exactly once when it is (the lost-tail
    / double-retire regression)."""
    cfg = IngestConfig(capacity_chunks=256, lookahead_ticks=128)
    ref, _ = _run_fleet(params, _mixed_sessions(6), n_slots=2, chunk_len=6)
    got, sched = _run_fleet(params, _mixed_sessions(6), n_slots=2,
                            chunk_len=6, ingest=cfg)
    _assert_fleet_identical(ref, got)
    # exactly-once: every session retired once, with every source timestep
    sids = [s.sid for s in sched.retired]
    assert sorted(sids) == sorted(set(sids)) == sorted(got)
    for s in sched.retired:
        assert s.status is SessionStatus.RETIRED
        assert s.timesteps_fed == s.source.n_timesteps, (
            f"stream {s.sid} lost its queued tail")
        assert s._pending == [] and s._ingest is None


def test_session_exhausted_consults_ingest_queue(params):
    """Unit view of the same hole: a session whose source is done but
    whose tail chunk is still queued in the worker reports exhausted
    only after the drain releases it."""
    w = IngestWorker(0.002, IngestConfig(capacity_chunks=8,
                                         lookahead_ticks=64))
    sess = StreamSession(sid=0, source=ReplaySource(_events(0, 24),
                                                    chunk_len=8))
    w.attach(sess)
    # steal-poll far ahead without releasing: drain(0) publishes tick 0,
    # then the worker (or a big drain) races ahead of the grid
    deadline = time.monotonic() + 5.0
    while not sess.source.exhausted and time.monotonic() < deadline:
        time.sleep(0.001)
    assert sess.source.exhausted          # lookahead outran the grid
    assert w.has_pending(0)
    assert not sess.exhausted             # the EOS fix: queued tail counts
    w.drain(64)                           # release everything
    assert not w.has_pending(0)
    assert sess.pending_timesteps() == 24
    sess.pop_chunk(24)
    assert sess.exhausted
    w.detach(sess)
    w.stop()


def test_detach_with_undrained_chunks_raises():
    w = IngestWorker(0.002, IngestConfig(lookahead_ticks=64))
    sess = StreamSession(sid=0, source=ReplaySource(_events(1, 24),
                                                    chunk_len=8))
    w.attach(sess)
    deadline = time.monotonic() + 5.0
    while not w.has_pending(0) and time.monotonic() < deadline:
        time.sleep(0.001)
    with pytest.raises(RuntimeError, match="undrained"):
        w.detach(sess)
    w.stop()


# ------------------------------------------------------------ backpressure

def test_bounded_queue_backpressure():
    """With no drain ever published, the worker polls each stream at most
    ``capacity_chunks`` deep and parks — the queue high-water mark is the
    obs bounded-container invariant."""
    cap = 3
    # lookahead >> capacity so capacity, not lookahead, is the binding cap
    w = IngestWorker(0.002, IngestConfig(capacity_chunks=cap,
                                         lookahead_ticks=100))
    sess = StreamSession(sid=0, source=ReplaySource(_events(2, 400),
                                                    chunk_len=8))
    w.attach(sess)
    deadline = time.monotonic() + 5.0
    while w.stats()["chunks_queued"] < cap and time.monotonic() < deadline:
        time.sleep(0.001)
    time.sleep(0.02)                      # give it rope to overshoot
    st = w.stats()
    assert st["queue_peak"] == cap, st
    assert st["chunks_queued"] == cap, "parked stream kept being polled"
    # a drain frees capacity and un-parks the producer
    pushed, peak = w.drain(1)
    assert pushed == 1 and peak == cap
    deadline = time.monotonic() + 5.0
    while w.stats()["chunks_queued"] < cap + 1 and time.monotonic() < deadline:
        time.sleep(0.001)
    assert w.stats()["queue_peak"] == cap
    w.stop()


def test_backpressure_invariant_via_telemetry(params):
    """Fleet-level: the exported high-water gauge respects the cap."""
    cap = 2
    _, sched = _run_fleet(params, _mixed_sessions(), n_slots=2, chunk_len=6,
                          ingest=IngestConfig(capacity_chunks=cap,
                                              lookahead_ticks=16))
    roll = sched.telemetry.tier_rollup()
    assert 0 < roll["ingest_queue_peak"] <= cap
    fam = sched.telemetry.registry.get("serving_ingest_queue_peak_chunks")
    assert fam is not None and fam.value <= cap


def test_ingest_config_validation():
    with pytest.raises(ValueError):
        IngestConfig(capacity_chunks=0)
    with pytest.raises(ValueError):
        IngestConfig(lookahead_ticks=0)
    w = IngestWorker(0.002)
    s = StreamSession(sid=7, source=ReplaySource(_events(3, 8)))
    w.attach(s)
    with pytest.raises(ValueError, match="already attached"):
        w.attach(s)
    w.drain(4)
    w.detach(s)
    w.stop()


# ------------------------------------------------------------- autopilot

def test_autopilot_hysteresis_no_oscillation():
    """A noisy overlap signal alternating far above/below the deadband
    must not flap the depth: changes are spaced >= hold_steps apart, and
    the deadband absorbs the EMA's excursions."""
    ap = DepthAutopilot(AutopilotConfig(max_depth=3, decide_every=1,
                                        hold_steps=10, warmup_obs=1,
                                        deepen_above=0.6, relax_below=0.05))
    depth, changes = 1, []
    ap.note_depth(0, depth)
    for step in range(1, 200):
        ap.observe(0.9 if step % 2 else 0.1)   # violently noisy signal
        new = ap.decide(step, depth)
        if new != depth:
            changes.append(step)
            ap.note_depth(step, new)
            depth = new
    for a, b in zip(changes, changes[1:]):
        assert b - a >= 10, f"changes {a}->{b} inside the hold window"
    # EMA of a 0.9/0.1 alternation sits mid-deadband -> nearly no changes
    assert len(changes) <= 2, changes


def test_autopilot_bounds_and_probe():
    cfg = AutopilotConfig(max_depth=2, decide_every=1, hold_steps=1,
                          warmup_obs=1, deepen_above=0.5, relax_below=0.2)
    ap = DepthAutopilot(cfg)
    ap.note_depth(0, 0)
    assert ap.decide(1, 0) == 0            # warming up: no observations yet
    ap.observe(0.0)
    depth = ap.decide(2, 0)
    assert depth == 1                      # serial probes regardless of EMA
    ap.note_depth(2, depth)
    for step in range(3, 40):              # saturating high signal
        ap.observe(1.0)
        depth = ap.decide(step, depth)
        ap.note_depth(step, depth)
    assert depth == cfg.max_depth          # bounded above
    for step in range(40, 120):            # saturating low signal
        ap.observe(0.0)
        depth = ap.decide(step, depth)
        ap.note_depth(step, depth)
    assert depth == cfg.min_pipelined_depth  # floored, never back to 0
    assert ap.depths_visited() == (0, 1, 2)


def test_autopilot_config_validation():
    with pytest.raises(ValueError):
        AutopilotConfig(min_pipelined_depth=3, max_depth=2)
    with pytest.raises(ValueError):
        AutopilotConfig(deepen_above=0.2, relax_below=0.5)
    with pytest.raises(ValueError):
        AutopilotConfig(ema_alpha=0.0)


def test_set_depth_only_at_drain_safe_boundary():
    p = StagingPipeline(depth=1)
    staged = StagedChunk(events=None, valid=None, adapt_mask=None, lanes=[],
                         retiring=[], merge_slots=(), fed={})
    p.push(InFlight(staged=staged, deltas=None, metrics=None, grid_step=1))
    with pytest.raises(RuntimeError, match="flush"):
        p.set_depth(2)
    p.pop()
    p.set_depth(2)                         # empty pipeline: fine
    assert p.depth == 2
    with pytest.raises(ValueError):
        p.set_depth(-1)


def test_adaptive_bit_identical_to_every_fixed_depth(params):
    """The acceptance property: an adaptive run that visited depths
    {0, 1, 2} is per-stream bit-identical to fixed-depth references at
    every one of those depths."""
    ap_cfg = AutopilotConfig(max_depth=2, decide_every=1, hold_steps=2,
                             warmup_obs=1, deepen_above=0.0,
                             relax_below=0.0)   # deepen on any overlap > 0
    sessions = lambda: _mixed_sessions(6)
    got, sched = _run_fleet(params, sessions(), n_slots=2, chunk_len=6,
                            ingest=True, autopilot=ap_cfg)
    visited = sched.autopilot.depths_visited()
    assert len(visited) > 1, "autopilot never moved — test proves nothing"
    assert sched.telemetry.tier_rollup()["depth_changes"] >= 1
    assert list(sched.autopilot.timeline)[0] == (0, 0)
    for depth in visited:
        ref, _ = _run_fleet(params, sessions(), n_slots=2, chunk_len=6,
                            pipeline_depth=depth)
        _assert_fleet_identical(ref, got)


def test_autopilot_clamped_by_topology_service(params):
    """A live topology service caps drain-safe depth at 1; the autopilot
    must inherit that clamp, not fight it."""
    from repro.core.dsst import DSSTConfig
    from repro.serving import TopologyService, TopologyServiceConfig

    tcfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=12,
                     dsst=DSSTConfig(period=4, prune_frac=0.5))
    tparams = init_params(jax.random.PRNGKey(0), tcfg)
    svc = TopologyService(tcfg, TopologyServiceConfig(epoch_every=50))
    sched = StreamScheduler(tparams, tcfg, n_slots=2, chunk_len=6,
                            topology=svc,
                            autopilot=AutopilotConfig(max_depth=3))
    assert sched.autopilot.cfg.max_depth == 1
    sched.close()


# ------------------------------------------------------------------ tiers

def test_tiered_fleet_matches_single_grid_references(params):
    """Streams on a two-tier fleet see bit-identically what they'd see on
    a dedicated single-grid scheduler with their tier's geometry."""
    tiers = [TierConfig("interactive", chunk_len=4, n_slots=2),
             TierConfig("bulk", chunk_len=12, n_slots=2)]

    def submit_split(sched, multi):
        for s in _mixed_sessions(6):
            tier = "interactive" if s.sid % 2 else "bulk"
            if multi or (tier == sched._only):
                sched.submit(s, tier=tier if multi else None)

    multi = StreamScheduler(params, CFG, n_slots=2, tiers=tiers, ingest=True)
    multi._only = None
    submit_split(multi, True)
    got = {s.sid: s for s in multi.run_until_drained()}
    multi.close()
    assert multi.tiers == ("interactive", "bulk")
    assert multi.n_slots == 4
    assert set(multi.n_compiles_by_tier.values()) == {1}
    per_tier = multi.telemetry.per_tier()
    assert set(per_tier) == {"interactive", "bulk"}
    assert per_tier["interactive"]["timesteps"] > 0
    lat = multi.telemetry.tier_percentiles()
    assert set(lat) == {"interactive", "bulk"}

    ref = {}
    for name, C in [("interactive", 4), ("bulk", 12)]:
        solo = StreamScheduler(params, CFG, n_slots=2, chunk_len=C)
        solo._only = name
        submit_split(solo, False)
        ref.update({s.sid: s for s in solo.run_until_drained()})
    _assert_fleet_identical(ref, got)


def test_tier_validation(params):
    with pytest.raises(ValueError, match="duplicate"):
        StreamScheduler(params, CFG, n_slots=2,
                        tiers=[TierConfig("a", 4, 2), TierConfig("a", 8, 2)])
    with pytest.raises(ValueError, match="non-empty"):
        StreamScheduler(params, CFG, n_slots=2, tiers=[])
    with pytest.raises(ValueError):
        TierConfig("x", chunk_len=0, n_slots=2)
    with pytest.raises(ValueError):
        TierConfig("x", chunk_len=4, n_slots=0)
    sched = StreamScheduler(params, CFG, n_slots=2,
                            tiers=[TierConfig("a", 4, 2)])
    with pytest.raises(ValueError, match="unknown tier"):
        sched.submit(StreamSession(sid=0, source=ReplaySource(_events(0, 8))),
                     tier="b")


def test_topology_requires_single_tier(params):
    from repro.core.dsst import DSSTConfig
    from repro.serving import TopologyService, TopologyServiceConfig

    tcfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=12,
                     dsst=DSSTConfig(period=4, prune_frac=0.5))
    svc = TopologyService(tcfg, TopologyServiceConfig(epoch_every=50))
    with pytest.raises(ValueError, match="single-tier"):
        StreamScheduler(init_params(jax.random.PRNGKey(0), tcfg), tcfg,
                        n_slots=2, topology=svc,
                        tiers=[TierConfig("a", 4, 2), TierConfig("b", 8, 2)])


# ------------------------------------------------------- 8-device parity

def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_qos_8device_matches_serial(params):
    """Tiers + async ingest + adaptive depth on an 8-device sharded grid:
    per-stream results bit-identical to the plain serial single-device
    single-grid references."""
    _run_sub("""
        import jax, numpy as np
        from repro.core.snn import SNNConfig, init_params
        from repro.data.events import make_task
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import (ArrivalConfig, AERStreamSource,
                                   AutopilotConfig, StreamScheduler,
                                   StreamSession, TierConfig)

        assert jax.device_count() == 8
        CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8,
                        t_steps=16)
        params = init_params(jax.random.PRNGKey(0), CFG)
        task = make_task("gesture", n_in=CFG.n_in, t_steps=CFG.t_steps)
        JIT = ArrivalConfig(min_chunk=3, max_chunk=13, mean_gap_s=0.004,
                            start_jitter_s=0.02)

        def sessions():
            return [StreamSession(sid=sid,
                                  source=AERStreamSource(task, n_windows=2,
                                                         seed=sid,
                                                         arrival=JIT),
                                  adapt=(sid % 2 == 0))
                    for sid in range(10)]

        def run(**kw):
            sched = StreamScheduler(params, CFG, **kw)
            for i, s in enumerate(sessions()):
                tier = None
                if "tiers" in kw:
                    tier = "interactive" if s.sid % 2 else "bulk"
                sched.submit(s, tier=tier)
            done = {s.sid: s for s in sched.run_until_drained()}
            sched.close()
            return done, sched

        tiers = [TierConfig("interactive", chunk_len=4, n_slots=8),
                 TierConfig("bulk", chunk_len=12, n_slots=8)]
        got, sched = run(n_slots=8, tiers=tiers, mesh=make_serving_mesh(),
                         ingest=True,
                         autopilot=AutopilotConfig(
                             max_depth=2, decide_every=1, hold_steps=2,
                             warmup_obs=1, deepen_above=0.0,
                             relax_below=0.0))
        assert set(sched.n_compiles_by_tier.values()) == {1}
        assert len(sched.autopilot.depths_visited()) > 1

        ref = {}
        for name, C in [("interactive", 4), ("bulk", 12)]:
            solo = StreamScheduler(params, CFG, n_slots=8, chunk_len=C)
            for s in sessions():
                want = "interactive" if s.sid % 2 else "bulk"
                if want == name:
                    solo.submit(s)
            ref.update({s.sid: s for s in solo.run_until_drained()})

        assert set(ref) == set(got)
        for sid in ref:
            a, b = ref[sid], got[sid]
            assert a.timesteps_fed == b.timesteps_fed
            assert len(a.predictions) == len(b.predictions)
            for pa, pb in zip(a.predictions, b.predictions):
                np.testing.assert_array_equal(pa.logits, pb.logits)
            np.testing.assert_array_equal(a.final_deltas, b.final_deltas)
        print("OK", len(ref))
    """)
