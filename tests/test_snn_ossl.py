"""The paper-faithful SNN: dynamics, OSSL learning, gating, DSST end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.dsst import DSSTConfig
from repro.core.gating import GatingConfig, skip_rate
from repro.core.snn import (SNNConfig, accuracy, init_params, init_state,
                            lif_step, make_eval_fn, make_train_fn, run_sample,
                            surrogate_grad)
from repro.core import sparsity as sp
from repro.data.events import make_task


def small_cfg(**kw):
    base = dict(n_in=64, n_hidden=64, n_out=4, t_steps=16,
                dsst=DSSTConfig(period=6, prune_frac=0.25))
    base.update(kw)
    return SNNConfig(**base)


def test_lif_closed_form():
    """No spikes below threshold: v follows the leaky-integrator geometric sum."""
    v = jnp.zeros((1, 4))
    tr = jnp.zeros((1, 4))
    cur = jnp.full((1, 4), 0.05)
    alpha = 0.9
    for _ in range(10):
        v, tr, s = lif_step(v, tr, cur, alpha=alpha, beta=0.8, theta=1.0)
        assert float(s.max()) == 0.0
    expected = 0.05 * (1 - alpha ** 10) / (1 - alpha)
    np.testing.assert_allclose(v, expected, rtol=1e-5)
    assert float(tr.max()) == 0.0


def test_lif_fires_and_soft_resets():
    v = jnp.array([[0.96]])
    v2, tr, s = lif_step(v, jnp.zeros((1, 1)), jnp.array([[0.1]]),
                         alpha=1.0, beta=0.5, theta=1.0)
    assert float(s[0, 0]) == 1.0
    np.testing.assert_allclose(v2, 0.06, atol=1e-6)   # soft reset: v - theta
    np.testing.assert_allclose(tr, 1.0)


def test_surrogate_is_triangular():
    v = jnp.array([0.0, 0.5, 1.0, 1.5, 2.0])
    g = surrogate_grad(v, theta=1.0, width=1.0)
    np.testing.assert_allclose(g, [0.0, 0.5, 1.0, 0.5, 0.0], atol=1e-6)


def test_masks_stay_nm_through_training():
    cfg = small_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, batch=8)
    step = make_train_fn(cfg)
    task = make_task("shd_kws", n_in=64, t_steps=16)
    rng = np.random.default_rng(0)
    for i in range(14):   # crosses two DSST events
        ev, lab = task.sample(rng, 8)
        params, state, m = step(params, state, jnp.asarray(ev), jnp.asarray(lab))
    for l, fan_in in enumerate(cfg.layer_fanins):
        spec = cfg.spec(fan_in)
        w, mask = engine.hidden_slice(params, l, cfg)
        assert bool(sp.check_unit_mask(mask, spec))
        # weights outside the mask must be exactly zero
        dense = sp.expand_unit_mask(mask, spec, fan_in, cfg.n_hidden)
        off = jnp.where(dense, 0.0, w)
        assert float(jnp.abs(off).max()) == 0.0
    assert not bool(jnp.isnan(m.logits).any())


def test_ossl_learns_separable_readout():
    """After OSSL + SL training, accuracy on held-out samples beats chance
    clearly (the paper's central claim: hierarchical features without labels)."""
    cfg = small_cfg(t_steps=20, n_out=10,
                    dsst=DSSTConfig(period=10, prune_frac=0.25))
    task = make_task("shd_kws", n_in=64, t_steps=20)   # 10 classes
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, batch=16)
    step = make_train_fn(cfg)
    rng = np.random.default_rng(1)
    for i in range(150):
        ev, lab = task.sample(rng, 16)
        params, state, _ = step(params, state, jnp.asarray(ev), jnp.asarray(lab))
    eval_fn = make_eval_fn(cfg)
    state_e = init_state(cfg, batch=64)
    ev, lab = task.sample(np.random.default_rng(999), 64)
    _, m = eval_fn(params, state_e, jnp.asarray(ev))
    acc = float(accuracy(m.logits, jnp.asarray(lab)))
    assert acc > 0.4, f"accuracy {acc} not well above chance (0.1)"


def test_gating_skips_repeats():
    """Replaying the same sample drives SS up -> gate closes (skip)."""
    cfg = small_cfg(gating=GatingConfig(enabled=True))
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, batch=8)
    step = make_train_fn(cfg)
    task = make_task("nmnist", n_in=64, t_steps=16)
    ev, lab = task.sample(np.random.default_rng(0), 8)
    ev, lab = jnp.asarray(ev), jnp.asarray(lab)
    fracs = []
    for i in range(10):   # same sample over and over
        params, state, m = step(params, state, ev, lab)
        fracs.append(float(m.gate_open_frac))
    assert np.mean(fracs[5:]) < np.mean(fracs[:2]) + 1e-6
    assert float(skip_rate(state.gate)) > 0.2


def test_gating_disabled_always_open():
    cfg = small_cfg(gating=GatingConfig(enabled=False), wu_start_frac=0.5)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, batch=4)
    task = make_task("gesture", n_in=64, t_steps=16)
    ev, lab = task.sample(np.random.default_rng(0), 4)
    params, state, m = run_sample(params, state, jnp.asarray(ev),
                                  jnp.asarray(lab), cfg, learn=True)
    assert float(m.gate_open_frac) == 1.0
    assert float(m.sop_wu) == float(m.sop_wu_offered)


def test_sparse_vs_dense_sop_counts():
    """Forward SOPs scale with density — the zero-skipping energy claim."""
    task = make_task("gesture", n_in=64, t_steps=16)
    ev, lab = task.sample(np.random.default_rng(0), 8)
    outs = {}
    for name, dense in [("sparse", False), ("dense", True)]:
        cfg = small_cfg(dense=dense, gating=GatingConfig(enabled=False))
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_state(cfg, batch=8)
        _, _, m = run_sample(params, state, jnp.asarray(ev), jnp.asarray(lab),
                             cfg, learn=True)
        outs[name] = float(m.sop_forward)
    ratio = outs["sparse"] / outs["dense"]
    assert 0.15 < ratio < 0.35    # ~20% density at 80% sparsity


def test_bypass_single_hidden_layer():
    cfg = small_cfg(n_layers=1)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, batch=4)
    task = make_task("nav_cue", n_in=64, t_steps=16)
    ev, lab = task.sample(np.random.default_rng(0), 4)
    params, state, m = run_sample(params, state, jnp.asarray(ev),
                                  jnp.asarray(lab), cfg, learn=True)
    assert m.logits.shape == (4, 4)
    assert not bool(jnp.isnan(m.logits).any())
