"""Topology lifecycle: stacked epochs, delta projection, the DSST schedule.

The contract pinned here backs the live-topology serving service:

* one stacked ``topology_epoch`` == the per-layer reference events;
* ``project_deltas`` keeps surviving connections' delta values BIT-exactly
  and zeroes pruned/regrown coordinates (property-tested);
* the ``DSSTConfig`` decay schedule is honored under jit — ``frac_decay``
  and ``start_step`` change the recycled-connection count at the scheduled
  steps (regression: ``k_per_group`` used to be called without the step,
  pinning k to its step-0 value forever).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro.core import dsst, engine, sparsity as sp, topology
from repro.core.snn import (SNNConfig, init_params, init_state,
                            init_stream_deltas, run_sample)

CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16,
                dsst=dsst.DSSTConfig(period=4, prune_frac=0.5))


def _params(seed=0, cfg=CFG):
    return init_params(jax.random.PRNGKey(seed), cfg)


def _factors(seed, cfg=CFG):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    kb = max(cfg.layer_fanins)
    pre = jnp.abs(jax.random.normal(ks[0], (cfg.n_layers, kb))) + 0.01
    post = jnp.abs(jax.random.normal(ks[1], (cfg.n_layers, cfg.n_hidden))) + 0.01
    return pre, post


# -------------------------------------------------------------- the value

def test_from_params_install_roundtrip_preserves_extra_keys():
    params = _params()
    topo = topology.from_params(params, CFG)
    assert topo.idx is not None                      # uniform geometry
    spec = CFG.spec(CFG.layer_fanins[0])
    g = CFG.layer_fanins[0] // spec.m
    assert topo.idx.shape == (CFG.n_layers, g, spec.n, CFG.n_hidden)
    # idx really is the compact view of the mask
    for l in range(CFG.n_layers):
        back = sp.indices_to_unit_mask(topo.idx[l], spec)
        np.testing.assert_array_equal(np.asarray(back),
                                      np.asarray(topo.unit_mask[l]))
    # generic install: future params keys survive at both nesting levels
    fat = {**params, "aux_head": jnp.ones(3),
           "hidden": {**params["hidden"], "scales": jnp.ones(2)}}
    out = topology.install(topo, fat)
    assert "aux_head" in out and "scales" in out["hidden"]
    np.testing.assert_array_equal(np.asarray(out["hidden"]["mask"]),
                                  np.asarray(topo.unit_mask))
    assert topology.check(topo, CFG)


def test_check_rejects_broken_invariant():
    params = _params()
    mask = np.asarray(params["hidden"]["mask"]).copy()
    mask[0, :, 0] = True                             # too many per group
    assert not topology.check(jnp.asarray(mask), CFG)


# -------------------------------------------------------------- stacked epoch

def test_stacked_epoch_equals_per_layer_reference():
    """topology_epoch == hand-rolled per-layer prune/regrow + weight remap
    (the exact code run_sample used before the refactor)."""
    cfg = CFG
    params = _params(1)
    pre, post = _factors(7)
    new_params, stats = topology.topology_epoch(params, pre, post, cfg, step=0)

    spec = cfg.spec(cfg.layer_fanins[0])
    k = cfg.dsst.k_per_group(spec, 0)
    assert k >= 1, "test config must actually recycle connections"
    for l, fan_in in enumerate(cfg.layer_fanins):
        kb, j = spec.unit_counts(fan_in, cfg.n_hidden)
        w = params["hidden"]["w"][l, :fan_in]
        mask = params["hidden"]["mask"][l, :kb, :j]
        wsc = sp.unit_scores(w, spec, fan_in, cfg.n_hidden)
        ref_mask, ref_stats = dsst.prune_regrow_factored(
            mask, wsc, pre[l, :kb], post[l, :j], spec, k)
        ref_w = dsst.apply_dsst_to_weights(w, mask, ref_mask, spec)
        np.testing.assert_array_equal(
            np.asarray(new_params["hidden"]["mask"][l, :kb, :j]),
            np.asarray(ref_mask))
        np.testing.assert_array_equal(
            np.asarray(new_params["hidden"]["w"][l, :fan_in]),
            np.asarray(ref_w))
        assert int(stats.pruned[l]) == int(ref_stats.pruned)
        assert int(stats.regrown[l]) == int(ref_stats.regrown)
    assert topology.check(new_params["hidden"]["mask"], cfg)
    # readout untouched, bitwise
    np.testing.assert_array_equal(np.asarray(new_params["readout"]),
                                  np.asarray(params["readout"]))


def test_epoch_prunes_exactly_k_per_group():
    cfg = CFG
    params = _params(2)
    pre, post = _factors(9)
    _, stats = topology.topology_epoch(params, pre, post, cfg, step=0)
    spec = cfg.spec(cfg.layer_fanins[0])
    k = cfg.dsst.k_per_group(spec, 0)
    g = cfg.layer_fanins[0] // spec.m
    for l in range(cfg.n_layers):
        assert int(stats.pruned[l]) == k * g * cfg.n_hidden
        assert int(stats.pruned[l]) == int(stats.regrown[l])


# -------------------------------------------------------------- delta projection

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_project_deltas_bit_exact(seed):
    """Across any mask change: surviving coordinates keep their delta BITS,
    pruned coordinates go to exactly zero, and the new mask keeps N:M."""
    cfg = CFG
    params = _params(seed % 7)
    pre, post = _factors(seed)
    deltas = jax.random.normal(jax.random.PRNGKey(seed),
                               (3,) + params["hidden"]["w"].shape)
    old_mask = params["hidden"]["mask"]
    # deltas live on the old mask's support (the engine's invariant)
    deltas = deltas * topology.dense_masks(old_mask, cfg)[None]

    new_params, _ = topology.topology_epoch(params, pre, post, cfg, step=0)
    new_mask = new_params["hidden"]["mask"]
    assert topology.check(new_mask, cfg)
    proj = topology.project_deltas(deltas, old_mask, new_mask, cfg)

    surv = np.asarray(topology.survivors_dense(old_mask, new_mask, cfg))
    d0, d1 = np.asarray(deltas), np.asarray(proj)
    # survivors: identical bits (not just allclose)
    np.testing.assert_array_equal(d1[:, surv], d0[:, surv])
    # everything else: exactly zero
    assert np.all(d1[:, ~surv] == 0.0)
    # something was actually pruned, or the test is vacuous
    pruned = np.asarray(old_mask) & ~np.asarray(new_mask)
    assert pruned.any()


# -------------------------------------------------------------- the schedule

def test_k_levels_and_k_per_group_follow_decay():
    spec = sp.NMSpec(4, 8)
    cfg = dsst.DSSTConfig(period=5, prune_frac=0.5, frac_decay=0.5,
                          start_step=10)
    # event 0 -> k=2, event 1 -> k=1, event 2 -> k=0 (round(0.5)=0)
    assert cfg.k_levels(spec) == ((0, 2), (1, 1), (2, 0))
    assert cfg.k_per_group(spec, 10) == 2
    assert cfg.k_per_group(spec, 14) == 2
    assert cfg.k_per_group(spec, 15) == 1     # event 1
    assert cfg.k_per_group(spec, 20) == 0     # event 2: decayed away
    # no decay: single level
    assert dsst.DSSTConfig(prune_frac=0.5).k_levels(spec) == ((0, 2),)


def test_maybe_dsst_honors_schedule_under_jit():
    """Regression: maybe_dsst pinned k to its step-0 value forever. With
    frac_decay the recycled count must shrink at later scheduled steps —
    also under a traced step (lax.switch over the static levels)."""
    spec = sp.NMSpec(4, 8)
    cfg = dsst.DSSTConfig(period=5, prune_frac=0.5, frac_decay=0.5)
    mask = sp.random_unit_mask(jax.random.PRNGKey(0), spec, 32, 4)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 4))
    acc = dsst.DSSTAccumulator.init(32, 4).update(
        jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (32,))) + 0.01,
        jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4,))) + 0.01)

    fn = jax.jit(lambda s: dsst.maybe_dsst(s, cfg, spec, w, mask, acc))
    g = 32 // spec.m
    # event 0 at step 4: k=2 -> 2*G*J flips each way
    _, m0, _, did0 = fn(jnp.asarray(4))
    assert bool(did0)
    assert int((np.asarray(mask) & ~np.asarray(m0)).sum()) == 2 * g * 4
    # event 1 at step 9: k=1
    _, m1, _, did1 = fn(jnp.asarray(9))
    assert bool(did1)
    assert int((np.asarray(mask) & ~np.asarray(m1)).sum()) == 1 * g * 4
    # event 2 at step 14: k decayed to 0 -> mask unchanged (still an event)
    _, m2, _, did2 = fn(jnp.asarray(14))
    assert bool(did2)
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(mask))
    # off-cycle: identity
    _, m3, _, did3 = fn(jnp.asarray(7))
    assert not bool(did3)
    np.testing.assert_array_equal(np.asarray(m3), np.asarray(mask))
    assert bool(sp.check_unit_mask(m0, spec))
    assert bool(sp.check_unit_mask(m1, spec))


def test_run_sample_honors_schedule():
    """End-to-end: the jitted train step's DSST epochs follow the decay
    schedule through the traced sample index."""
    cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=1, n_out=8, t_steps=8,
                    dsst=dsst.DSSTConfig(period=2, prune_frac=0.5,
                                         frac_decay=0.5))
    spec = cfg.spec(32)
    assert cfg.dsst.k_levels(spec) == ((0, 1), (1, 0))
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, 2)
    ev = jnp.asarray((np.random.default_rng(0).random((8, 2, 32)) < 0.4)
                     .astype(np.float32))
    fn = jax.jit(lambda p, s: run_sample(p, s, ev, None, cfg))

    masks = [np.asarray(params["hidden"]["mask"])]
    for _ in range(6):
        params, state, _ = fn(params, state)
        masks.append(np.asarray(params["hidden"]["mask"]))
        assert topology.check(params["hidden"]["mask"], cfg)
    # sample 1 closes event 0 (k=1): mask changed
    assert (masks[2] != masks[1]).any()
    # sample 3 closes event 1 (k decayed to 0): mask identical
    np.testing.assert_array_equal(masks[4], masks[3])
    np.testing.assert_array_equal(masks[6], masks[5])


def test_init_stream_deltas_match_topology_width():
    """The delta tensor the projection operates on matches the dense mask
    expansion — shape contract between serving and topology. The dense
    baseline matches the mask directly; the compact default densifies to
    the same dense width through the mask's kept-block ids."""
    mask = _params()["hidden"]["mask"]
    dm = topology.dense_masks(mask, CFG)
    dl_dense = init_stream_deltas(CFG, 4, compact=False)
    assert dl_dense.shape[1:] == dm.shape
    dl = init_stream_deltas(CFG, 4)               # compact [S,L,J,T,bk,bo]
    assert dl.ndim == 6
    idx = topology.stacked_kept_ids(mask, CFG)
    back = engine.densify_deltas(dl, idx, CFG)
    assert back.shape[1:] == dm.shape
