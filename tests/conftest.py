import os

# Tests run single-device (the dry-run pins 512 devices itself, in its own
# process). Force CPU determinism-friendly settings only.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
