"""Per-rule fixtures for the host-path lint (repro.analysis.lint).

Each rule gets a positive snippet (must be caught) and a negative twin
(must stay clean), plus the suppression-comment and baseline workflows and
an end-to-end CLI run over the real repo against the checked-in baseline.
"""
import json
import pathlib
import textwrap

from repro.analysis import lint

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

# paths chosen to fall inside each rule's scope
SYNC_PATH = "src/repro/serving/staging.py"
OBS_PATH = "src/repro/obs/fixture.py"
DOC_PATH = "docs/FIXTURE.md"


def _lint(path, src):
    return lint.lint_source(path, textwrap.dedent(src))


def _rules(violations):
    return sorted({v.rule for v in violations})


# ----------------------------------------------------------------- SYNC01

def test_sync01_catches_item_in_hot_phase():
    vs = _lint(SYNC_PATH, """\
        class Pipe:
            def poll(self):
                n = self.counts.item()
                return n
        """)
    assert _rules(vs) == ["SYNC01"]
    assert "poll" in vs[0].message and vs[0].line == 3


def test_sync01_catches_np_asarray_on_device_state():
    vs = _lint(SYNC_PATH, """\
        import numpy as np

        class Pipe:
            def _stage(self, chunk):
                host = np.asarray(chunk.metrics)
                return host
        """)
    assert _rules(vs) == ["SYNC01"]
    assert "np.asarray" in vs[0].message


def test_sync01_negative_cold_function_and_host_values():
    vs = _lint(SYNC_PATH, """\
        import numpy as np

        class Pipe:
            def retire(self):
                return self.counts.item()       # retire may wait

            def poll(self):
                return np.asarray([1, 2, 3])    # host literal, no sync
        """)
    assert vs == []


def test_sync01_out_of_scope_path_is_clean():
    vs = _lint("src/repro/core/engine.py", """\
        class X:
            def poll(self):
                return self.counts.item()
        """)
    assert vs == []


# ------------------------------------------------------------------ OBS01

def test_obs01_catches_unbounded_append():
    vs = _lint(OBS_PATH, """\
        class Telemetry:
            def __init__(self):
                self.events = []

            def record(self, e):
                self.events.append(e)
        """)
    assert _rules(vs) == ["OBS01"]
    assert "self.events" in vs[0].message and "record" in vs[0].message


def test_obs01_catches_dict_key_insert_and_bare_deque():
    vs = _lint(OBS_PATH, """\
        from collections import deque

        class Telemetry:
            def __init__(self):
                self.by_sid = {}
                self.log = deque()

            def record(self, sid, e):
                self.by_sid[sid] = e
                self.log.append(e)
        """)
    assert [v.rule for v in vs] == ["OBS01", "OBS01"]


def test_obs01_negative_bounded_deque_is_clean():
    vs = _lint(OBS_PATH, """\
        from collections import deque

        class Telemetry:
            def __init__(self):
                self.recent = deque(maxlen=256)

            def record(self, e):
                self.recent.append(e)
        """)
    assert vs == []


# ------------------------------------------------------------------ OBS02

def test_obs02_catches_mutation_outside_lock():
    vs = _lint(OBS_PATH, """\
        import threading

        class Shared:
            def __init__(self):
                self._lock = threading.Lock()
                self.total = 0

            def bump(self, n):
                self.total += n
        """)
    assert _rules(vs) == ["OBS02"]
    assert "self.total" in vs[0].message and "bump" in vs[0].message


def test_obs02_negative_mutation_under_lock():
    vs = _lint(OBS_PATH, """\
        import threading

        class Shared:
            def __init__(self):
                self._lock = threading.Lock()
                self.total = 0

            def bump(self, n):
                with self._lock:
                    if n > 0:
                        self.total += n
        """)
    assert vs == []


def test_obs02_negative_lockless_class_out_of_scope():
    # a class with no lock attribute has opted out of OBS02 (OBS01 still
    # watches its containers)
    vs = _lint(OBS_PATH, """\
        class Plain:
            def __init__(self):
                self.total = 0

            def bump(self, n):
                self.total += n
        """)
    assert vs == []


# ----------------------------------------------------------------- HOST01

def test_host01_catches_module_level_jax_import():
    vs = _lint(OBS_PATH, """\
        import jax
        import jax.numpy as jnp
        """)
    assert [v.rule for v in vs] == ["HOST01", "HOST01"]


def test_host01_negative_lazy_import_is_fine():
    vs = _lint(OBS_PATH, """\
        def fetch(x):
            import jax
            return jax.device_get(x)
        """)
    assert vs == []


# ------------------------------------------------------------------ DOC01

def test_doc01_catches_bare_pythonish_fence():
    vs = _lint(DOC_PATH, "intro\n\n```\nimport repro\nprint(repro)\n```\n")
    assert _rules(vs) == ["DOC01"]


def test_doc01_negative_tagged_or_non_python():
    clean = ("```python\nimport repro\n```\n"
             "```python noexec\nfrom x import y\n```\n"
             "```\n$ pip list\n```\n")
    assert _lint(DOC_PATH, clean) == []


# ------------------------------------------------------- suppression lines

def test_suppression_same_line_and_line_above():
    vs = _lint(SYNC_PATH, """\
        class Pipe:
            def poll(self):
                a = self.counts.item()  # lint: ok SYNC01 sanctioned here
                # lint: ok SYNC01 sanctioned here too
                b = self.totals.item()
                c = self.others.item()
                return a + b + c
        """)
    assert len(vs) == 1 and vs[0].line == 6


def test_suppression_rule_must_match():
    vs = _lint(SYNC_PATH, """\
        class Pipe:
            def poll(self):
                return self.counts.item()  # lint: ok OBS01 wrong rule
        """)
    assert _rules(vs) == ["SYNC01"]


def test_suppression_markdown_comment():
    src = ("<!-- lint: ok DOC01 illustration of a bare fence -->\n"
           "```\nimport repro\n```\n")
    assert _lint(DOC_PATH, src) == []


# ------------------------------------------------------- baseline workflow

def test_baseline_roundtrip_new_and_stale(tmp_path):
    caught = _lint(OBS_PATH, """\
        class Telemetry:
            def __init__(self):
                self.events = []

            def record(self, e):
                self.events.append(e)
        """)
    assert len(caught) == 1

    bp = tmp_path / "baseline.json"
    lint.write_baseline(bp, caught)
    entries = lint.load_baseline(bp)
    assert [e["rule"] for e in entries] == ["OBS01"]

    # accepted finding filters out; nothing stale
    new, stale = lint.apply_baseline(caught, entries)
    assert new == [] and stale == []

    # a different violation is NOT covered; the old entry reads as stale
    other = _lint(OBS_PATH, """\
        class Telemetry:
            def __init__(self):
                self.log = []

            def push(self, e):
                self.log.append(e)
        """)
    new, stale = lint.apply_baseline(other, entries)
    assert len(new) == 1 and len(stale) == 1


def test_baseline_keyed_by_line_text_not_number():
    src = """\
        class Telemetry:
            def __init__(self):
                self.events = []

            def record(self, e):
                self.events.append(e)
        """
    v0 = _lint(OBS_PATH, src)[0]
    shifted = _lint(OBS_PATH, "# a new leading comment\n"
                    + textwrap.dedent(src))[0]
    assert shifted.line != v0.line
    assert shifted.baseline_key == v0.baseline_key


# ------------------------------------------------------------ CLI / repo

def test_cli_runs_clean_against_checked_in_baseline(tmp_path, capsys):
    """Acceptance: the real repo lints clean through lint-baseline.json
    (what CI's static-analysis step runs)."""
    out_json = tmp_path / "lint.json"
    rc = lint.main(["--root", str(REPO_ROOT), "--baseline",
                    "--json", str(out_json)])
    stdout = capsys.readouterr().out
    assert rc == 0, stdout
    assert "lint clean" in stdout
    doc = json.loads(out_json.read_text())
    assert doc["schema"] == "repro-lint/1"
    assert doc["violations"] == [] and doc["stale_baseline"] == []


def test_cli_without_baseline_reports_accepted_findings(capsys):
    """The baseline is load-bearing: the raw run still sees the accepted
    per-stream-counter finding (so the baseline file cannot rot silently)."""
    rc = lint.main(["--root", str(REPO_ROOT)])
    stdout = capsys.readouterr().out
    assert rc == 1
    assert "serving/telemetry.py" in stdout and "OBS01" in stdout


def test_repo_baseline_file_matches_real_findings():
    """Every checked-in baseline entry corresponds to a live finding (no
    stale entries) and carries a reason."""
    entries = lint.load_baseline(REPO_ROOT / lint.DEFAULT_BASELINE)
    assert entries, "baseline should carry the accepted findings"
    assert all(e.get("reason") for e in entries)
    violations = lint.lint_paths(REPO_ROOT)
    new, stale = lint.apply_baseline(violations, entries)
    assert new == [] and stale == []
