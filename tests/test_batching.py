"""Continuous batching: correctness vs single-request generate, slot reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch.batching import ContinuousBatcher, Request
from repro.launch.serve import generate
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = C.get_reduced("phi3_medium_14b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_matches_single_request_generate(setup):
    cfg, params = setup
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)
    want = np.asarray(generate(params, cfg, prompt, n_new=5))[0, 6:]

    b = ContinuousBatcher(params, cfg, n_slots=2, max_seq=32)
    b.submit(Request(rid=0, prompt=prompt[0].tolist(), max_new=5))
    done = b.run_until_drained()
    assert len(done) == 1
    np.testing.assert_array_equal(np.asarray(done[0].out), want)


def test_concurrent_requests_isolated(setup):
    """Two different prompts decoded in adjacent slots must each match their
    solo generation — per-slot cache lanes don't leak."""
    cfg, params = setup
    p1 = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 0, cfg.vocab)
    p2 = jax.random.randint(jax.random.PRNGKey(3), (1, 5), 0, cfg.vocab)
    w1 = np.asarray(generate(params, cfg, p1, n_new=4))[0, 5:]
    w2 = np.asarray(generate(params, cfg, p2, n_new=4))[0, 5:]

    b = ContinuousBatcher(params, cfg, n_slots=2, max_seq=32)
    b.submit(Request(rid=1, prompt=p1[0].tolist(), max_new=4))
    b.submit(Request(rid=2, prompt=p2[0].tolist(), max_new=4))
    done = {r.rid: r for r in b.run_until_drained()}
    np.testing.assert_array_equal(np.asarray(done[1].out), w1)
    np.testing.assert_array_equal(np.asarray(done[2].out), w2)


def test_max_new_1_emits_exactly_one_token(setup):
    """Regression: the prefill-completion branch appended the first
    generated token and ``continue``d past the done check, so a
    ``max_new=1`` request decoded one extra step and emitted 2 tokens."""
    cfg, params = setup
    prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 6), 0, cfg.vocab)
    want = np.asarray(generate(params, cfg, prompt, n_new=1))[0, 6:]

    b = ContinuousBatcher(params, cfg, n_slots=2, max_seq=32)
    b.submit(Request(rid=0, prompt=prompt[0].tolist(), max_new=1))
    done = b.run_until_drained()
    assert len(done) == 1 and done[0].done
    assert len(done[0].out) == 1, done[0].out
    np.testing.assert_array_equal(np.asarray(done[0].out), want)
    assert b.stats["tokens_out"] == 1
    assert b.grid.drained


def test_eos_as_first_generated_token_retires_immediately(setup):
    """Regression: an EOS emitted by the prefill-completion branch was
    ignored for an extra decode step. Pick eos_id = the token the model
    actually generates first, then assert the request stops at 1 token."""
    cfg, params = setup
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 5), 0, cfg.vocab)
    first = int(np.asarray(generate(params, cfg, prompt, n_new=1))[0, 5])

    b = ContinuousBatcher(params, cfg, n_slots=2, max_seq=32, eos_id=first)
    b.submit(Request(rid=0, prompt=prompt[0].tolist(), max_new=8))
    done = b.run_until_drained()
    assert len(done) == 1 and done[0].done
    assert done[0].out == [first], done[0].out


def test_slot_reuse_more_requests_than_slots(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).tolist(),
                    max_new=3) for i in range(5)]
    b = ContinuousBatcher(params, cfg, n_slots=2, max_seq=32)
    for r in reqs:
        b.submit(r)
    done = b.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out) == 3 for r in done)
    assert 0 < b.utilization <= 1.0
