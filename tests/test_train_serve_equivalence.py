"""Training and serving share ONE timestep engine (core/engine.py).

The load-bearing acceptance property of the engine refactor: `run_chunk`
driven with all-valid, window-aligned chunks from zero deltas must retrace
`run_sample` exactly — logits, traces, adaptive gate thresholds, weight
drift (base updates ≡ accumulated deltas, by linearity of the forward
current), and telemetry — at every depth. One stream vs batch-of-one, so
the training path's batch-shared gate decisions coincide with the serving
path's per-slot decisions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.core import engine
from repro.core.snn import (SNNConfig, init_params, init_state,
                            init_stream_deltas, init_stream_state, run_chunk,
                            run_sample)

N_WINDOWS = 2
CHUNK = 6   # divides t_steps: chunks are window-aligned


def _cfg(depth):
    return SNNConfig(n_in=32, n_hidden=32, n_layers=depth, n_out=8,
                     t_steps=12, dsst_enabled=False)


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_chunk_trajectory_matches_sample(depth):
    cfg = _cfg(depth)
    T = cfg.t_steps
    t_wu = int(T * cfg.wu_start_frac)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    ev = (rng.random((N_WINDOWS * T, 1, cfg.n_in)) < 0.3).astype(np.float32)

    # ---- training path: batch of one, learn on, labels off (no SL drift)
    ps, st = params, init_state(cfg, 1)
    tr_logits, tr_sop = [], {"fwd": 0.0, "wu": 0.0, "off": 0.0}
    tr_loss = tr_opens = 0.0
    for w in range(N_WINDOWS):
        ps, st, m = run_sample(ps, st, jnp.asarray(ev[w * T:(w + 1) * T]),
                               None, cfg, learn=True)
        tr_logits.append(np.asarray(m.logits[0]))
        tr_sop["fwd"] += float(m.sop_forward)
        tr_sop["wu"] += float(m.sop_wu)
        tr_sop["off"] += float(m.sop_wu_offered)
        tr_loss += float(m.local_loss) * (T - t_wu)
        tr_opens += float(m.gate_open_frac) * T * cfg.n_layers

    # ---- serving path: one slot, frozen base + delta, window-aligned chunks
    ss, dl = init_stream_state(cfg, 1), init_stream_deltas(cfg, 1)
    sv_logits, sv_sop = [], {"fwd": 0.0, "wu": 0.0, "off": 0.0}
    sv_loss = sv_opens = 0.0
    for c in range(0, N_WINDOWS * T, CHUNK):
        chunk = jnp.asarray(ev[c:c + CHUNK])
        valid = jnp.ones((CHUNK, 1), bool)
        dl, ss, cm = run_chunk(params, dl, ss, chunk, valid, cfg, learn=True)
        for t in np.nonzero(np.asarray(cm.window_end[:, 0]))[0]:
            sv_logits.append(np.asarray(cm.logits[t, 0]))
        sv_sop["fwd"] += float(cm.sop_forward[0])
        sv_sop["wu"] += float(cm.sop_wu[0])
        sv_sop["off"] += float(cm.sop_wu_offered[0])
        sv_loss += float(cm.local_loss[0])
        sv_opens += float(cm.gate_opened[0].sum())

    # window logits (the user-visible predictions)
    assert len(tr_logits) == len(sv_logits) == N_WINDOWS
    for a, b in zip(tr_logits, sv_logits):
        np.testing.assert_allclose(a, b, atol=1e-5)

    # weight drift: in-place base updates == accumulated per-stream delta
    # (serving deltas come back compact [S, L, J, T, bk, bo]; densify over
    # the frozen base's kept-block ids for the dense comparison)
    from repro.core import topology
    idx = topology.stacked_kept_ids(params["hidden"]["mask"], cfg)
    dl_dense = engine.densify_deltas(dl, idx, cfg)
    drift = np.asarray(ps["hidden"]["w"] - params["hidden"]["w"])
    np.testing.assert_allclose(drift, np.asarray(dl_dense[0]), atol=1e-5)
    # labels never entered: readout identical on both paths
    np.testing.assert_array_equal(np.asarray(ps["readout"]),
                                  np.asarray(params["readout"]))

    # carried state: CC negatives, input trace, window counters, thresholds
    np.testing.assert_allclose(np.asarray(st.layers.tr_cc[:, 0]),
                               np.asarray(ss.layers.tr_cc[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st.x_tr[0]),
                               np.asarray(ss.x_tr[0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(st.gate.ss_mean),
                               np.asarray(ss.ss_mean[0]), atol=1e-6)
    assert int(st.sample_idx) == int(ss.sample_idx[0]) == N_WINDOWS

    # telemetry: identical energy-model inputs
    for k in ("fwd", "wu", "off"):
        np.testing.assert_allclose(tr_sop[k], sv_sop[k], rtol=1e-6)
    np.testing.assert_allclose(tr_opens, sv_opens, atol=1e-6)
    np.testing.assert_allclose(tr_loss, sv_loss, atol=1e-4)


def test_stacked_params_checkpoint_roundtrip(tmp_path):
    """The stacked layout survives checkpoint save/restore bitwise, and the
    legacy (PR-1 list-of-dicts) layout migrates through stack_params."""
    cfg = _cfg(2)
    params = init_params(jax.random.PRNGKey(1), cfg)
    checkpoint.save(str(tmp_path), 7, params)
    step, back, _ = checkpoint.restore(str(tmp_path), params)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    legacy = engine.unstack_params(params, cfg)
    assert isinstance(legacy["hidden"], list) and len(legacy["hidden"]) == 2
    restacked = engine.stack_params(legacy, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
