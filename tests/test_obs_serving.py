"""Observability of the serving hot path: spans, phase metrics, overlap.

The load-bearing acceptance properties of the obs subsystem:

* **Tracing is free of behavior**: with a live tracer attached, every
  per-stream trajectory — window predictions, final deltas, telemetry
  counters, topology epoch history — is BIT-identical to the untraced
  scheduler (1-device and 8-device subprocess), the chunk step still
  compiles exactly once, and the serving jaxpr is unchanged. Spans wrap
  host phases at already-synchronous points only.
* **Per-phase attribution survives pipelining**: each stage/dispatch/
  retire span carries the grid step that owns the work (a retire span
  recorded inside ``step()`` for step ``t`` belongs to step ``t-1``
  under double buffering — the bug whole-step walls can't see), exactly
  one span of each phase exists per grid step, and the per-phase wall
  sums reconcile with the step+flush walls.
* **Telemetry is bounded**: the step-latency histogram replaces the old
  unbounded list — O(buckets) memory at any stream count/run length,
  percentiles within one bucket width (~10%) of exact.
"""
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.core.dsst import DSSTConfig
from repro.core.snn import (SNNConfig, init_params, init_stream_deltas,
                            init_stream_state)
from repro.obs import Tracer, parse_prometheus_text, prometheus_text
from repro.obs.metrics import LATENCY_BUCKETS_S
from repro.serving import (ReplaySource, StreamScheduler, StreamSession,
                           TopologyService, TopologyServiceConfig)
from repro.serving.telemetry import FleetTelemetry

CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
EVOLVE_CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=12,
                       dsst=DSSTConfig(period=4, prune_frac=0.5))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _events(seed, t, rate=0.3):
    rng = np.random.default_rng(seed)
    return (rng.random((t, CFG.n_in)) < rate).astype(np.float32)


def _drive(params, cfg, depth, tracer=None, n_streams=5, n_slots=3,
           chunk_len=6, topology_every=0):
    svc = None
    if topology_every:
        svc = TopologyService(cfg, TopologyServiceConfig(
            epoch_every=topology_every, merge_top=1))
    sched = StreamScheduler(params, cfg, n_slots=n_slots, chunk_len=chunk_len,
                            topology=svc, pipeline_depth=depth, tracer=tracer)
    for sid in range(n_streams):
        sched.submit(StreamSession(
            sid=sid,
            source=ReplaySource(_events(sid, (3 + sid % 2) * cfg.t_steps,
                                        rate=0.25 + 0.03 * sid),
                                chunk_len=7),
            adapt=(sid % 2 == 0)))
    done = {s.sid: s for s in sched.run_until_drained()}
    return sched, svc, done


def _assert_fleet_identical(a, b):
    """(sched, svc, done) pairs: bit-identical per-stream outcomes."""
    sa, va, da = a
    sb, vb, db = b
    assert set(da) == set(db)
    for sid in da:
        pa, pb = da[sid].predictions, db[sid].predictions
        assert len(pa) == len(pb) > 0, (sid, len(pa), len(pb))
        for x, y in zip(pa, pb):
            np.testing.assert_array_equal(x.logits, y.logits)
        np.testing.assert_array_equal(da[sid].final_deltas,
                                      db[sid].final_deltas)
        ca, cb = sa.telemetry.stream(sid), sb.telemetry.stream(sid)
        for f in ("timesteps", "events_in", "sop_forward", "sop_wu",
                  "sop_wu_offered", "gate_opened", "gate_offered",
                  "windows", "local_loss"):
            assert getattr(ca, f) == getattr(cb, f), (sid, f)
    for x, y in zip(jax.tree_util.tree_leaves(sa.params),
                    jax.tree_util.tree_leaves(sb.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(sa.deltas), np.asarray(sb.deltas))


@pytest.fixture(scope="module")
def frozen_runs(params):
    """The same pipelined frozen-fleet workload, tracer off vs on."""
    off = _drive(params, CFG, depth=1)
    on = _drive(params, CFG, depth=1, tracer=Tracer(capacity=65536))
    return off, on


@pytest.fixture(scope="module")
def evolve_runs():
    """The same evolving-fleet workload, tracer off vs on."""
    p = init_params(jax.random.PRNGKey(1), EVOLVE_CFG)
    off = _drive(p, EVOLVE_CFG, depth=1, n_slots=4, topology_every=3)
    on = _drive(p, EVOLVE_CFG, depth=1, n_slots=4, topology_every=3,
                tracer=Tracer(capacity=65536))
    return off, on


# ------------------------------------------------- tracing changes nothing

def test_tracing_on_off_bit_identical(frozen_runs):
    off, on = frozen_runs
    assert off[0].n_compiles == 1 and on[0].n_compiles == 1
    _assert_fleet_identical(off, on)
    assert off[0].tracer.spans() == []          # NULL_TRACER records nothing
    assert on[0].tracer.n_recorded > 0 and on[0].tracer.n_dropped == 0


def test_tracing_on_off_bit_identical_evolving(evolve_runs):
    """With live topology epochs in the loop: same epochs, same evolved
    params/deltas, same trajectories — spans around ``svc.evolve`` change
    nothing about when or how epochs land."""
    off, on = evolve_runs
    va, vb = off[1], on[1]
    assert va.epoch_idx >= 2 and va.epoch_idx == vb.epoch_idx
    assert [(e.grid_step, e.pruned, e.regrown) for e in va.events] == \
           [(e.grid_step, e.pruned, e.regrown) for e in vb.events]
    _assert_fleet_identical(off, on)


def test_serving_jaxpr_unchanged_by_tracer(params):
    """Instrumentation never reaches the jitted computation: the chunk
    fn's jaxpr is identical with and without a tracer attached."""
    def chunk_jaxpr(sched):
        dl = init_stream_deltas(CFG, sched.n_slots)
        st = init_stream_state(CFG, sched.n_slots)
        ev = np.zeros((sched.chunk_len, sched.n_slots, CFG.n_in), np.float32)
        va = np.ones((sched.chunk_len, sched.n_slots), bool)
        am = np.ones(sched.n_slots, bool)
        return str(jax.make_jaxpr(lambda *a: sched.chunk_fn(*a))(
            sched.params, dl, st, ev, va, am))

    s_off = StreamScheduler(params, CFG, n_slots=3, chunk_len=6)
    s_on = StreamScheduler(params, CFG, n_slots=3, chunk_len=6,
                           tracer=Tracer())
    assert chunk_jaxpr(s_off) == chunk_jaxpr(s_on)


def test_tracing_8device_bit_identical(params):
    """Tracer on == tracer off on the 8-device slot-sharded pipelined
    grid, bit for bit (subprocess: XLA pins devices at init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.core.snn import SNNConfig, init_params
        from repro.launch.mesh import make_serving_mesh
        from repro.obs import Tracer
        from repro.serving import ReplaySource, StreamScheduler, StreamSession

        cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
        params = init_params(jax.random.PRNGKey(0), cfg)

        def events(seed, t, rate=0.3):
            r = np.random.default_rng(seed)
            return (r.random((t, cfg.n_in)) < rate).astype(np.float32)

        def drive(tracer):
            sched = StreamScheduler(params, cfg, n_slots=16, chunk_len=5,
                                    mesh=make_serving_mesh(),
                                    pipeline_depth=1, tracer=tracer)
            for sid in range(6):
                sched.submit(StreamSession(
                    sid=sid, source=ReplaySource(events(sid, 2 * cfg.t_steps)),
                    adapt=(sid % 2 == 0)))
            return sched, {s.sid: s for s in sched.run_until_drained()}

        tr = Tracer(capacity=65536)
        s0, d0 = drive(None)
        s1, d1 = drive(tr)
        assert s0.n_compiles == 1 and s1.n_compiles == 1
        for sid in d0:
            assert len(d0[sid].predictions) == len(d1[sid].predictions) == 2
            for a, b in zip(d0[sid].predictions, d1[sid].predictions):
                np.testing.assert_array_equal(a.logits, b.logits)
            np.testing.assert_array_equal(d0[sid].final_deltas,
                                          d1[sid].final_deltas)
        steps = s1.grid.stats["steps"]
        assert len(tr.spans("sched.retire")) == steps > 0
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr


# -------------------------------------------- per-grid-step attribution

def test_span_taxonomy_one_of_each_phase_per_grid_step(frozen_runs):
    """Every grid step owns exactly one stage, one dispatch, and one
    retire span — retires landing in a later ``step()`` (or at flush)
    included — with the owning step in the ``grid_step`` attr."""
    sched, _, _ = frozen_runs[1]
    tr = sched.tracer
    steps = sched.grid.stats["steps"]
    assert steps >= 4
    for name in ("sched.stage", "sched.dispatch", "sched.retire",
                 "sched.poll_sources", "sched.admit", "sched.device_wait"):
        got = sorted(s.attr("grid_step") for s in tr.spans(name))
        assert got == list(range(1, steps + 1)), (name, got)
    assert len(tr.spans("sched.step")) == steps
    # stage span nests poll_sources + admit under it
    by_id = {s.span_id: s for s in tr.spans()}
    for s in tr.spans("sched.poll_sources") + tr.spans("sched.admit"):
        assert by_id[s.parent_id].name == "sched.stage"
    for s in tr.spans("sched.device_wait"):
        assert by_id[s.parent_id].name == "sched.retire"


def test_retire_attributed_to_earlier_grid_step_under_pipelining(frozen_runs):
    """The attribution bugfix: under double buffering, the retire running
    inside ``step()`` for grid step ``t`` belongs to step ``t-1`` — its
    span must say so rather than inherit the enclosing step's number."""
    sched, _, _ = frozen_runs[1]
    tr = sched.tracer
    by_id = {s.span_id: s for s in tr.spans()}
    crossed = 0
    for s in tr.spans("sched.retire"):
        parent = by_id.get(s.parent_id)
        if parent is not None and parent.name == "sched.step":
            assert parent.attr("grid_step") == s.attr("grid_step") + 1
            crossed += 1
        else:
            assert parent is None       # flush-time retire: no step parent
    assert crossed >= 2, "pipeline never overlapped a retire with a step"
    # ...and the in-flight step's results were genuinely hidden behind
    # host work: the aggregate overlap ratio is a real signal, not 0
    tel = sched.telemetry
    assert 0.0 < tel.overlap_ratio() <= 1.0
    assert tel.rollup()["overlap_ratio"] == tel.overlap_ratio()


def test_phase_walls_reconcile_with_step_walls(frozen_runs):
    """stage+dispatch+retire wall sums account for (almost) all of the
    recorded step+flush wall — nothing double counted, nothing lost to
    the pipeline's reordering."""
    tel = frozen_runs[1][0].telemetry
    pp = tel.phase_percentiles()
    assert set(pp) >= {"stage", "dispatch", "retire"}
    phases = sum(pp[k]["total_s"] for k in ("stage", "dispatch", "retire"))
    walls = (tel.registry.get("serving_step_latency_seconds").sum
             + tel.registry.get("serving_flush_seconds_total").value)
    assert phases <= walls + 1e-6, (phases, walls)
    assert phases >= 0.7 * walls, (phases, walls)
    for k in ("stage", "dispatch", "retire"):
        assert pp[k]["p99_ms"] >= pp[k]["p50_ms"] > 0.0


def test_topology_epoch_spans(evolve_runs):
    sched, svc, _ = evolve_runs[1]
    spans = sched.tracer.spans("topology.epoch")
    assert len(spans) == svc.epoch_idx >= 2
    for s, e in zip(spans, svc.events):
        assert s.attr("grid_step") == e.grid_step
        assert s.attr("pruned") == e.pruned
        assert s.attr("regrown") == e.regrown
    assert sched.telemetry.rollup()["topology_epochs"] == svc.epoch_idx


def test_depth2_tracing_parity_and_spans(params, frozen_runs):
    """Deeper queues (frozen fleet): tracing still bit-identical, and
    per-phase spans still land exactly once per grid step."""
    deep = _drive(params, CFG, depth=2, tracer=Tracer(capacity=65536))
    assert deep[0].pipeline.depth == 2
    _assert_fleet_identical(frozen_runs[0], deep)
    steps = deep[0].grid.stats["steps"]
    for name in ("sched.stage", "sched.retire"):
        got = sorted(s.attr("grid_step")
                     for s in deep[0].tracer.spans(name))
        assert got == list(range(1, steps + 1)), (name, got)


# ------------------------------------------------- telemetry regressions

def test_fleet_telemetry_memory_is_bounded():
    """The ``step_latencies_s`` unbounded-list bug, pinned fixed: 20k
    recorded steps leave the telemetry O(buckets), and the percentile
    view stays within one bucket width of the exact values."""
    tel = FleetTelemetry()
    rng = np.random.default_rng(0)
    vals = np.exp(rng.normal(loc=np.log(2e-3), scale=0.8, size=20_000))
    for v in vals:
        tel.record_step(v)
    assert "step_latencies_s" not in vars(tel)
    assert not any(isinstance(v, list) and len(v) > 100
                   for v in vars(tel).values())
    hist = tel.registry.get("serving_step_latency_seconds").labels()
    assert len(hist.bucket_counts()) == len(LATENCY_BUCKETS_S) + 1
    assert hist.count == 20_000 and tel.steps == 20_000
    lp = tel.latency_percentiles()
    for key, q in (("p50_ms", 50), ("p99_ms", 99)):
        exact = float(np.percentile(vals, q)) * 1e3
        assert abs(lp[key] - exact) / exact < 0.12, (key, lp[key], exact)


def test_topology_epoch_log_bounded_rollup_exact():
    """Regression for the lint-surfaced OBS01 finding: the per-epoch event
    *log* is a bounded recent-events ring, while the rollup reads the
    registry counters — so its totals stay exact past the ring's horizon."""
    tel = FleetTelemetry(max_epoch_events=32)
    n = 500
    for i in range(n):
        tel.record_topology_epoch(grid_step=i, pruned=2, regrown=1,
                                  mask_change=0.01 * (i % 7),
                                  merged_streams=i % 2)
    assert len(tel.topology_epochs) == 32                       # bounded
    assert tel.topology_epochs[-1]["grid_step"] == n - 1        # most recent
    r = tel.topology_rollup()
    assert r["topology_epochs"] == n                            # exact
    assert r["topology_pruned"] == 2 * n
    assert r["topology_regrown"] == n
    assert r["streams_merged"] == sum(i % 2 for i in range(n))
    exact_mean = sum(0.01 * (i % 7) for i in range(n)) / n
    assert r["topology_mask_change_mean"] == pytest.approx(exact_mean)


def test_fleet_telemetry_thread_safe_mutation():
    """Regression for the lint-surfaced OBS02 finding: concurrent sources
    racing on stream() creation and epoch recording lose nothing — one
    counter record per sid, exact epoch totals."""
    import threading

    tel = FleetTelemetry()
    n_threads, per_thread, sids = 8, 50, range(6)
    seen = [[] for _ in range(n_threads)]
    start = threading.Barrier(n_threads)

    def worker(t):
        start.wait()
        for i in range(per_thread):
            seen[t].append(tel.stream(sids[i % len(sids)]))
            tel.record_topology_epoch(grid_step=i, pruned=1, regrown=1,
                                      mask_change=0.0, merged_streams=0)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    assert sorted(tel.streams) == list(sids)
    for t in range(n_threads):                  # every thread saw THE record
        for i, rec in enumerate(seen[t]):
            assert rec is tel.streams[sids[i % len(sids)]]
    assert tel.topology_rollup()["topology_epochs"] == n_threads * per_thread


def test_overlap_ratio_accounting():
    tel = FleetTelemetry()
    assert tel.overlap_ratio() == 0.0            # nothing recorded
    assert tel.record_overlap(0.0, 0.01) == 0.0  # serial step: nothing hidden
    assert tel.record_overlap(0.02, 0.01) == pytest.approx(2 / 3)
    assert tel.record_overlap(0.01, 0.0) == 1.0  # fully hidden
    assert tel.overlap_ratio() == pytest.approx(0.03 / 0.05)
    assert tel.registry.get("serving_overlap_ratio").count == 3


def test_prometheus_scrape_of_live_run(frozen_runs):
    """A text scrape of a real run carries the required metric families
    with values that agree with the scheduler's own bookkeeping."""
    sched = frozen_runs[1][0]
    parsed = parse_prometheus_text(prometheus_text(sched.telemetry.registry))
    assert parsed["serving_grid_steps_total"] == sched.grid.stats["steps"]
    assert parsed["serving_step_latency_seconds_count"] == \
        sched.grid.stats["steps"]
    for required in ("serving_overlap_ratio_count",
                     "serving_device_wait_seconds_total",
                     'serving_phase_seconds_count{phase="retire"}',
                     'serving_stream_timesteps_total{sid="0"}',
                     'serving_stream_windows_total{sid="4"}'):
        assert required in parsed, required
    # per-stream counters in the scrape == the in-process view
    c0 = sched.telemetry.stream(0)
    assert parsed['serving_stream_timesteps_total{sid="0"}'] == c0.timesteps


# ------------------------------------------------------- overhead guard

def test_tracing_overhead_guard(params):
    """Tracing must stay out of the hot path's way: best-of-5 drained-
    fleet walls with a live tracer within 25% of untraced (the quick
    serving bench pins the tighter <5%-events/s budget; this guard keeps
    gross regressions — a sync, a per-step allocation storm — out)."""
    def build(tracer):
        sched = StreamScheduler(params, CFG, n_slots=4, chunk_len=6,
                                pipeline_depth=1, tracer=tracer)
        sched.submit(StreamSession(                      # warmup: compile
            sid=999, source=ReplaySource(_events(99, CFG.t_steps))))
        sched.run_until_drained()
        return sched

    def wave(sched, base_sid):
        for k in range(6):
            sched.submit(StreamSession(
                sid=base_sid + k,
                source=ReplaySource(_events(k, 2 * CFG.t_steps), chunk_len=7),
                adapt=(k % 2 == 0)))
        t0 = time.perf_counter()
        sched.run_until_drained()
        return time.perf_counter() - t0

    off, on = build(None), build(Tracer(capacity=65536))
    walls_off, walls_on = [], []
    for rep in range(5):                   # interleaved: fair to both
        walls_off.append(wave(off, 1000 + 100 * rep))
        walls_on.append(wave(on, 5000 + 100 * rep))
    assert min(walls_on) <= min(walls_off) * 1.25, (walls_on, walls_off)
    assert on.tracer.n_recorded > 0


# ------------------------------------------------- continuous batcher

def test_batcher_spans_and_parity():
    import repro.configs as C
    from repro.launch.batching import ContinuousBatcher, Request
    from repro.models import transformer as T
    cfg = C.get_reduced("phi3_medium_14b")
    p = T.init_params(jax.random.PRNGKey(0), cfg)

    def drive(tracer):
        b = ContinuousBatcher(p, cfg, n_slots=2, max_seq=32, tracer=tracer)
        b.submit(Request(rid=0, prompt=[1, 2, 3], max_new=3))
        return b, b.run_until_drained()

    tr = Tracer()
    b_on, done_on = drive(tr)
    _, done_off = drive(None)
    assert done_on[0].out == done_off[0].out         # tracing-free behavior
    steps = b_on.grid.stats["steps"]
    admits, decodes = tr.spans("batch.admit"), tr.spans("batch.decode_step")
    assert len(admits) == len(decodes) == steps >= 4
    # the first step replays prompt (prefill), later steps decode
    assert decodes[0].attr("prefill_slots") == 1
    assert decodes[0].attr("decode_slots") == 0
    assert decodes[-1].attr("decode_slots") == 1
    assert [d.attr("grid_step") for d in decodes] == \
        list(range(1, steps + 1))
