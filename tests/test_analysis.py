"""Unit tests for the jaxpr contract analyzer (repro.analysis).

Each contract must statically catch its planted violation on a toy
function — a psum under shard_map, a slot-axis reduction, a dense-mask
constvar, a factor carry in a scan, an f64 promotion, a retracing
entrypoint — and pass on the clean twin. The registry test then runs every
real entrypoint's contract set end to end (the CI static-analysis suite).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import analysis

S = 4     # toy slot count — distinct from every other extent used below


class _Cfg:
    """Duck-typed stand-in for SNNConfig (what the contract factories
    actually read)."""
    n_layers = 2
    n_hidden = 8
    layer_fanins = (16, 8)     # k_max = 16 != n_hidden


# ------------------------------------------------------- no_collectives

def _slot_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("slots",))


def test_no_collectives_catches_planted_psum():
    mesh = _slot_mesh()

    def planted(x):
        def body(x):
            return jax.lax.psum(x, "slots")
        return shard_map(body, mesh=mesh, in_specs=P("slots"),
                         out_specs=P())(x)

    r = analysis.check(planted, (jnp.zeros((S, 3)),),
                       [analysis.no_collectives()])
    assert not r.ok
    assert any("psum" in v.message and "shard_map" in v.message
               for v in r.violations)
    with pytest.raises(analysis.ContractViolationError, match="psum"):
        r.raise_if_violations()


def test_no_collectives_passes_clean_shard_map():
    mesh = _slot_mesh()

    def clean(x):
        def body(x):
            return x * 2.0
        return shard_map(body, mesh=mesh, in_specs=P("slots"),
                         out_specs=P("slots"))(x)

    analysis.check(clean, (jnp.zeros((S, 3)),),
                   [analysis.no_collectives()]).raise_if_violations()


def test_no_collectives_axis_filter():
    mesh = _slot_mesh()

    def planted(x):
        def body(x):
            return jax.lax.psum(x, "slots")
        return shard_map(body, mesh=mesh, in_specs=P("slots"),
                         out_specs=P())(x)

    args = (jnp.zeros((S, 3)),)
    assert not analysis.check(planted, args,
                              [analysis.no_collectives(axis="slots")]).ok
    # a collective over a *different* named axis is out of scope
    assert analysis.check(planted, args,
                          [analysis.no_collectives(axis="model")]).ok


# ------------------------------------------------------- slot_separable

def test_slot_separable_catches_planted_slot_sum():
    def planted(x):                      # x: [S, N]
        return {"kept": x * 2.0, "mean": x.sum(0)}

    r = analysis.check(planted, (jnp.zeros((S, 8)),),
                       [analysis.slot_separable(S)])
    assert not r.ok
    assert len(r.violations) == 1
    assert "mean" in r.violations[0].message
    assert "lost the slot axis" in r.violations[0].message


def test_slot_separable_exempt_and_second_dim():
    def fn(x):                           # slot axis allowed at dim 0 or 1
        return {"a": x, "b": jnp.moveaxis(x, 0, 1), "mean": x.sum(0)}

    args = (jnp.zeros((S, 8)),)
    assert not analysis.check(fn, args, [analysis.slot_separable(S)]).ok
    analysis.check(
        fn, args,
        [analysis.slot_separable(S, exempt=("mean",))]).raise_if_violations()


# ----------------------------------------------- mask_free / dense leaves

def test_mask_free_catches_planted_dense_mask_constvar():
    cfg = _Cfg()
    k_max = max(cfg.layer_fanins)
    mask = np.ones((cfg.n_layers, k_max, cfg.n_hidden), np.float32)

    def planted(x):
        return (jnp.asarray(mask) * x).sum()

    r = analysis.check(planted, (jnp.zeros(()),), [analysis.mask_free(cfg)])
    assert not r.ok
    assert any("dense layout" in v.message for v in r.violations)

    def clean(x):
        return x * 2.0

    analysis.check(clean, (jnp.zeros(()),),
                   [analysis.mask_free(cfg)]).raise_if_violations()


def test_no_dense_deltas_catches_both_layouts():
    cfg = _Cfg()
    k_max = max(cfg.layer_fanins)
    contracts = [analysis.no_dense_deltas(cfg, S)]

    def slot_leading(x):
        return x + jnp.zeros((S, cfg.n_layers, k_max, cfg.n_hidden))

    def layer_leading(x):
        return x + jnp.zeros((cfg.n_layers, S, k_max, cfg.n_hidden))

    assert not analysis.check(slot_leading, (jnp.zeros(()),), contracts).ok
    assert not analysis.check(layer_leading, (jnp.zeros(()),), contracts).ok


# ------------------------------------------------------ no_factor_carries

def _scan_with_carries(n_lsn, n_lsk, cfg, C):
    """A toy chunk scan carrying ``n_lsn`` [L,S,N] and ``n_lsk`` [L,S,Kmax]
    f32 arrays."""
    L, N, k_max = cfg.n_layers, cfg.n_hidden, max(cfg.layer_fanins)

    def fn(xs):
        def body(c, x):
            return tuple(a + x for a in c), x
        c0 = (tuple(jnp.zeros((L, S, N)) for _ in range(n_lsn))
              + tuple(jnp.zeros((L, S, k_max)) for _ in range(n_lsk)))
        return jax.lax.scan(body, c0, xs)
    return fn


def test_no_factor_carries_catches_planted_accumulators():
    cfg, C = _Cfg(), 5
    contracts = [analysis.no_factor_carries(cfg, S, chunk_len=C)]
    args = (jnp.zeros((C, 1, 1, 1)),)

    # 4 [L,S,N] carries = the LayerState leaves — allowed
    analysis.check(_scan_with_carries(4, 0, cfg, C), args,
                   contracts).raise_if_violations()
    # a 5th [L,S,N] (the post_mag accumulator) — caught
    assert not analysis.check(_scan_with_carries(5, 0, cfg, C), args,
                              contracts).ok
    # any [L,S,Kmax] (the pre_mag accumulator; k_max != N here) — caught
    assert not analysis.check(_scan_with_carries(0, 1, cfg, C), args,
                              contracts).ok


def test_no_factor_carries_chunk_len_scoping():
    cfg, C = _Cfg(), 5
    # a scan of a DIFFERENT length may carry what it likes
    r = analysis.check(
        _scan_with_carries(5, 1, cfg, C), (jnp.zeros((C, 1, 1, 1)),),
        [analysis.no_factor_carries(cfg, S, chunk_len=C + 1)])
    assert r.ok


# ------------------------------------------------------ dtype_discipline

def test_dtype_discipline_catches_f64():
    def planted(x):
        return x.astype(jnp.float64) + np.float64(1.0)

    with jax.experimental.enable_x64():
        r = analysis.check(planted, (jnp.zeros((3,), jnp.float32),),
                           [analysis.dtype_discipline()])
    assert not r.ok
    assert any("float64" in v.message for v in r.violations)

    def clean(x):
        return x + 1.0

    analysis.check(clean, (jnp.zeros((3,), jnp.float32),),
                   [analysis.dtype_discipline()]).raise_if_violations()


# -------------------------------------------------------- compile_count

def _counted_fn(retrace_every_call):
    traces = {"n": 0}

    def body(x):
        traces["n"] += 1
        return x + 1.0
    stable = jax.jit(body)

    def fn(x):
        if retrace_every_call:
            # a fresh closure per call defeats jit's cache → retrace
            def fresh(y):
                traces["n"] += 1
                return y + 1.0
            return jax.jit(fresh)(x)
        return stable(x)
    fn.n_traces = lambda: traces["n"]
    return fn


def test_compile_count_passes_stable_entrypoint():
    analysis.check(_counted_fn(False), (jnp.zeros((2,)),),
                   [analysis.compile_count()]).raise_if_violations()


def test_compile_count_catches_retracing():
    r = analysis.check(_counted_fn(True), (jnp.zeros((2,)),),
                       [analysis.compile_count()])
    assert not r.ok
    assert "retracing" in r.violations[0].message


def test_compile_count_requires_trace_counter():
    r = analysis.check(lambda x: x, (jnp.zeros((2,)),),
                       [analysis.compile_count()])
    assert not r.ok and "n_traces" in r.violations[0].message


# --------------------------------------------- the shared trace-time assert

def _fake_chunk_trees(C, S, L, N, want_factors, break_leaf=None):
    layers = {"v": jnp.zeros((L, S, N)), "tr": jnp.zeros((L, S, N))}
    x_tr = jnp.zeros((S, 6))
    ss_mean = jnp.zeros((L, S))
    t_w = jnp.zeros((S,))
    samp = jnp.zeros((S,))
    dls = jnp.zeros((L, S, 3, N))
    acc = ((jnp.zeros((L, S, 5)), jnp.zeros((L, S, N)))
           if want_factors else ())
    outs = {"spk": jnp.zeros((C, S, N))}
    if break_leaf == "out":
        outs["spk"] = jnp.zeros((C, N))          # slot axis reduced away
    if break_leaf == "carry":
        ss_mean = jnp.zeros((L,))
    return (layers, x_tr, ss_mean, t_w, samp, dls, *acc), outs


@pytest.mark.parametrize("want_factors", [False, True])
def test_chunk_carry_assert_accepts_separable_trees(want_factors):
    C, L, N = 6, 2, 8
    carry, outs = _fake_chunk_trees(C, S, L, N, want_factors)
    analysis.assert_chunk_carry_slot_separable(
        carry, outs, C=C, S=S, n_layers=L, want_factors=want_factors)


@pytest.mark.parametrize("break_leaf", ["out", "carry"])
def test_chunk_carry_assert_catches_dropped_slot_axis(break_leaf):
    C, L, N = 6, 2, 8
    carry, outs = _fake_chunk_trees(C, S, L, N, True, break_leaf=break_leaf)
    with pytest.raises(AssertionError):
        analysis.assert_chunk_carry_slot_separable(
            carry, outs, C=C, S=S, n_layers=L, want_factors=True)


def test_engine_assert_is_the_shared_one():
    """Satellite: engine._assert_slot_separable wraps the analyzer —
    same AssertionError, same shape-bearing message."""
    from repro.core import engine, snn

    cfg = snn.SNNConfig(n_in=16, n_hidden=8, n_layers=2, n_out=4, t_steps=4)
    C, L, N = 6, 2, cfg.n_hidden
    carry, outs = _fake_chunk_trees(C, S, L, N, False, break_leaf="out")
    with pytest.raises(AssertionError) as ei:
        engine._assert_slot_separable(carry, outs, C, S, cfg, False)
    assert str((C, N)) in str(ei.value)          # the offending shape


# --------------------------------------------------------- report / walkers

def test_report_formatting_and_walkers():
    def fn(xs):
        def body(c, x):
            return c + x, c
        return jax.lax.scan(body, jnp.zeros(()), xs)

    r = analysis.check(fn, (jnp.zeros((3,)),), [analysis.no_collectives()],
                       name="toy.scan")
    assert r.ok and "toy.scan" in str(r) and "OK" in str(r)

    closed = jax.make_jaxpr(fn)(jnp.zeros((3,)))
    names = [e.primitive.name for e, _ in analysis.iter_eqns(closed)]
    assert "scan" in names
    roles = {role for _, role in analysis.all_avals(closed)}
    assert "input" in roles and "eqn-out" in roles


# ------------------------------------------------------------ the registry

def test_registry_every_entrypoint_passes():
    """Acceptance: every registered real entrypoint (compact and dense
    layouts, sharded and unsharded, factors on and off) passes its
    contract set on a small config."""
    from repro.analysis import registry

    reports = registry.check_all()
    assert set(reports) == set(registry.names())
    assert {"serving.chunk_fn[compact,factors]",
            "serving.chunk_fn[compact,frozen]", "serving.chunk_fn[dense]",
            "serving.chunk_fn[sharded]", "snn.run_chunk[compact]",
            "snn.run_chunk[dense]", "launch.decode_step"} <= set(reports)
    for name, r in reports.items():
        assert r.ok, f"{name}:\n{r}"

    s = registry.summary(reports)
    assert s["ok"] and s["violations"] == 0
    assert s["contracts"] >= 20
    assert s["entrypoints"] == sorted(reports)
