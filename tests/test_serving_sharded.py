"""Sharded slot grid: multi-device parity for the serving chunk step.

The slot axis is the shardable axis by construction (every per-stream
quantity is slot-leading; the chunk step never reduces over slots —
asserted in core/engine.scan_chunk). These tests pin the consequence: the
same chunk step on a 1-device grid and under slot-axis ``shard_map`` on an
8-device host mesh is **bit-identical** — deltas, every StreamState leaf,
and all metrics — and the scheduler still compiles exactly once.

Device count must be pinned before jax initializes, so the 8-device cases
run in a subprocess with XLA_FLAGS set (conftest keeps the main process at
1 device); helper-level rules are tested in-process on a 1-device mesh.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# ------------------------------------------------------------ in-process

def test_slot_axis_rules_single_device_mesh():
    from repro.core.snn import SNNConfig, init_stream_state
    from repro.launch import sharding as SH
    from repro.launch.mesh import make_serving_mesh

    mesh = make_serving_mesh(1)
    assert SH.slot_devices(mesh) == 1
    assert SH.round_up_slots(5, mesh) == 5
    st = init_stream_state(SNNConfig(n_in=8, n_hidden=8, n_out=4), 4)
    shs = SH.stream_shardings(st, mesh)
    for sh in jax.tree_util.tree_leaves(shs):
        assert sh.spec == SH.slot_spec(0), sh.spec
    in_specs, out_specs = SH.chunk_step_specs()
    assert in_specs[0] == jax.sharding.PartitionSpec()      # params replicate
    assert out_specs[2].logits == SH.slot_spec(1)           # [C, S, n_out]


def test_round_up_and_divisibility():
    from repro.launch import sharding as SH
    from repro.launch.mesh import make_serving_mesh

    mesh = make_serving_mesh(1)
    assert SH.round_up_slots(1, mesh) == 1
    SH.check_slot_divisible(3, mesh)    # 1 device divides anything
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_serving_mesh(4096)


def test_mesh_scheduler_pads_slot_grid_single_device():
    """Device-count-aware allocation: on a 1-device mesh the grid is only
    padded up to the 2-slots-per-device bit-identity floor."""
    from repro.core.snn import SNNConfig, init_params
    from repro.launch.mesh import make_serving_mesh
    from repro.serving import StreamScheduler

    cfg = SNNConfig(n_in=8, n_hidden=8, n_layers=1, n_out=4, t_steps=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sched = StreamScheduler(params, cfg, n_slots=1, mesh=make_serving_mesh(1))
    assert sched.n_slots == 2
    sched = StreamScheduler(params, cfg, n_slots=3, mesh=make_serving_mesh(1))
    assert sched.n_slots == 3


# ------------------------------------------------------------ 8 devices

def test_sharded_chunk_step_bit_identical_and_compiles_once():
    """3 carried chunk steps, ragged valid, mixed adapt mask, decay+clip on:
    1-device vs 8-device shard_map paths agree bit-for-bit everywhere."""
    print(_run("""
        import numpy as np, jax
        from repro.core.snn import (SNNConfig, init_params, init_stream_state,
                                    init_stream_deltas)
        from repro.launch import sharding as SH
        from repro.launch.mesh import make_serving_mesh
        from repro.serving.adapt import AdaptConfig, make_chunk_fn

        cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
        params = init_params(jax.random.PRNGKey(0), cfg)
        mesh = make_serving_mesh()
        assert SH.slot_devices(mesh) == 8
        S, C = 16, 6
        rng = np.random.default_rng(0)
        adapt = AdaptConfig(delta_decay=0.95, delta_clip=0.3)
        fn1 = make_chunk_fn(cfg, adapt)
        fn8 = make_chunk_fn(cfg, adapt, mesh=mesh)
        st1, dl1 = init_stream_state(cfg, S), init_stream_deltas(cfg, S)
        st8 = jax.device_put(st1, SH.stream_shardings(st1, mesh))
        dl8 = jax.device_put(dl1, SH.slot_sharding(mesh))
        for i in range(3):
            events = (rng.random((C, S, cfg.n_in)) < 0.3).astype(np.float32)
            valid = rng.random((C, S)) < 0.8
            amask = rng.random(S) < 0.7
            dl1, st1, m1 = fn1(params, dl1, st1, events, valid, amask)
            dl8, st8, m8 = fn8(params, dl8, st8, events, valid, amask)
        assert dl8.sharding.spec == SH.slot_spec(0), dl8.sharding
        np.testing.assert_array_equal(np.asarray(dl1), np.asarray(dl8))
        for a, b in zip(jax.tree_util.tree_leaves(st1),
                        jax.tree_util.tree_leaves(st8)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for name, a, b in zip(m1._fields, m1, m8):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        assert fn1.n_traces() == 1 and fn8.n_traces() == 1, \\
            (fn1.n_traces(), fn8.n_traces())
        print("OK")
    """))


def test_sharded_scheduler_end_to_end_parity():
    """Full lifecycle on the mesh — admits, lane surgery on sharded arrays,
    retires — produces the same predictions/deltas as the 1-device grid,
    pads n_slots to the device count, and compiles exactly once."""
    print(_run("""
        import numpy as np, jax
        from repro.core.snn import SNNConfig, init_params
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import ReplaySource, StreamScheduler, StreamSession

        cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
        params = init_params(jax.random.PRNGKey(0), cfg)

        def events(seed, t, rate=0.3):
            r = np.random.default_rng(seed)
            return (r.random((t, cfg.n_in)) < rate).astype(np.float32)

        def drive(mesh, n_slots):
            sched = StreamScheduler(params, cfg, n_slots=n_slots,
                                    chunk_len=5, mesh=mesh)
            for sid in range(6):
                sched.submit(StreamSession(
                    sid=sid, source=ReplaySource(events(sid, 2 * cfg.t_steps)),
                    adapt=(sid % 2 == 0)))
            done = {s.sid: s for s in sched.run_until_drained()}
            return sched, done

        s1, d1 = drive(None, 16)
        s8, d8 = drive(make_serving_mesh(), 6)   # pads to 16 (2 per device)
        assert s8.n_slots == 16, s8.n_slots
        assert s1.n_compiles == 1 and s8.n_compiles == 1, \\
            (s1.n_compiles, s8.n_compiles)
        for sid in d1:
            assert len(d1[sid].predictions) == len(d8[sid].predictions) == 2
            for a, b in zip(d1[sid].predictions, d8[sid].predictions):
                np.testing.assert_array_equal(a.logits, b.logits)
            np.testing.assert_array_equal(d1[sid].final_deltas,
                                          d8[sid].final_deltas)
        print("OK")
    """))


def test_sharded_topology_evolution_parity():
    """Live DSST epochs on the 8-device slot-sharded grid: bit-identical to
    the 1-device fleet (evolved base, deltas, predictions, epoch history),
    the swap preserves the slot sharding, and the chunk step compiles
    exactly once on both paths — the zero-recompile topology-swap
    guarantee under shard_map."""
    print(_run("""
        import numpy as np, jax
        from repro.core.dsst import DSSTConfig
        from repro.core.snn import SNNConfig, init_params
        from repro.core import topology
        from repro.launch import sharding as SH
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import (ReplaySource, StreamScheduler,
                                   StreamSession, TopologyService,
                                   TopologyServiceConfig)

        cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8,
                        t_steps=12, dsst=DSSTConfig(period=4, prune_frac=0.5))
        params = init_params(jax.random.PRNGKey(0), cfg)

        def events(seed, t, rate=0.3):
            r = np.random.default_rng(seed)
            return (r.random((t, cfg.n_in)) < rate).astype(np.float32)

        def drive(mesh):
            svc = TopologyService(cfg, TopologyServiceConfig(
                epoch_every=3, merge_top=1))
            sched = StreamScheduler(params, cfg, n_slots=16, chunk_len=6,
                                    mesh=mesh, topology=svc)
            for sid in range(6):
                sched.submit(StreamSession(
                    sid=sid, source=ReplaySource(events(sid, 54),
                                                 chunk_len=6),
                    adapt=(sid % 2 == 0)))
            done = {s.sid: s for s in sched.run_until_drained()}
            return sched, svc, done

        s1, v1, d1 = drive(None)
        s8, v8, d8 = drive(make_serving_mesh())
        assert v1.epoch_idx >= 2 and v8.epoch_idx == v1.epoch_idx, \\
            (v1.epoch_idx, v8.epoch_idx)
        assert sum(e.pruned for e in v1.events) > 0
        assert s1.n_compiles == 1 and s8.n_compiles == 1, \\
            (s1.n_compiles, s8.n_compiles)
        # epoch-for-epoch identical evolution
        assert [(e.pruned, e.regrown, e.merged_slots) for e in v1.events] \\
            == [(e.pruned, e.regrown, e.merged_slots) for e in v8.events]
        # the swap preserved the slot sharding of the delta grid
        assert s8.deltas.sharding.spec == SH.slot_spec(0), s8.deltas.sharding
        # evolved base + deltas bit-identical across paths, invariant holds
        assert topology.check(s8.params["hidden"]["mask"], cfg)
        for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                        jax.tree_util.tree_leaves(s8.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(s1.deltas),
                                      np.asarray(s8.deltas))
        for sid in d1:
            assert len(d1[sid].predictions) == len(d8[sid].predictions) > 0
            for a, b in zip(d1[sid].predictions, d8[sid].predictions):
                np.testing.assert_array_equal(a.logits, b.logits)
        print("OK")
    """))
