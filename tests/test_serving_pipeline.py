"""Pipelined serving hot path: staging overlap + compiled-out DSST factors.

Two acceptance properties of the hot-path tentpole:

* **Pipelining changes *when* host work happens, never *what* the device
  computes**: with double-buffered staging (``pipeline_depth=1``) every
  per-stream trajectory — window predictions, final deltas, telemetry
  counters, and (for evolving fleets) the whole topology epoch history —
  is BIT-identical to the serial scheduler, on one device and on an
  8-device slot-sharded mesh, and the chunk step still compiles once.
* **``want_factors=False`` really compiles the DSST factor machinery
  out**: the chunk metrics carry no factor leaves, the chunk scan's carry
  holds no factor accumulator (asserted on the jaxpr), and the stream
  dynamics are bit-identical either way.

Plus the primitive underneath the cheap evolving-fleet path:
``engine.ordered_slot_sum``'s reduction tree is a function of S alone, so
the device-side factor reduction is sharding-independent.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.dsst import DSSTConfig
from repro.core.snn import (SNNConfig, init_params, init_stream_deltas,
                            init_stream_state, run_chunk)
from repro.serving import (AdaptConfig, ReplaySource, StagingPipeline,
                           StreamScheduler, StreamSession, TopologyService,
                           TopologyServiceConfig, make_chunk_fn)

CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
EVOLVE_CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=12,
                       dsst=DSSTConfig(period=4, prune_frac=0.5))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _events(seed, t, rate=0.3):
    rng = np.random.default_rng(seed)
    return (rng.random((t, CFG.n_in)) < rate).astype(np.float32)


def _drive(params, cfg, depth, n_streams=5, n_slots=3, chunk_len=6,
           topology_every=0):
    svc = None
    if topology_every:
        svc = TopologyService(cfg, TopologyServiceConfig(
            epoch_every=topology_every, merge_top=1))
    sched = StreamScheduler(params, cfg, n_slots=n_slots, chunk_len=chunk_len,
                            topology=svc, pipeline_depth=depth)
    for sid in range(n_streams):
        sched.submit(StreamSession(
            sid=sid,
            source=ReplaySource(_events(sid, (3 + sid % 2) * cfg.t_steps,
                                        rate=0.25 + 0.03 * sid),
                                chunk_len=7),
            adapt=(sid % 2 == 0)))
    done = {s.sid: s for s in sched.run_until_drained()}
    return sched, svc, done


def _assert_fleet_identical(a, b):
    """(sched, svc, done) pairs: bit-identical per-stream outcomes."""
    sa, va, da = a
    sb, vb, db = b
    assert set(da) == set(db)
    for sid in da:
        pa, pb = da[sid].predictions, db[sid].predictions
        assert len(pa) == len(pb) > 0, (sid, len(pa), len(pb))
        for x, y in zip(pa, pb):
            np.testing.assert_array_equal(x.logits, y.logits)
        np.testing.assert_array_equal(da[sid].final_deltas,
                                      db[sid].final_deltas)
        ca, cb = sa.telemetry.stream(sid), sb.telemetry.stream(sid)
        for f in ("timesteps", "events_in", "sop_forward", "sop_wu",
                  "sop_wu_offered", "gate_opened", "gate_offered",
                  "windows", "local_loss"):
            assert getattr(ca, f) == getattr(cb, f), (sid, f)
    for x, y in zip(jax.tree_util.tree_leaves(sa.params),
                    jax.tree_util.tree_leaves(sb.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(sa.deltas), np.asarray(sb.deltas))


# --------------------------------------------------------- pipeline parity

def test_pipeline_on_off_bit_exact(params):
    """Double-buffered staging == serial phases, bit for bit: predictions,
    final deltas, per-stream counters — with oversubscription (5 streams on
    3 slots) so admit/retire lane recycling crosses the pipeline boundary.
    One compile each (the pipeline adds no shapes)."""
    serial = _drive(params, CFG, depth=0)
    piped = _drive(params, CFG, depth=1)
    assert serial[0].n_compiles == 1 and piped[0].n_compiles == 1
    _assert_fleet_identical(serial, piped)
    # pipeline actually drained: nothing left in flight after run
    assert piped[0].drained and len(piped[0].pipeline) == 0


def test_pipeline_parity_with_live_topology_epochs(params):
    """Evolving fleet: epochs land between the same grid steps, fold the
    same hot lanes, and produce the same evolved (params, deltas) under the
    pipeline as serially — the epoch-vs-dispatch ordering contract."""
    p = init_params(jax.random.PRNGKey(1), EVOLVE_CFG)
    serial = _drive(p, EVOLVE_CFG, depth=0, n_slots=4, topology_every=3)
    piped = _drive(p, EVOLVE_CFG, depth=1, n_slots=4, topology_every=3)
    va, vb = serial[1], piped[1]
    assert va.epoch_idx >= 2, "workload too short: no epochs ran"
    assert va.epoch_idx == vb.epoch_idx
    assert [(e.grid_step, e.pruned, e.regrown, e.merged_slots)
            for e in va.events] == \
           [(e.grid_step, e.pruned, e.regrown, e.merged_slots)
            for e in vb.events]
    _assert_fleet_identical(serial, piped)
    # a live topology service clamps deeper queues back to depth 1
    deep = StreamScheduler(p, EVOLVE_CFG, n_slots=4, pipeline_depth=3,
                           topology=TopologyService(EVOLVE_CFG))
    assert deep.pipeline.depth == 1


def test_pipeline_depth_two_frozen_fleet_parity(params):
    """Without a topology service deeper queues are allowed and still
    bit-identical — bookkeeping just lands later."""
    serial = _drive(params, CFG, depth=0)
    deep = _drive(params, CFG, depth=2)
    assert deep[0].pipeline.depth == 2
    _assert_fleet_identical(serial, deep)


def test_pipeline_8device_sharded_parity(params):
    """Pipelined + slot-sharded over 8 devices == serial 1-device grid,
    bit for bit, one compile each (subprocess: XLA pins devices at init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.core.snn import SNNConfig, init_params
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import ReplaySource, StreamScheduler, StreamSession

        cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)
        params = init_params(jax.random.PRNGKey(0), cfg)

        def events(seed, t, rate=0.3):
            r = np.random.default_rng(seed)
            return (r.random((t, cfg.n_in)) < rate).astype(np.float32)

        def drive(mesh, depth):
            sched = StreamScheduler(params, cfg, n_slots=16, chunk_len=5,
                                    mesh=mesh, pipeline_depth=depth)
            for sid in range(6):
                sched.submit(StreamSession(
                    sid=sid, source=ReplaySource(events(sid, 2 * cfg.t_steps)),
                    adapt=(sid % 2 == 0)))
            return sched, {s.sid: s for s in sched.run_until_drained()}

        s1, d1 = drive(None, 0)
        s8, d8 = drive(make_serving_mesh(), 1)
        assert s1.n_compiles == 1 and s8.n_compiles == 1
        for sid in d1:
            assert len(d1[sid].predictions) == len(d8[sid].predictions) == 2
            for a, b in zip(d1[sid].predictions, d8[sid].predictions):
                np.testing.assert_array_equal(a.logits, b.logits)
            np.testing.assert_array_equal(d1[sid].final_deltas,
                                          d8[sid].final_deltas)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr


def test_staging_pipeline_bounds():
    pl = StagingPipeline(depth=0)
    with pytest.raises(RuntimeError, match="synchronous"):
        pl.push(object())
    with pytest.raises(ValueError, match="depth"):
        StagingPipeline(depth=-1)
    pl = StagingPipeline(depth=1)
    assert not pl.full and len(pl) == 0
    pl.push("a")
    assert pl.full
    with pytest.raises(RuntimeError, match="full"):
        pl.push("b")
    assert pl.pop() == "a" and len(pl) == 0


# ----------------------------------------------------- factor compile-out

def test_want_factors_off_metrics_and_dynamics(params):
    """want_factors=False: metrics carry no factor leaves; deltas/state are
    bit-identical to the factor-bearing step (the factors are telemetry,
    never dynamics)."""
    st = init_stream_state(CFG, 2)
    dl = init_stream_deltas(CFG, 2)
    ev = _events(40, 10)[:, None, :].repeat(2, 1)
    va = np.ones((10, 2), bool)
    amask = np.ones(2, bool)
    fn_on = make_chunk_fn(CFG, AdaptConfig(), want_factors=True)
    fn_off = make_chunk_fn(CFG, AdaptConfig(), want_factors=False)
    d1, s1, m1 = fn_on(params, dl, st, ev, va, amask)
    d0, s0, m0 = fn_off(params, dl, st, ev, va, amask)
    assert m0.pre_mag is None and m0.post_mag is None
    assert m1.pre_mag.shape == (CFG.n_layers, CFG.n_in)       # slot-reduced
    assert m1.post_mag.shape == (CFG.n_layers, CFG.n_hidden)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d0))
    for a, b in zip(jax.tree_util.tree_leaves(s1),
                    jax.tree_util.tree_leaves(s0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert fn_off.want_factors is False and fn_on.want_factors is True


def test_want_factors_false_compiles_accumulators_out_of_scan():
    """The acceptance assert: with want_factors=False the chunk scan's
    jaxpr contains NO factor accumulator in its carry — not a zeroed one,
    none. Since the static-analysis PR the scan-carry walk lives in the
    shared ``no_factor_carries`` contract; the with-factors trace doubles
    as its planted positive (both the unique [L, S, Kmax] pre accumulator
    and the extra [L, S, N] post accumulator must be called out)."""
    from repro import analysis

    cfg = SNNConfig(n_in=48, n_hidden=16, n_layers=2, n_out=4, t_steps=8)
    C, S = 5, 3
    params = init_params(jax.random.PRNGKey(2), cfg)
    st = init_stream_state(cfg, S)
    dl = init_stream_deltas(cfg, S)
    ev = jnp.zeros((C, S, cfg.n_in))
    va = jnp.ones((C, S), bool)

    def fn(want_factors):
        def f(p, d, s, e, v):
            return run_chunk(p, d, s, e, v, cfg, want_factors=want_factors)
        return f

    contracts = [analysis.no_factor_carries(cfg, S, chunk_len=C)]
    args = (params, dl, st, ev, va)
    analysis.check(fn(False), args, contracts).raise_if_violations()

    on = analysis.check(fn(True), args, contracts)
    assert not on.ok
    msgs = " ".join(v.message for v in on.violations)
    L, k_max = cfg.n_layers, max(cfg.layer_fanins)
    assert str([L, S, k_max]) in msgs            # pre accumulator caught
    assert str([L, S, cfg.n_hidden]) in msgs     # extra post acc caught


def test_live_topology_requires_factors(params):
    svc = TopologyService(EVOLVE_CFG)
    assert not svc.frozen
    p = init_params(jax.random.PRNGKey(3), EVOLVE_CFG)
    with pytest.raises(ValueError, match="factors"):
        StreamScheduler(p, EVOLVE_CFG, n_slots=2, topology=svc,
                        want_factors=False)
    # inferred default: factors on with a live service, off without
    assert StreamScheduler(p, EVOLVE_CFG, n_slots=2,
                           topology=svc).want_factors is True
    assert StreamScheduler(params, CFG, n_slots=2).want_factors is False


# --------------------------------------------------- ordered slot reduction

def test_ordered_slot_sum_fixed_tree():
    """The reduction tree is a function of S alone: equals an explicit
    pairwise-halving reference bit-for-bit, for odd and even S, and is
    invariant to how the array is later split (the sharded-parity
    mechanism, testable without devices)."""
    rng = np.random.default_rng(0)
    for S in (1, 2, 3, 7, 8, 16):
        x = (rng.standard_normal((S, 4, 5)).astype(np.float32) * 1e3)

        def ref(a):
            while a.shape[0] > 1:
                h = a.shape[0] // 2
                p = a[:h] + a[h:2 * h]
                a = p if a.shape[0] % 2 == 0 else \
                    np.concatenate([p, a[2 * h:]], 0)
            return a[0]

        got = np.asarray(engine.ordered_slot_sum(jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref(x))
        # and under jit (the form the chunk fn actually runs)
        jitted = np.asarray(jax.jit(engine.ordered_slot_sum)(jnp.asarray(x)))
        np.testing.assert_array_equal(jitted, ref(x))
