"""Event-stream serving runtime: isolation, lifecycle, gating, telemetry.

The load-bearing property is per-slot separability: a stream multiplexed
into a busy slot grid must see bit-for-bit (up to fp32 batching effects)
the same spikes, traces, and weight deltas as when it runs alone. Everything
else — admit/retire reuse, gated adaptation, telemetry — layers on that.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.snn import (SNNConfig, init_params, init_stream_deltas,
                            init_stream_state, run_chunk)
from repro.data.events import make_task
from repro.launch.batching import SlotGrid
from repro.serving import (AdaptConfig, ReplaySource, SessionStatus,
                           StreamScheduler, StreamSession, TaskStreamSource,
                           delta_norms, make_chunk_fn, read_lane, write_lane)

CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=16)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _events(seed, t, rate=0.25):
    rng = np.random.default_rng(seed)
    return (rng.random((t, CFG.n_in)) < rate).astype(np.float32)


def _run_lane(params, ev, n_slots, lane, chunk_len=6, others=()):
    """Feed ``ev`` into ``lane`` of an ``n_slots`` grid; ``others`` are
    (lane, events) streams fed concurrently. Returns (state, deltas)."""
    st = init_stream_state(CFG, n_slots)
    dl = init_stream_deltas(CFG, n_slots)
    cursors = {lane: [ev, 0]}
    for ln, oe in others:
        cursors[ln] = [oe, 0]
    while any(c < e.shape[0] for e, c in cursors.values()):
        events = np.zeros((chunk_len, n_slots, CFG.n_in), np.float32)
        valid = np.zeros((chunk_len, n_slots), bool)
        for ln, cur in cursors.items():
            e, c = cur
            n = min(chunk_len, e.shape[0] - c)
            if n > 0:
                events[:n, ln] = e[c:c + n]
                valid[:n, ln] = True
                cur[1] = c + n
        dl, st, _ = run_chunk(params, dl, st, jnp.asarray(events),
                              jnp.asarray(valid), CFG)
    return st, dl


# ------------------------------------------------------------- isolation

def test_interleaved_matches_solo(params):
    """Two interleaved streams == each run alone (traces, CC slot, deltas,
    per-stream gate thresholds) to fp32 tolerance."""
    ev_a, ev_b = _events(1, 40), _events(2, 40, rate=0.35)
    st_a, dl_a = _run_lane(params, ev_a, n_slots=1, lane=0)
    st_b, dl_b = _run_lane(params, ev_b, n_slots=1, lane=0)
    st2, dl2 = _run_lane(params, ev_a, n_slots=3, lane=0,
                         others=[(2, ev_b)])    # lane 1 stays idle

    for l in range(CFG.n_layers):
        np.testing.assert_allclose(st2.layers.tr[0, l], st_a.layers.tr[0, l],
                                   atol=1e-5)
        np.testing.assert_allclose(st2.layers.tr_cc[0, l],
                                   st_a.layers.tr_cc[0, l], atol=1e-5)
        np.testing.assert_allclose(st2.layers.tr[2, l], st_b.layers.tr[0, l],
                                   atol=1e-5)
        np.testing.assert_allclose(dl2[0, l], dl_a[0, l], atol=1e-5)
        np.testing.assert_allclose(dl2[2, l], dl_b[0, l], atol=1e-5)
    np.testing.assert_allclose(st2.ss_mean[0], st_a.ss_mean[0], atol=1e-6)
    np.testing.assert_allclose(st2.ss_mean[2], st_b.ss_mean[0], atol=1e-6)
    # the idle lane never moved
    assert float(jnp.abs(st2.layers.tr[1]).max()) == 0.0
    assert float(delta_norms(dl2)[1]) == 0.0


def test_chunk_boundaries_do_not_matter(params):
    """The same stream cut into different ragged chunkings ends identically."""
    ev = _events(3, 37)
    st1, dl1 = _run_lane(params, ev, n_slots=1, lane=0, chunk_len=6)
    st2, dl2 = _run_lane(params, ev, n_slots=1, lane=0, chunk_len=11)
    for l in range(CFG.n_layers):
        np.testing.assert_allclose(st1.layers.tr[0, l], st2.layers.tr[0, l],
                                   atol=1e-5)
        np.testing.assert_allclose(dl1[0, l], dl2[0, l], atol=1e-5)
    assert int(st1.sample_idx[0]) == int(st2.sample_idx[0]) == 37 // CFG.t_steps


def test_all_invalid_chunk_is_exact_noop(params):
    st = init_stream_state(CFG, 2)
    dl = init_stream_deltas(CFG, 2)
    ev = jnp.asarray(_events(4, 5))[:, None, :].repeat(2, 1)
    valid = jnp.zeros((5, 2), bool)
    dl2, st2, m = run_chunk(params, dl, st, ev, valid, CFG)
    for a, b in zip(jax.tree_util.tree_leaves((st, dl)),
                    jax.tree_util.tree_leaves((st2, dl2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m.sop_forward.sum()) == 0.0
    assert float(m.sop_wu_offered.sum()) == 0.0
    assert float(m.steps.sum()) == 0.0


def test_scheduler_interleaved_matches_solo(params):
    """End-to-end through the scheduler: window predictions of a stream are
    unaffected by a neighbor stream sharing the grid."""
    ev = _events(5, 2 * CFG.t_steps)
    def preds(extra_stream):
        sched = StreamScheduler(params, CFG, n_slots=2, chunk_len=5)
        sched.submit(StreamSession(sid=0, source=ReplaySource(ev, chunk_len=7)))
        if extra_stream:
            sched.submit(StreamSession(
                sid=1, source=ReplaySource(_events(6, 50, 0.4), chunk_len=9)))
        done = {s.sid: s for s in sched.run_until_drained()}
        return done[0].predictions
    solo, inter = preds(False), preds(True)
    assert len(solo) == len(inter) == 2
    for a, b in zip(solo, inter):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5)


# ------------------------------------------------------------- lifecycle

def test_admit_retire_slot_reuse(params):
    """More streams than slots: lanes are recycled and every stream ends
    RETIRED with its predictions and a final-delta snapshot."""
    task = make_task("gesture", n_in=CFG.n_in, t_steps=CFG.t_steps)
    sched = StreamScheduler(params, CFG, n_slots=2, chunk_len=8)
    for sid in range(5):
        sched.submit(StreamSession(
            sid=sid, source=TaskStreamSource(task, n_windows=1, seed=sid)))
    done = sched.run_until_drained()
    assert len(done) == 5
    assert sched.grid.stats["admitted"] == 5
    assert sched.grid.stats["retired"] == 5
    assert sched.grid.drained
    for s in done:
        assert s.status is SessionStatus.RETIRED and s.slot is None
        assert len(s.predictions) == 1
        assert s.final_deltas is not None
    assert 0.0 < sched.utilization <= 1.0


def test_slot_grid_helper():
    g: SlotGrid = SlotGrid(2)
    for i in range(3):
        g.submit(i)
    admitted = g.admit()
    assert [s for s, _ in admitted] == [0, 1] and g.free_slots() == []
    assert g.retire(0) == 0
    assert g.admit() == [(0, 2)]
    g.tick()
    assert not g.drained and g.stats["slot_busy"] == 2
    g.retire(0), g.retire(1)
    assert g.drained


# ------------------------------------------------------------- adaptation

def test_silent_stream_never_updates(params):
    """IA gate: an all-silent stream pays zero WU energy and keeps delta 0."""
    sched = StreamScheduler(params, CFG, n_slots=1, chunk_len=8)
    silent = np.zeros((3 * CFG.t_steps, CFG.n_in), np.float32)
    sched.submit(StreamSession(sid=0, source=ReplaySource(silent)))
    sched.run_until_drained()
    c = sched.telemetry.stream(0)
    assert c.sop_wu == 0.0 and c.gate_opened == 0.0
    assert float(np.abs(np.concatenate(
        [d.ravel() for d in sched.retired[0].final_deltas])).max()) == 0.0
    # but the gate was *offered* decisions and the stream was stepped
    assert c.gate_offered > 0 and c.timesteps == silent.shape[0]


def test_active_stream_adapts_and_frozen_does_not(params):
    """SS/IA gating opens for novel activity; a ``adapt=False`` session keeps
    its lane's delta frozen while state still tracks the stream."""
    ev = _events(7, 3 * CFG.t_steps, rate=0.3)
    sched = StreamScheduler(params, CFG, n_slots=2, chunk_len=8)
    sched.submit(StreamSession(sid=0, source=ReplaySource(ev.copy())))
    sched.submit(StreamSession(sid=1, source=ReplaySource(ev.copy()),
                               adapt=False))
    done = {s.sid: s for s in sched.run_until_drained()}
    n0 = float(np.sqrt(sum((d ** 2).sum() for d in done[0].final_deltas)))
    n1 = float(np.sqrt(sum((d ** 2).sum() for d in done[1].final_deltas)))
    assert n0 > 0.0, "gated OSSL never fired on an active stream"
    assert n1 == 0.0, "frozen session's delta moved"
    assert sched.telemetry.stream(1).sop_wu == 0.0
    # the frozen lane still produced the same number of window predictions
    assert len(done[0].predictions) == len(done[1].predictions) == 3


def test_idle_lane_delta_untouched_by_decay(params):
    """Regression: delta decay/clip used to run on every adaptive lane every
    grid step, idle or not — an empty slot slowly bled its delta toward 0.
    Hygiene must only touch lanes with valid timesteps this chunk."""
    fn = make_chunk_fn(CFG, AdaptConfig(delta_decay=0.9, delta_clip=0.05))
    st = init_stream_state(CFG, 2)
    # both lanes carry accumulated adaptation
    dl = jnp.full_like(init_stream_deltas(CFG, 2), 0.04)
    before = np.asarray(dl).copy()
    ev = jnp.asarray(_events(11, 5, rate=0.4))[:, None, :].repeat(2, 1)
    valid = np.zeros((5, 2), bool)
    valid[:, 0] = True    # lane 1 idle in every chunk
    amask = np.ones(2, bool)
    for _ in range(4):
        dl, st, _ = fn(params, dl, st, ev, jnp.asarray(valid), amask)
    np.testing.assert_array_equal(np.asarray(dl[1]), before[1])
    # the active lane's hygiene still ran: decay bled its parked delta
    assert float(np.abs(np.asarray(dl[0])).max()) < 0.04
    assert not np.array_equal(np.asarray(dl[0]), before[0])


def test_frozen_lane_offered_counters_masked(params):
    """Regression: sop_wu/gate_opened were zeroed for adapt=False lanes but
    the *offered* counters were not, so a frozen stream reported a fake
    100% wu_skip_rate. Frozen lanes must offer nothing too."""
    fn = make_chunk_fn(CFG)
    st = init_stream_state(CFG, 2)
    dl = init_stream_deltas(CFG, 2)
    ev = jnp.asarray(_events(12, CFG.t_steps, rate=0.4))[:, None, :].repeat(2, 1)
    valid = jnp.ones((CFG.t_steps, 2), bool)
    amask = np.array([True, False])
    dl, st, m = fn(params, dl, st, ev, valid, amask)
    assert float(m.sop_wu_offered[0]) > 0
    assert float(m.sop_wu_offered[1]) == 0.0
    assert float(m.gate_offered[1].sum()) == 0.0
    # scheduler-level: a frozen stream's skip rate reads 0 (nothing offered),
    # not 100% (everything "skipped")
    sched = StreamScheduler(params, CFG, n_slots=1, chunk_len=8)
    sched.submit(StreamSession(
        sid=0, source=ReplaySource(_events(13, 2 * CFG.t_steps, 0.4)),
        adapt=False))
    sched.run_until_drained()
    c = sched.telemetry.stream(0)
    assert c.sop_wu_offered == 0.0 and c.wu_skip_rate == 0.0
    assert c.timesteps == 2 * CFG.t_steps    # the stream was still served


def test_gate_skips_repetitive_stream(params):
    """SS gate: after per-stream threshold calibration, a stream repeating
    the same window pattern skips far more WUs than a varied one."""
    rng = np.random.default_rng(0)
    window = (rng.random((CFG.t_steps, CFG.n_in)) < 0.3).astype(np.float32)
    repetitive = np.concatenate([window] * 8, axis=0)
    varied = _events(9, 8 * CFG.t_steps, rate=0.3)
    sched = StreamScheduler(params, CFG, n_slots=2, chunk_len=8)
    sched.submit(StreamSession(sid=0, source=ReplaySource(repetitive)))
    sched.submit(StreamSession(sid=1, source=ReplaySource(varied)))
    sched.run_until_drained()
    rep = sched.telemetry.stream(0)
    var = sched.telemetry.stream(1)
    assert rep.wu_skip_rate > var.wu_skip_rate, (
        rep.wu_skip_rate, var.wu_skip_rate)


# ------------------------------------------------------------- telemetry

def test_telemetry_monotone_and_separable(params):
    ev = _events(10, 2 * CFG.t_steps, rate=0.3)
    sched = StreamScheduler(params, CFG, n_slots=2, chunk_len=4)
    sched.submit(StreamSession(sid=0, source=ReplaySource(ev)))
    sched.submit(StreamSession(
        sid=1, source=ReplaySource(np.zeros((40, CFG.n_in), np.float32))))
    prev = {}
    while not sched.grid.drained:
        sched.step()
        for sid, c in sched.telemetry.streams.items():
            snap = (c.timesteps, c.sop_forward, c.sop_wu, c.sop_wu_offered,
                    c.gate_offered, c.events_in)
            if sid in prev:
                assert all(b >= a for a, b in zip(prev[sid], snap)), sid
            prev[sid] = snap
    c0, c1 = sched.telemetry.stream(0), sched.telemetry.stream(1)
    # separable: the silent stream consumed zero input events and forward SOPs
    assert c1.events_in == 0.0 and c1.sop_forward == 0.0
    assert c0.events_in == float(ev.sum()) and c0.sop_forward > 0
    # fleet rollup is the sum of the per-stream counters
    r = sched.telemetry.rollup()
    assert r["events_in"] == c0.events_in + c1.events_in
    assert r["timesteps"] == c0.timesteps + c1.timesteps
    assert r["windows"] == c0.windows + c1.windows
    per = sched.telemetry.per_stream()
    assert [p["sid"] for p in per] == [0, 1]
    assert per[1]["power_uW"] < per[0]["power_uW"]   # silent slot is cheaper


def test_zero_recompilation_across_traffic_patterns(params):
    """Ragged chunks, admits, retires, idle slots: still one compilation."""
    task = make_task("shd_kws", n_in=CFG.n_in, t_steps=CFG.t_steps)
    sched = StreamScheduler(params, CFG, n_slots=4, chunk_len=8)
    for sid in range(7):
        sched.submit(StreamSession(
            sid=sid, source=TaskStreamSource(task, n_windows=1, seed=sid)))
    done = sched.run_until_drained()
    assert len(done) == 7
    assert sched.n_compiles == 1


# ------------------------------------------------------------- lane surgery

def test_pop_chunk_empty_is_column_shaped():
    """Regression: an empty pop returned shape (0, 0), a broadcast footgun
    for callers that concatenate or index columns. Width comes from the
    first pushed chunk, or from ``n_in`` stamped at construction/submit."""
    sess = StreamSession(sid=0, n_in=CFG.n_in)
    assert sess.pop_chunk(4).shape == (0, CFG.n_in)
    sess2 = StreamSession(sid=1)
    sess2.push_events(np.zeros((3, CFG.n_in), np.float32))
    assert sess2.pop_chunk(8).shape == (3, CFG.n_in)
    assert sess2.pop_chunk(8).shape == (0, CFG.n_in)     # drained: width kept
    with pytest.raises(ValueError, match="width"):
        sess2.push_events(np.zeros((2, CFG.n_in + 1), np.float32))
    # the scheduler stamps n_in at submit, so even a never-pushed session
    # pops well-shaped empties
    params = init_params(jax.random.PRNGKey(1), CFG)
    sched = StreamScheduler(params, CFG, n_slots=1)
    fresh = StreamSession(sid=2)
    sched.submit(fresh)
    assert fresh.n_in == CFG.n_in
    assert fresh.pop_chunk(4).shape == (0, CFG.n_in)
    # a session whose learned width disagrees with the grid fails at submit,
    # not mid-step with a half-mutated grid
    wrong = StreamSession(sid=3)
    wrong.push_events(np.zeros((2, CFG.n_in + 1), np.float32))
    with pytest.raises(ValueError, match="n_in"):
        sched.submit(wrong)


def test_write_read_lane_roundtrip():
    st = init_stream_state(CFG, 3)
    one = init_stream_state(CFG, 1)
    one = one._replace(x_tr=one.x_tr + 7.0)
    st2 = write_lane(st, one, 1)
    back = read_lane(st2, 1)
    np.testing.assert_array_equal(np.asarray(back.x_tr), np.asarray(one.x_tr))
    # other lanes untouched
    assert float(jnp.abs(st2.x_tr[0]).max()) == 0.0
    assert float(jnp.abs(st2.x_tr[2]).max()) == 0.0
