"""Compact-sparsity-first serving hot path.

The serving layout contract this PR line pins down:

* the per-stream delta tensor is compact ``[S, L, J, T, bk, bo]`` by
  default — only kept N:M blocks are stored;
* the compiled chunk step's jaxpr carries **no dense mask constant and no
  dense delta leaf** (the dense mask exists only on host, at topology
  epoch boundaries);
* storage-level ops — compact<->dense conversion, WU scatter, delta
  projection across topology epochs, lane merge — are **bitwise** exact
  at every kept coordinate;
* whole trajectories agree with the dense baseline to the repo's usual
  1e-5 (compact and dense contractions order float reductions
  differently, so bitwise cross-layout equality is not a real property);
* the compact chunk step is bit-identical between 1 device and an
  8-device slot-sharded mesh (subprocess — device count pins at init).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro.core import engine, topology
from repro.core.dsst import DSSTConfig
from repro.core.snn import (SNNConfig, init_params, init_stream_deltas,
                            init_stream_state, run_chunk, serving_params)
from repro.kernels.nm_spmm import ops as nm_ops
from repro.kernels.wu_outer import ops as wu_ops

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=8,
                dsst_enabled=False)


def _params(seed=0, cfg=CFG):
    return init_params(jax.random.PRNGKey(seed), cfg)


def _events(seed, c, s, cfg=CFG, rate=0.3):
    r = np.random.default_rng(seed)
    return jnp.asarray((r.random((c, s, cfg.n_in)) < rate)
                       .astype(np.float32))


# ------------------------------------------------------------ make_compact

def test_make_compact_traced_mask_needs_n_kept():
    """Regression: under jit a mask is a tracer, and the kept count cannot
    be read off it — the error must say to pass n_kept, not die inside
    a jnp indexing op."""
    spec = CFG.spec(CFG.n_in)
    params = _params()
    w = params["hidden"]["w"][0]
    mask = params["hidden"]["mask"][0]
    bk, bo = spec.block, spec.out_tile

    def f(w, mask):
        return nm_ops.make_compact(w, mask, bk, bo)

    with pytest.raises(ValueError, match="n_kept"):
        jax.jit(f)(w, mask)
    # and with n_kept it traces fine
    t = engine.compact_kept(CFG)
    wc, idx = jax.jit(lambda w, m: nm_ops.make_compact(w, m, bk, bo,
                                                       n_kept=t))(w, mask)
    wc2, idx2 = nm_ops.make_compact(w, mask, bk, bo)
    np.testing.assert_array_equal(np.asarray(wc), np.asarray(wc2))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))


# ------------------------------------------------- compact<->dense roundtrip

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_slots=st.integers(1, 5))
def test_compact_dense_delta_roundtrip_bitwise(seed, n_slots):
    """densify(compact(x)) == x * dense_mask, bitwise, for any dense delta
    tensor; and compact(densify(c)) == c for any compact one."""
    cfg = CFG
    params = _params(seed % 7, cfg)
    mask = params["hidden"]["mask"]
    idx = topology.stacked_kept_ids(mask, cfg)
    dm = np.asarray(topology.dense_masks(mask, cfg))

    r = np.random.default_rng(seed)
    dense = jnp.asarray(r.standard_normal(
        (n_slots, cfg.n_layers) + dm.shape[1:]).astype(np.float32))
    c = engine.compact_deltas(dense, idx, cfg)
    back = engine.densify_deltas(c, idx, cfg)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.asarray(dense) * dm[None])
    # exact inverse on the kept coordinates
    c2 = engine.compact_deltas(back, idx, cfg)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(c))


def test_stacked_kept_ids_agree_with_make_compact():
    """topology.stacked_kept_ids and kernels' make_compact must emit the
    same kept-block order — serving gathers with one, checkpoints/epochs
    with the other."""
    cfg = CFG
    params = _params(3, cfg)
    idx = topology.stacked_kept_ids(params["hidden"]["mask"], cfg)
    spec = cfg.spec(cfg.n_in)
    for l in range(cfg.n_layers):
        _, idx_l = nm_ops.make_compact(params["hidden"]["w"][l],
                                       params["hidden"]["mask"][l],
                                       spec.block, spec.out_tile)
        np.testing.assert_array_equal(np.asarray(idx[l]), np.asarray(idx_l))


def test_compact_weights_match_forward():
    """base forward through {"wc", "idx"} == dense masked einsum to 1e-6
    (same math, different reduction order)."""
    cfg = CFG
    params = _params(1, cfg)
    wrep = engine.compact_weights(params["hidden"]["w"],
                                  params["hidden"]["mask"], cfg)
    dm = topology.dense_masks(params["hidden"]["mask"], cfg)
    x = _events(5, 4, 1, cfg)[:, 0, :]           # [4, n_in] spikes
    y_c = nm_ops.nm_spmm_batched(x, wrep["wc"][0], wrep["idx"][0])
    y_d = x @ np.asarray(params["hidden"]["w"][0] * dm[0])
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_d), atol=1e-6)


# ----------------------------------------------------- projection bitwise

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_project_deltas_compact_matches_dense_bitwise(seed):
    """Across a topology swap: projecting in the compact layout == project
    dense then re-compact, bitwise. Surviving blocks keep their exact
    bits; recycled coordinates restart at zero."""
    cfg = dataclasses.replace(CFG, dsst=DSSTConfig(period=4, prune_frac=0.5),
                              dsst_enabled=True)
    old = _params(seed % 11, cfg)["hidden"]["mask"]
    new = _params((seed % 11) + 1, cfg)["hidden"]["mask"]
    old_ids = topology.stacked_kept_ids(old, cfg)
    new_ids = topology.stacked_kept_ids(new, cfg)

    r = np.random.default_rng(seed)
    dm_old = np.asarray(topology.dense_masks(old, cfg))
    dense = jnp.asarray((r.standard_normal(
        (3, cfg.n_layers) + dm_old.shape[1:]) * dm_old[None])
        .astype(np.float32))
    compact = engine.compact_deltas(dense, old_ids, cfg)

    proj_dense = topology.project_deltas(dense, old, new, cfg)
    proj_compact = topology.project_deltas(compact, old, new, cfg)
    # the dispatcher and the explicit-id entry point agree exactly
    np.testing.assert_array_equal(
        np.asarray(topology.project_deltas_compact(compact, old_ids,
                                                   new_ids)),
        np.asarray(proj_compact))
    np.testing.assert_array_equal(
        np.asarray(engine.densify_deltas(proj_compact, new_ids, cfg)),
        np.asarray(proj_dense))
    # survivors bit-preserved
    np.testing.assert_array_equal(
        np.asarray(engine.compact_deltas(proj_dense, new_ids, cfg)),
        np.asarray(proj_compact))


# ------------------------------------------------------- mask-free jaxpr

def test_serving_jaxpr_has_no_dense_mask_or_dense_deltas():
    """THE tentpole assert: with mask-free exec params and compact deltas
    the chunk jaxpr contains no f32 leaf shaped like the dense mask
    [L, Kmax, N] or the dense delta tensor [S, L, Kmax, N] — neither as a
    constant nor as an intermediate. Since the static-analysis PR the
    hand-rolled jaxpr walk lives in repro.analysis (mask_free /
    no_dense_deltas check avals recursively AND cross-check the printed
    jaxpr — belt and braces for consts a traversal might miss); this test
    pins those contracts to the real chunk entrypoint."""
    from repro import analysis

    cfg = CFG
    S, C = 4, 6
    params = _params(0, cfg)
    sp_exec = serving_params(params, cfg)
    dc = init_stream_deltas(cfg, S)
    st0 = init_stream_state(cfg, S)
    ev = _events(0, C, S, cfg)
    valid = jnp.ones((C, S), bool)

    report = analysis.check(
        lambda p, d, s: run_chunk(p, d, s, ev, valid, cfg),
        (sp_exec, dc, st0),
        [analysis.mask_free(cfg), analysis.no_dense_deltas(cfg, S)])
    report.raise_if_violations()
    assert report.ok and set(report.contracts) == {"mask_free",
                                                  "no_dense_deltas"}


def test_dense_baseline_still_runs_and_matches():
    """The dense path stays selectable (compact=False) and the two layouts
    track each other at the repo's trajectory tolerance."""
    cfg = CFG
    S, C = 4, 8
    params = _params(0, cfg)
    ev = _events(1, C, S, cfg)
    valid = jnp.asarray(np.random.default_rng(2).random((C, S)) < 0.85)
    st0 = init_stream_state(cfg, S)

    dc, _, mc = run_chunk(serving_params(params, cfg),
                          init_stream_deltas(cfg, S), st0, ev, valid, cfg)
    dd, _, md = run_chunk(params, init_stream_deltas(cfg, S, compact=False),
                          st0, ev, valid, cfg)
    idx = topology.stacked_kept_ids(params["hidden"]["mask"], cfg)
    np.testing.assert_allclose(
        np.asarray(engine.densify_deltas(dc, idx, cfg)), np.asarray(dd),
        atol=1e-5)
    np.testing.assert_allclose(np.asarray(mc.logits), np.asarray(md.logits),
                               atol=1e-5)


# ------------------------------------------------------------- WU bitwise

def test_wu_outer_slots_bitwise_vs_dense_at_kept_coords():
    """One WU step: the compact scatter == the dense masked outer product,
    bitwise, because the multiply association is mirrored."""
    cfg = CFG
    spec = cfg.spec(cfg.n_in)
    params = _params(4, cfg)
    mask = params["hidden"]["mask"][0]
    idx = topology.stacked_kept_ids(params["hidden"]["mask"], cfg)[0]
    dm = np.asarray(topology.dense_masks(params["hidden"]["mask"], cfg)[0])

    S = 5
    r = np.random.default_rng(7)
    pre = jnp.asarray(r.standard_normal((S, cfg.n_in)).astype(np.float32))
    mod = jnp.asarray(r.standard_normal((S, cfg.n_hidden)).astype(np.float32))
    scale = jnp.asarray(r.uniform(0, 0.1, S).astype(np.float32))

    dwc = wu_ops.wu_outer_slots(pre, mod, idx, scale,
                                bk=spec.block, bo=spec.out_tile)
    dense = (scale[:, None] * pre)[:, :, None] * mod[:, None, :] * dm[None]
    got = engine.densify_deltas(
        dwc[:, None], idx[None], dataclasses.replace(cfg, n_layers=1))[:, 0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))


# ------------------------------------------------------------- merge fold

def test_merge_lane_into_base_fold_exact_compact():
    """Folding a compact lane into the base then serving with a zero lane
    == serving with the lane, to fp tolerance; and the merge itself is a
    bitwise densify-add (base is exactly zero off-mask)."""
    from repro.serving.adapt import merge_lane_into_base

    cfg = CFG
    S, C = 2, 8
    params = _params(0, cfg)
    ev = _events(3, C, S, cfg)
    valid = jnp.ones((C, S), bool)
    st0 = init_stream_state(cfg, S)
    dl, _, _ = run_chunk(serving_params(params, cfg),
                         init_stream_deltas(cfg, S), st0, ev, valid, cfg)

    merged = merge_lane_into_base(params, dl, 0, cfg, weight=1.0)
    idx = topology.stacked_kept_ids(params["hidden"]["mask"], cfg)
    lane_dense = engine.densify_deltas(dl[:1], idx, cfg)[0]
    np.testing.assert_array_equal(
        np.asarray(merged["hidden"]["w"]),
        np.asarray(params["hidden"]["w"] + lane_dense))
    # base stays exactly zero off the mask — the invariant that makes the
    # mask-free merge exact
    dm = np.asarray(topology.dense_masks(params["hidden"]["mask"], cfg))
    assert np.all(np.asarray(merged["hidden"]["w"])[dm == 0] == 0)

    # fold-exactness: folded base + zero lane == old base + lane, to fp
    ev2 = _events(4, C, S, cfg)
    _, _, m_lane = run_chunk(serving_params(params, cfg), dl, st0, ev2,
                             valid, cfg, learn=False)
    zero0 = dl.at[0].set(0.0)
    _, _, m_fold = run_chunk(serving_params(merged, cfg), zero0, st0, ev2,
                             valid, cfg, learn=False)
    np.testing.assert_allclose(np.asarray(m_fold.logits[:, 0]),
                               np.asarray(m_lane.logits[:, 0]), atol=1e-5)


# ------------------------------------------------------- checkpoint shim

def test_fleet_checkpoint_roundtrip_and_migration(tmp_path):
    from repro.serving import restore_fleet, save_fleet

    cfg = CFG
    S, C = 3, 8
    params = _params(0, cfg)
    ev = _events(6, C, S, cfg)
    valid = jnp.ones((C, S), bool)
    st0 = init_stream_state(cfg, S)
    dc, stc, _ = run_chunk(serving_params(params, cfg),
                           init_stream_deltas(cfg, S), st0, ev, valid, cfg)

    # compact-stored -> compact fleet: bitwise roundtrip
    save_fleet(str(tmp_path / "c"), 5, params, dc, stc)
    step, p2, d2, s2, extra = restore_fleet(str(tmp_path / "c"), cfg)
    assert step == 5 and extra["delta_layout"] == "compact"
    assert extra["n_slots"] == S
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(dc))
    for a, b in zip(jax.tree_util.tree_leaves((params, stc)),
                    jax.tree_util.tree_leaves((p2, s2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # dense-stored (pre-compact checkpoint) -> compact fleet: migrated
    # bit-exactly at every kept coordinate
    idx = topology.stacked_kept_ids(params["hidden"]["mask"], cfg)
    dd = engine.densify_deltas(dc, idx, cfg)
    save_fleet(str(tmp_path / "d"), 9, params, dd, stc)
    step, _, d3, _, extra = restore_fleet(str(tmp_path / "d"), cfg,
                                          compact=True)
    assert step == 9 and extra["delta_layout"] == "dense"
    np.testing.assert_array_equal(np.asarray(d3), np.asarray(dc))

    # compact-stored -> dense fleet densifies
    _, _, d4, _, _ = restore_fleet(str(tmp_path / "c"), cfg, compact=False)
    np.testing.assert_array_equal(np.asarray(d4), np.asarray(dd))


# ------------------------------------------------- scheduler + telemetry

def test_scheduler_compact_by_default_reports_bytes_held():
    from repro.serving import ReplaySource, StreamScheduler, StreamSession

    cfg = CFG
    params = _params(0, cfg)
    sched = StreamScheduler(params, cfg, n_slots=2, chunk_len=4)
    assert sched.compact and sched.deltas.ndim == 6
    ev = (np.random.default_rng(0).random((2 * cfg.t_steps, cfg.n_in))
          < 0.3).astype(np.float32)
    sched.submit(StreamSession(sid=0, source=ReplaySource(ev)))
    sched.run_until_drained()
    bh = sched.telemetry.bytes_held()
    assert bh["total"] == bh["params"] + bh["deltas"] > 0
    assert bh["deltas"] == sched.deltas.nbytes
    # compact holds strictly less than the dense baseline would
    dense = init_stream_deltas(cfg, 2, compact=False)
    assert bh["deltas"] < dense.nbytes
    assert sched.telemetry.rollup()["bytes_held"]["total"] == bh["total"]
    # the gauge is in the obs registry for scraping
    fam = sched.telemetry.registry.get("serving_bytes_held")
    assert fam is not None


def test_scheduler_dense_vs_compact_trajectory_parity_evolving():
    """Full fleet with live DSST epochs: compact and dense layouts make the
    same epoch decisions and agree on every prediction to 1e-5."""
    from repro.serving import (ReplaySource, StreamScheduler, StreamSession,
                               TopologyService, TopologyServiceConfig)

    cfg = dataclasses.replace(
        CFG, t_steps=12, dsst=DSSTConfig(period=4, prune_frac=0.5),
        dsst_enabled=True)
    params = _params(0, cfg)

    def drive(compact):
        svc = TopologyService(cfg, TopologyServiceConfig(epoch_every=3,
                                                         merge_top=1))
        sched = StreamScheduler(params, cfg, n_slots=4, chunk_len=6,
                                topology=svc, compact=compact)
        for sid in range(4):
            ev = (np.random.default_rng(sid).random((36, cfg.n_in))
                  < 0.35).astype(np.float32)
            sched.submit(StreamSession(sid=sid, source=ReplaySource(
                ev, chunk_len=6), adapt=(sid % 2 == 0)))
        done = {s.sid: s for s in sched.run_until_drained()}
        return sched, svc, done

    sc, vc, dc = drive(True)
    sd, vd, dd = drive(False)
    assert sc.compact and not sd.compact
    assert vc.epoch_idx == vd.epoch_idx >= 1
    assert [(e.pruned, e.regrown) for e in vc.events] \
        == [(e.pruned, e.regrown) for e in vd.events]
    assert sc.n_compiles == 1 and sd.n_compiles == 1
    for sid in dc:
        assert len(dc[sid].predictions) == len(dd[sid].predictions) > 0
        for a, b in zip(dc[sid].predictions, dd[sid].predictions):
            np.testing.assert_allclose(a.logits, b.logits, atol=1e-5)
    # compact fleet held strictly less weight-state than the dense one
    assert sc.telemetry.bytes_held()["total"] \
        < sd.telemetry.bytes_held()["total"]


# ------------------------------------------------------------- 8 devices

def test_compact_chunk_step_8device_bit_identical():
    """The compact chunk step under 8-device slot-axis shard_map is
    bit-identical to 1 device (subprocess: device count pins at init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.core.snn import (SNNConfig, init_params, init_stream_state,
                                    init_stream_deltas, serving_params)
        from repro.launch import sharding as SH
        from repro.launch.mesh import make_serving_mesh
        from repro.serving.adapt import AdaptConfig, make_chunk_fn

        cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8,
                        t_steps=16)
        params = init_params(jax.random.PRNGKey(0), cfg)
        sp = serving_params(params, cfg)
        mesh = make_serving_mesh()
        assert SH.slot_devices(mesh) == 8
        S, C = 16, 6
        rng = np.random.default_rng(0)
        adapt = AdaptConfig(delta_decay=0.95, delta_clip=0.3)
        fn1 = make_chunk_fn(cfg, adapt)
        fn8 = make_chunk_fn(cfg, adapt, mesh=mesh)
        st1 = init_stream_state(cfg, S)
        dl1 = init_stream_deltas(cfg, S)
        assert dl1.ndim == 6, dl1.shape            # compact by default
        st8 = jax.device_put(st1, SH.stream_shardings(st1, mesh))
        dl8 = jax.device_put(dl1, SH.slot_sharding(mesh))
        for i in range(3):
            events = (rng.random((C, S, cfg.n_in)) < 0.3).astype(np.float32)
            valid = rng.random((C, S)) < 0.8
            amask = rng.random(S) < 0.7
            dl1, st1, m1 = fn1(sp, dl1, st1, events, valid, amask)
            dl8, st8, m8 = fn8(sp, dl8, st8, events, valid, amask)
        assert dl8.sharding.spec == SH.slot_spec(0), dl8.sharding
        np.testing.assert_array_equal(np.asarray(dl1), np.asarray(dl8))
        for a, b in zip(jax.tree_util.tree_leaves(st1),
                        jax.tree_util.tree_leaves(st8)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for name, a, b in zip(m1._fields, m1, m8):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        assert fn1.n_traces() == 1 and fn8.n_traces() == 1
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "OK" in out.stdout
