"""obs unit coverage: tracer, metrics registry, exporter golden formats.

The contracts the serving integration relies on, tested in isolation:
span nesting and the ring-buffer bound, thread safety, counter
monotonicity-by-construction, the bounded histogram's O(1)-in-
observations memory with percentiles within bucket tolerance of exact,
and byte-for-byte exporter goldens (Prometheus text, JSONL, Chrome
``trace_event``).
"""
import json
import threading

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, MetricsRegistry, Tracer, chrome_trace,
                       linear_buckets, log_buckets, parse_prometheus_text,
                       prometheus_text, read_jsonl, span_records,
                       write_chrome_trace, write_jsonl)
from repro.obs.metrics import Histogram

# ------------------------------------------------------------------- tracer


def test_span_nesting_ids_and_attrs():
    tr = Tracer()
    with tr.span("outer", step=1):
        with tr.span("inner") as sp:
            sp.set(count=3)
        with tr.span("inner2"):
            pass
    spans = {s.name: s for s in tr.spans()}
    assert set(spans) == {"outer", "inner", "inner2"}
    outer = spans["outer"]
    assert outer.parent_id is None and outer.attr("step") == 1
    assert spans["inner"].parent_id == outer.span_id
    assert spans["inner2"].parent_id == outer.span_id
    assert spans["inner"].attr("count") == 3
    assert spans["inner"].span_id != spans["inner2"].span_id
    # children recorded before the parent (exit order), durations nest
    assert outer.dur_s >= spans["inner"].dur_s >= 0.0
    assert outer.t0_s <= spans["inner"].t0_s


def test_ring_buffer_bound_and_drop_count():
    tr = Tracer(capacity=8)
    for i in range(20):
        with tr.span("s", i=i):
            pass
    spans = tr.spans()
    assert len(spans) == 8                      # bounded: O(capacity)
    assert tr.n_recorded == 20 and tr.n_dropped == 12
    assert [s.attr("i") for s in spans] == list(range(12, 20))  # newest kept
    tr.clear()
    assert tr.spans() == []


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    with tr.span("x") as sp:
        sp.set(a=1)             # no-op handle supports the same surface
    assert tr.spans() == [] and tr.n_recorded == 0
    with NULL_TRACER.span("y"):
        pass
    assert NULL_TRACER.spans() == []
    # the disabled path hands back the shared singleton (no allocation)
    assert tr.span("a") is tr.span("b")


def test_tracer_thread_safety():
    """Concurrent recording from many threads: no lost/corrupt spans and
    per-thread parent stacks stay independent (the pipeline_depth=2-style
    usage where a poller thread would trace alongside the main loop)."""
    tr = Tracer(capacity=10_000)
    n_threads, n_spans = 8, 200

    def work(t):
        for i in range(n_spans):
            with tr.span("outer", t=t):
                with tr.span("inner", t=t):
                    pass

    threads = [threading.Thread(target=work, args=(t,), name=f"w{t}")
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    spans = tr.spans()
    assert len(spans) == tr.n_recorded == n_threads * n_spans * 2
    ids = [s.span_id for s in spans]
    assert len(set(ids)) == len(ids), "span ids collided across threads"
    parents = {p.span_id: p for p in spans if p.name == "outer"}
    for s in spans:
        if s.name == "inner":
            # parent is an outer span from the SAME thread
            assert s.parent_id in parents
            assert parents[s.parent_id].thread == s.thread


def test_tracer_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        Tracer(capacity=0)


# ------------------------------------------------------------------ metrics


def test_counter_monotone_by_construction():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "help", labels=("code",))
    c.labels(code="200").inc()
    c.labels(code="200").inc(2.5)
    c.labels(code="500").inc(0.0)
    assert c.labels(code="200").value == 3.5
    assert c.total() == 3.5
    with pytest.raises(ValueError, match=">= 0"):
        c.labels(code="200").inc(-1.0)
    with pytest.raises(ValueError, match="expected labels"):
        c.labels(status="200")


def test_gauge_and_family_reuse():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "queue depth")
    g.set(3)
    g.inc(-1)
    assert g.value == 2.0
    assert reg.gauge("depth") is g          # create-or-get
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("depth")                # kind mismatch refused
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad-name")
    with pytest.raises(ValueError, match="invalid label name"):
        reg.counter("ok", labels=("bad-label",))


def test_histogram_percentiles_within_bucket_tolerance():
    """p50/p99 from the bounded histogram stay within one bucket's
    relative width (~10% for the default per_decade=24 latency buckets)
    of the exact numpy percentiles — the satellite's regression contract
    for FleetTelemetry.latency_percentiles."""
    rng = np.random.default_rng(0)
    h = Histogram(log_buckets(1e-6, 60.0, per_decade=24))
    vals = np.exp(rng.normal(loc=np.log(3e-3), scale=1.0, size=5000))
    for v in vals:
        h.observe(v)
    for q in (50, 90, 99):
        exact = float(np.percentile(vals, q))
        est = h.percentile(q)
        assert abs(est - exact) / exact < 0.12, (q, est, exact)
    assert h.count == len(vals)
    np.testing.assert_allclose(h.sum, vals.sum(), rtol=1e-9)


def test_histogram_memory_is_o1_in_observations():
    """The unbounded-list fix: internal state size is a function of the
    bucket count alone, not of how many values were observed."""
    h = Histogram(linear_buckets(0.0, 1.0, 10))
    size0 = len(h.bucket_counts())
    for i in range(10_000):
        h.observe((i % 100) / 100.0)
    assert len(h.bucket_counts()) == size0 == 11
    assert h.count == 10_000
    # no per-observation storage exists anywhere on the object
    assert all(not isinstance(getattr(h, a, None), list)
               or a == "_counts" for a in dir(h))


def test_histogram_edges_and_validation():
    h = Histogram([1.0, 2.0])
    h.observe(0.5)
    h.observe(1.0)       # boundary: le semantics, lands in first bucket
    h.observe(5.0)       # overflow bucket
    assert h.bucket_counts() == [2, 0, 1]
    assert h.percentile(100) == 2.0          # overflow clamps to last bound
    assert Histogram([1.0]).percentile(50) == 0.0    # empty
    with pytest.raises(ValueError, match="increasing"):
        Histogram([1.0, 1.0])
    with pytest.raises(ValueError, match="percentile"):
        h.percentile(101)


def test_bucket_helpers():
    b = log_buckets(1e-3, 1.0, per_decade=3)
    assert b[0] == 1e-3 and b[-1] >= 1.0
    assert all(y > x for x, y in zip(b, b[1:]))
    lin = linear_buckets(0.0, 1.0, 4)
    np.testing.assert_allclose(lin, (0.25, 0.5, 0.75, 1.0))
    with pytest.raises(ValueError):
        log_buckets(1.0, 0.5)
    with pytest.raises(ValueError):
        linear_buckets(0.0, 1.0, 0)


def test_registry_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("a_total", "A", labels=("k",)).labels(k="x").inc(2)
    reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
    snap = reg.snapshot()
    assert snap["a_total"]["type"] == "counter"
    assert snap["a_total"]["samples"] == [{"labels": {"k": "x"}, "value": 2.0}]
    hs = snap["lat_seconds"]["samples"][0]
    assert hs["count"] == 1 and hs["sum"] == 0.05
    assert set(hs) == {"labels", "count", "sum", "p50", "p99"}
    json.dumps(snap)                         # artifact-safe


# ---------------------------------------------------------------- exporters


def test_prometheus_text_golden():
    reg = MetricsRegistry()
    c = reg.counter("events_total", "events seen", labels=("sid",))
    c.labels(sid="0").inc(3)
    c.labels(sid="1").inc(1.5)
    reg.gauge("depth", "queue depth").set(2)
    reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)).observe(0.05)
    reg.histogram("lat_seconds").observe(10.0)
    golden = "\n".join([
        '# HELP depth queue depth',
        '# TYPE depth gauge',
        'depth 2',
        '# HELP events_total events seen',
        '# TYPE events_total counter',
        'events_total{sid="0"} 3',
        'events_total{sid="1"} 1.5',
        '# HELP lat_seconds latency',
        '# TYPE lat_seconds histogram',
        'lat_seconds_bucket{le="0.1"} 1',
        'lat_seconds_bucket{le="1"} 1',
        'lat_seconds_bucket{le="+Inf"} 2',
        'lat_seconds_sum 10.05',
        'lat_seconds_count 2',
    ]) + "\n"
    assert prometheus_text(reg) == golden
    parsed = parse_prometheus_text(golden)
    assert parsed['events_total{sid="0"}'] == 3.0
    assert parsed['lat_seconds_bucket{le="+Inf"}'] == 2.0


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.counter("c_total", labels=("p",)).labels(p='a"b\\c\nd').inc()
    text = prometheus_text(reg)
    assert 'c_total{p="a\\"b\\\\c\\nd"} 1' in text


def test_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "log.jsonl")
    tr = Tracer()
    with tr.span("stage", grid_step=3):
        pass
    n = write_jsonl(path, span_records(tr.spans()))
    n += write_jsonl(path, [{"kind": "rollup", "events_per_s": 10.0}])
    assert n == 2
    recs = read_jsonl(path)
    assert len(recs) == 2
    assert recs[0]["kind"] == "span" and recs[0]["name"] == "stage"
    assert recs[0]["grid_step"] == 3 and recs[0]["dur_s"] >= 0.0
    assert recs[1] == {"kind": "rollup", "events_per_s": 10.0}
    # append=False truncates
    write_jsonl(path, [{"a": 1}], append=False)
    assert read_jsonl(path) == [{"a": 1}]


def test_chrome_trace_golden_structure(tmp_path):
    tr = Tracer()
    with tr.span("step", grid_step=1):
        with tr.span("stage"):
            pass
    doc = chrome_trace(tr)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    xs = {e["name"]: e for e in events if e["ph"] == "X"}
    assert len(meta) == 1 and meta[0]["name"] == "thread_name"
    assert set(xs) == {"step", "stage"}
    step, stage = xs["step"], xs["stage"]
    assert step["args"]["grid_step"] == 1
    assert stage["args"]["parent_id"] == step["args"]["span_id"]
    # µs timeline relative to the earliest span; child inside parent
    assert step["ts"] == 0.0 and stage["ts"] >= 0.0
    assert stage["ts"] + stage["dur"] <= step["ts"] + step["dur"] + 1e-3
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, tr)
    assert json.load(open(path))["traceEvents"]
