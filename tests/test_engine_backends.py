"""ref ↔ Pallas parity across the engine's backend seam.

``backend="pallas-interpret"`` forces every seam op (forward current, fused
LIF step, WU outer product) through the Pallas kernels in emulation mode, so
these run on CPU CI. Covered at two levels: each seam op in isolation on
masked N:M weights, and the full train/serve trajectories end-to-end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.snn import (SNNConfig, init_params, init_state,
                            init_stream_deltas, init_stream_state, run_chunk,
                            run_sample)

CFG = SNNConfig(n_in=16, n_hidden=16, n_layers=2, n_out=4, t_steps=6)
REF = engine.make_backend(CFG)
PAL = engine.make_backend(dataclasses.replace(CFG, backend="pallas-interpret"))


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _wreps(params):
    w, m = params["hidden"]["w"], params["hidden"]["mask"]
    return (engine.prepare_weights(w, m, CFG, REF),
            engine.prepare_weights(w, m, CFG, PAL))


def _slice(wrep, l):
    return jax.tree_util.tree_map(lambda a: a[l], wrep)


def test_forward_current_parity(params):
    wr, wp = _wreps(params)
    pre = jax.random.normal(jax.random.PRNGKey(1), (5, CFG.n_in))
    for l in range(CFG.n_layers):
        want = engine.fwd_current(REF, pre, _slice(wr, l), None)
        got = engine.fwd_current(PAL, pre, _slice(wp, l), None)
        # both must equal the dense masked matmul
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(want), np.asarray(pre @ params["hidden"]["w"][l]),
            atol=1e-5)


def test_lif_step_parity():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    v = jax.random.normal(ks[0], (5, CFG.n_hidden))
    tr = jax.random.uniform(ks[1], (5, CFG.n_hidden))
    cur = jax.random.normal(ks[2], (5, CFG.n_hidden))
    want = engine.lif(REF, CFG, v, tr, cur)
    got = engine.lif(PAL, CFG, v, tr, cur)
    for a, b in zip(want, got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-5)


def test_wu_outer_parity(params):
    """The training WU on masked N:M weights: dense dw·mask (ref) equals the
    compact-layout outer product (wu_outer kernel), densified. The sparsity
    pattern rides inside the weight rep itself (mask_f for ref, kept block
    ids for compact) — train_wu takes no separate mask argument."""
    wr, wp = _wreps(params)
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    pre_tr = jax.random.uniform(ks[0], (5, CFG.n_in))
    mod = jax.random.normal(ks[1], (5, CFG.n_hidden))
    scale = jnp.float32(0.03)
    for l in range(CFG.n_layers):
        want = engine.train_wu(REF, CFG, _slice(wr, l), pre_tr, mod,
                               scale)["w"]
        got_rep = engine.train_wu(PAL, CFG, _slice(wp, l), pre_tr, mod,
                                  scale)
        got = engine.finalize_weights(
            jax.tree_util.tree_map(lambda a: a[None], got_rep), CFG, PAL)[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        # gate closed (scale 0) -> exactly no update on either path
        same = engine.train_wu(REF, CFG, _slice(wr, l), pre_tr, mod,
                               jnp.float32(0.0))["w"]
        np.testing.assert_array_equal(np.asarray(same),
                                      np.asarray(params["hidden"]["w"][l]))


def test_run_sample_backend_parity(params):
    st = init_state(CFG, 4)
    ev = jnp.asarray((np.random.default_rng(0).random(
        (CFG.t_steps, 4, CFG.n_in)) < 0.3).astype(np.float32))
    lab = jnp.asarray(np.arange(4) % CFG.n_out)
    outs = {}
    for backend in ("ref", "pallas-interpret"):
        cfg = dataclasses.replace(CFG, backend=backend)
        p2, _, m = run_sample(params, st, ev, lab, cfg, learn=True)
        outs[backend] = (np.asarray(m.logits), np.asarray(p2["hidden"]["w"]),
                         float(m.sop_wu))
    for a, b in zip(outs["ref"], outs["pallas-interpret"]):
        np.testing.assert_allclose(b, a, atol=1e-5)


def test_run_chunk_backend_parity(params):
    ss, dl = init_stream_state(CFG, 2), init_stream_deltas(CFG, 2)
    ev = jnp.asarray((np.random.default_rng(1).random(
        (6, 2, CFG.n_in)) < 0.3).astype(np.float32))
    valid = jnp.ones((6, 2), bool).at[4:, 1].set(False)
    outs = {}
    for backend in ("ref", "pallas-interpret"):
        cfg = dataclasses.replace(CFG, backend=backend)
        dl2, ss2, cm = run_chunk(params, dl, ss, ev, valid, cfg)
        outs[backend] = (np.asarray(cm.logits), np.asarray(dl2),
                         np.asarray(ss2.layers.tr))
    for a, b in zip(outs["ref"], outs["pallas-interpret"]):
        np.testing.assert_allclose(b, a, atol=1e-5)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        engine.make_backend(dataclasses.replace(CFG, backend="cuda"))
