"""Fleet launcher: validate gate + local smoke train (single host)."""
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, extra_env=None, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    env.update(extra_env or {})
    return subprocess.run([sys.executable, "-m", "repro.launch.launcher"] + args,
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_validate_gate_production_mesh():
    """--validate lowers the full-scale arch on the 512-dev mesh (CI gate)."""
    out = _run(["--arch", "qwen2_vl_2b", "--validate", "--multi-pod"],
               extra_env={"XLA_FLAGS":
                          "--xla_force_host_platform_device_count=512"})
    assert out.returncode == 0, out.stdout + out.stderr
    assert "validate OK" in out.stdout


def test_local_smoke_train_falls_back():
    """Without 512 devices the launcher reduces the config and trains."""
    out = _run(["--arch", "stablelm_12b", "--steps", "4",
                "--seq-len", "32", "--global-batch", "4",
                "--opt", "zero1"])
    assert out.returncode == 0, out.stdout + out.stderr
    assert "loss" in out.stdout
