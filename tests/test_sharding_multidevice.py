"""Sharding rules + distributed semantics on an 8-device host mesh.

Device count must be pinned before jax initializes, so these run in a
subprocess with XLA_FLAGS set (conftest keeps the main process at 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_rules_produce_valid_shardings_and_train_step_runs():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        import repro.configs as C
        from repro.launch import sharding as SH
        from repro.launch.train import TrainHParams, make_train_step, init_train_state
        from repro.optim import adamw_init

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        cfg = C.get_reduced("phi3_medium_14b")
        hp = TrainHParams()
        params, opt, ss = init_train_state(jax.random.PRNGKey(0), cfg, hp)
        p_sh = SH.tree_shardings(params, cfg, mesh)
        o_sh = SH.tree_shardings(opt, cfg, mesh)
        ss_sh = jax.tree.map(lambda _: SH.replicated(mesh), ss)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "labels": jnp.zeros((8, 16), jnp.int32)}
        b_sh = SH.batch_shardings(batch, mesh)
        with mesh:
            fn = jax.jit(make_train_step(cfg, hp),
                         in_shardings=(p_sh, o_sh, ss_sh, b_sh),
                         out_shardings=(p_sh, o_sh, ss_sh, None))
            params = jax.device_put(params, p_sh)
            opt = jax.device_put(opt, o_sh)
            batch = jax.device_put(batch, b_sh)
            p2, o2, s2, m = fn(params, opt, ss, batch)
            assert not bool(jnp.isnan(m["loss"])), m
            # attention projection really is sharded over model axis
            wq = p2["layers"]["attn"]["wq"]["w"]
            assert "model" in wq.sharding.spec, wq.sharding
        print("OK loss", float(m["loss"]))
    """))


def test_sharded_equals_single_device():
    """The same step on a (2,4) mesh and on 1 device gives the same loss."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        import repro.configs as C
        from repro.launch import sharding as SH
        from repro.launch.train import TrainHParams, make_train_step, init_train_state

        cfg = C.get_reduced("stablelm_12b")
        hp = TrainHParams()
        params, opt, ss = init_train_state(jax.random.PRNGKey(0), cfg, hp)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab)}
        step = make_train_step(cfg, hp)
        _,_,_, m1 = jax.jit(step)(params, opt, ss, batch)

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        p_sh = SH.tree_shardings(params, cfg, mesh)
        b_sh = SH.batch_shardings(batch, mesh)
        with mesh:
            fn = jax.jit(step, in_shardings=(p_sh, None, None, b_sh))
            _,_,_, m2 = fn(jax.device_put(params, p_sh), opt, ss,
                           jax.device_put(batch, b_sh))
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-3, (float(m1["loss"]), float(m2["loss"]))
        print("OK", float(m1["loss"]), float(m2["loss"]))
    """))


def test_compressed_dp_allreduce_shardmap():
    """int8+EF gradient compression under shard_map psum: mean of
    decompressed per-replica grads ~= uncompressed mean."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.runtime.compression import CompressionConfig, compress, decompress

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        cfg = CompressionConfig(kind="int8")
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        @functools.partial(shard_map, mesh=mesh, in_specs=P("data", None),
                           out_specs=P(None))
        def mean_compressed(gl):
            rec = decompress(compress(gl[0], cfg), cfg)
            return jax.lax.pmean(rec, "data")[None]

        got = mean_compressed(g)[0]
        want = g.mean(0)
        assert float(jnp.abs(got - want).max()) < 0.02, float(jnp.abs(got-want).max())
        print("OK")
    """))


def test_dryrun_entry_on_8_devices():
    """The dry-run machinery end-to-end on a small mesh + reduced config."""
    print(_run("""
        import jax, numpy as np
        from jax.sharding import Mesh
        import repro.configs as C
        from repro.configs.base import ShapeConfig
        # note: importing dryrun pins 512 host devices (its first lines);
        # the test mesh just uses the first 8.
        from repro.launch.dryrun import lower_cell, input_specs
        from repro.launch.train import TrainHParams

        cfg = C.get_reduced("mixtral_8x7b")
        shape = ShapeConfig("smoke", 32, 8, "train")
        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
        rec = lower_cell(cfg, shape, mesh, hp=TrainHParams(), cost_probes=True)
        assert rec["flops_per_device"] > 0
        assert rec["memory"]["peak_estimate_bytes"] > 0
        shape_d = ShapeConfig("smoke_d", 64, 8, "decode")
        rec2 = lower_cell(cfg, shape_d, mesh, cost_probes=False)
        assert rec2["compile_s"] > 0
        print("OK", rec["flops_per_device"], rec2["raw"]["flops_per_device"])
    """))
