"""Hypothesis compatibility shim.

Property tests in this repo use a tiny slice of hypothesis
(``@given``/``@settings`` with ``st.integers``).  The container image does
not always ship hypothesis, and a missing import must not turn into a
tier-1 collection error — so test modules import from here instead.  When
hypothesis is installed we re-export the real thing; otherwise each
property test runs a handful of deterministic, seeded examples.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: "random.Random"):
            return self._draw(rng)

    class _strategies:
        @staticmethod
        def integers(min_value=0, max_value=(1 << 30)):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    strategies = _strategies()

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strats):
        """Run the test ``_FALLBACK_EXAMPLES`` times with seeded draws."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for case in range(_FALLBACK_EXAMPLES):
                    rng = random.Random(f"{fn.__module__}.{fn.__name__}:{case}")
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)
            # pytest must not see the wrapped signature, or it would treat
            # the strategy kwargs as fixtures to inject
            del wrapper.__dict__["__wrapped__"]
            wrapper.__signature__ = inspect.Signature(
                p for p in inspect.signature(fn).parameters.values()
                if p.name not in strats)
            return wrapper
        return deco
