"""launch/spmd context: inert without activation, effective inside a mesh."""
import subprocess
import sys
import os
import textwrap

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.launch import spmd
from repro.models import transformer as T

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_inert_without_context():
    assert spmd.current() is None
    h = jnp.ones((2, 16, 8))
    out = spmd.constrain_seq(h)
    assert out is h                      # strict no-op on the default path


def test_forward_unchanged_by_flags_single_device():
    cfg = C.get_reduced("stablelm_12b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    a, _ = T.forward(params, cfg, tokens=toks)
    mesh = jax.sharding.Mesh(
        __import__("numpy").asarray(jax.devices()).reshape(1, 1),
        ("data", "model"))
    with mesh, spmd.activate(mesh, seq_shard=True, loss_chunk=8):
        b, _ = T.forward(params, cfg, tokens=toks)
    assert float(jnp.abs(a - b).max()) < 1e-6


def test_flash_flag_routes_attention():
    """With flash_attn=True the attention goes through the kernel path
    (numerics equal on CPU via the ref fallback in ops)."""
    cfg = C.get_reduced("phi3_medium_14b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    a, _ = T.forward(params, cfg, tokens=toks)
    mesh = jax.sharding.Mesh(
        __import__("numpy").asarray(jax.devices()).reshape(1, 1),
        ("data", "model"))
    with mesh, spmd.activate(mesh, flash_attn=True):
        b, _ = T.forward(params, cfg, tokens=toks)
    assert float(jnp.abs(a - b).max()) < 2e-4
