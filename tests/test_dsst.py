"""DSST: prune/regrow invariants + the paper's factorized-sorting claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro.core import sparsity as sp
from repro.core import dsst


SPEC = sp.NMSpec(2, 8)


def _mask_scores(seed, k=64, o=8, spec=SPEC):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    mask = sp.random_unit_mask(ks[0], spec, k, o)
    w = jnp.abs(jax.random.normal(ks[1], (k, o)))
    g = jnp.abs(jax.random.normal(ks[2], (k, o)))
    return mask, sp.unit_scores(w, spec, k, o), sp.unit_scores(g, spec, k, o)


def test_prune_regrow_keeps_nm():
    mask, ws, gs = _mask_scores(0)
    new, stats = dsst.prune_regrow(mask, ws, gs, SPEC, k=1)
    assert bool(sp.check_unit_mask(new, SPEC))
    assert int(stats.pruned) == int(stats.regrown)


def test_prune_drops_smallest_regrows_largest():
    spec = sp.NMSpec(2, 4)
    mask = jnp.array([[1], [1], [0], [0]], bool)          # one group, one col
    ws = jnp.array([[0.1], [5.0], [0.0], [0.0]])          # active scores
    gs = jnp.array([[0.0], [0.0], [9.0], [1.0]])          # inactive grads
    new, _ = dsst.prune_regrow(mask, ws, gs, spec, k=1)
    # smallest active (row 0) dropped; largest-grad inactive (row 2) added
    assert new[:, 0].tolist() == [False, True, True, False]


def test_factored_equals_dense_oracle_rank1():
    """The paper's neuron-level sorting == dense synapse-level sorting when
    the gradient is exactly rank-1 (g_ij = pre_i · post_j) — Fig. 5 claim."""
    for seed in range(10):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        mask = sp.random_unit_mask(ks[0], SPEC, 64, 8)
        w = jnp.abs(jax.random.normal(ks[1], (64, 8)))
        ws = sp.unit_scores(w, SPEC, 64, 8)
        pre = jnp.abs(jax.random.normal(ks[2], (64,))) + 0.01
        post = jnp.abs(jax.random.normal(jax.random.fold_in(ks[2], 1), (8,))) + 0.01
        dense_score = sp.unit_scores(jnp.outer(pre, post), SPEC, 64, 8)
        m_dense, _ = dsst.prune_regrow(mask, ws, dense_score, SPEC, k=1)
        m_fact, _ = dsst.prune_regrow_factored(mask, ws, pre, post, SPEC, k=1)
        assert bool((m_dense == m_fact).all()), f"seed {seed}"


def test_factored_sort_is_neuron_level():
    """One argsort of |pre| per group serves every output column."""
    pre = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (64,)))
    order = dsst.factored_group_order(pre, SPEC)
    assert order.shape == (8, 8)        # [G, m] — no output dimension
    grouped = np.asarray(pre).reshape(8, 8)
    for g in range(8):
        assert (np.argsort(-grouped[g], kind="stable") == np.asarray(order[g])).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(0, 1))
def test_property_dsst_event_preserves_nm(seed, k):
    mask, ws, gs = _mask_scores(seed)
    new, _ = dsst.prune_regrow(mask, ws, gs, SPEC, k=k)
    assert bool(sp.check_unit_mask(new, SPEC))


def test_apply_dsst_zeroes_regrown():
    mask, ws, gs = _mask_scores(3)
    w = jax.random.normal(jax.random.PRNGKey(9), (64, 8))
    new_mask, _ = dsst.prune_regrow(mask, ws, gs, SPEC, k=1)
    w2 = dsst.apply_dsst_to_weights(w, mask, new_mask, SPEC)
    regrown = sp.expand_unit_mask(new_mask & ~mask, SPEC, 64, 8)
    assert float(jnp.abs(jnp.where(regrown, w2, 0.0)).max()) == 0.0
    survived = sp.expand_unit_mask(new_mask & mask, SPEC, 64, 8)
    np.testing.assert_allclose(jnp.where(survived, w2 - w, 0.0), 0.0)


def test_maybe_dsst_period():
    spec = sp.NMSpec(2, 8)
    cfg = dsst.DSSTConfig(period=5, prune_frac=0.5)
    mask = sp.random_unit_mask(jax.random.PRNGKey(0), spec, 32, 4)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 4))
    acc = dsst.DSSTAccumulator.init(32, 4)
    acc = acc.update(jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (32,))),
                     jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4,))))
    w1, m1, _, did1 = dsst.maybe_dsst(3, cfg, spec, w, mask, acc)
    assert not bool(did1) and bool((m1 == mask).all())
    w2, m2, _, did2 = dsst.maybe_dsst(4, cfg, spec, w, mask, acc)
    assert bool(did2)
    assert bool(sp.check_unit_mask(m2, spec))


def test_maybe_dsst_respects_frac_decay_and_start_step():
    """Regression: the event's k used to ignore the step entirely, so
    frac_decay/start_step never changed the recycled count. (The jitted
    traced-step path is pinned in tests/test_topology.py.)"""
    spec = sp.NMSpec(4, 8)
    cfg = dsst.DSSTConfig(period=5, prune_frac=0.5, frac_decay=0.5,
                          start_step=5)
    mask = sp.random_unit_mask(jax.random.PRNGKey(0), spec, 32, 4)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 4))
    acc = dsst.DSSTAccumulator.init(32, 4)
    acc = acc.update(
        jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (32,))) + 0.01,
        jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4,))) + 0.01)
    g = 32 // spec.m
    # event 0 (step 9): k = round(4*0.5) = 2
    _, m0, _, did0 = dsst.maybe_dsst(9, cfg, spec, w, mask, acc)
    assert bool(did0)
    assert int((np.asarray(mask) & ~np.asarray(m0)).sum()) == 2 * g * 4
    # event 1 (step 14): k decayed to 1
    _, m1, _, did1 = dsst.maybe_dsst(14, cfg, spec, w, mask, acc)
    assert bool(did1)
    assert int((np.asarray(mask) & ~np.asarray(m1)).sum()) == 1 * g * 4
    # before start_step: no event at all
    _, m2, _, did2 = dsst.maybe_dsst(4, cfg, spec, w, mask, acc)
    assert not bool(did2)
