"""Substrate: optimizer, checkpointing, compression, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro.data.events import (DelayBuffer, make_task, pack_events,
                               unpack_events, TASK_NAMES)
from repro.data.pipeline import PipelineConfig, TokenPipeline, synthetic_lm_batch
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime.compression import (CompressionConfig, ErrorFeedback,
                                       compress, compressed_bytes, decompress)


# ---------------------------------------------------------------- optimizer

def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.array([3.0, -2.0]), "mask": jnp.array([1, 1], jnp.int32)}
    state = adamw_init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"], "mask": jnp.zeros((), jnp.float32)}
        params, state, _ = adamw_update(grads, params, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert params["mask"].dtype == jnp.int32          # untouched


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lr0 = float(cosine_schedule(cfg, jnp.asarray(0)))
    lr_peak = float(cosine_schedule(cfg, jnp.asarray(10)))
    lr_end = float(cosine_schedule(cfg, jnp.asarray(100)))
    assert lr0 < 0.2 and abs(lr_peak - 1.0) < 0.01 and abs(lr_end - 0.1) < 0.01


def test_update_scale_gates_layers():
    from repro.optim.sparse import gated_scale_tree
    params = {"layers": {"w": jnp.ones((4, 3, 3))}, "lm_head": jnp.ones((3, 3))}
    gates = jnp.array([1.0, 0.0, 1.0, 0.0])
    scale = gated_scale_tree(params, gates, None)
    assert scale["layers"]["w"].shape == (4, 1, 1)
    assert scale["lm_head"].shape == ()
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    st_ = adamw_init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    new, _, _ = adamw_update(grads, params, st_, cfg, update_scale=scale)
    moved = jnp.abs(new["layers"]["w"] - 1.0).reshape(4, -1).max(1)
    assert float(moved[0]) > 0 and float(moved[1]) == 0.0   # gated layer frozen


# ---------------------------------------------------------------- checkpoint

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 4)),
            "nested": {"b": jnp.arange(6).reshape(2, 3),
                       "c": [jnp.ones(2), jnp.zeros(3)]}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"data_pos": 123})
    step, back, extra = ckpt.restore(str(tmp_path), t)
    assert step == 7 and extra["data_pos"] == 123
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_keep_k_and_latest(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, t, keep=3)
    assert ckpt.list_steps(str(tmp_path)) == [3, 4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_corruption_falls_back(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, t)
    # corrupt the newest
    with open(os.path.join(str(tmp_path), "step_000000002", "arrays.npz"), "wb") as f:
        f.write(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1
    step, _, _ = ckpt.restore(str(tmp_path), t)
    assert step == 1


# ---------------------------------------------------------------- compression

@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_roundtrip_bounded(kind):
    cfg = CompressionConfig(kind=kind, topk_frac=0.2)
    g = jax.random.normal(jax.random.PRNGKey(0), (37, 53))
    rec = decompress(compress(g, cfg), cfg)
    assert rec.shape == g.shape
    if kind == "int8":
        assert float(jnp.abs(rec - g).max()) < float(jnp.abs(g).max()) / 100
    assert compressed_bytes(compress(g, cfg), cfg) < g.size * 4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), rows=st.integers(1, 40), cols=st.integers(1, 40))
def test_property_int8_error_bound(seed, rows, cols):
    cfg = CompressionConfig(kind="int8")
    g = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    rec = decompress(compress(g, cfg), cfg)
    # per-chunk absmax scaling bounds error by scale/2 = absmax/254
    assert float(jnp.abs(rec - g).max()) <= float(jnp.abs(g).max()) / 127 + 1e-6


def test_error_feedback_preserves_signal():
    """With EF, the *sum* of applied gradients tracks the true sum (topk alone
    would lose the small coordinates forever)."""
    cfg = CompressionConfig(kind="topk", topk_frac=0.1)
    g = {"w": jnp.linspace(0.01, 1.0, 64).reshape(8, 8)}
    ef = ErrorFeedback.init(g)
    applied = jnp.zeros((8, 8))
    for _ in range(30):
        rec, ef = ef.step(g, cfg)
        applied += rec["w"]
    true_sum = g["w"] * 30
    rel = float(jnp.abs(applied - true_sum).max() / true_sum.max())
    assert rel < 0.25   # EF lag is bounded; plain top-k would sit at 1.0
    # and compare against no-EF top-k: small coordinates never delivered
    plain = jnp.zeros((8, 8))
    for _ in range(30):
        plain += decompress(compress(g["w"], cfg), cfg)
    rel_plain = float(jnp.abs(plain - true_sum).max() / true_sum.max())
    assert rel < rel_plain


# ---------------------------------------------------------------- data

def test_pipeline_deterministic_and_restartable():
    cfg = PipelineConfig(vocab=101, seq_len=12, global_batch=4)
    p1 = TokenPipeline(cfg)
    seq = [next(p1) for _ in range(5)]
    state = p1.state()
    p2 = TokenPipeline.restore(cfg, {"next_step": 3})
    s3, b3 = next(p2)
    assert s3 == 3
    np.testing.assert_array_equal(b3["tokens"], seq[3][1]["tokens"])


def test_pipeline_host_shards_disjoint_deterministic():
    cfg = PipelineConfig(vocab=50, seq_len=8, global_batch=8)
    b0 = synthetic_lm_batch(cfg, 0, host_id=0, n_hosts=2)
    b1 = synthetic_lm_batch(cfg, 0, host_id=1, n_hosts=2)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    again = synthetic_lm_batch(cfg, 0, host_id=0, n_hosts=2)
    np.testing.assert_array_equal(b0["tokens"], again["tokens"])


def test_labels_are_shifted_tokens():
    cfg = PipelineConfig(vocab=97, seq_len=16, global_batch=2, noise=0.0)
    b = synthetic_lm_batch(cfg, 0)
    # affine recurrence: consistent chain (labels continue the token stream)
    t, l = b["tokens"][0], b["labels"][0]
    np.testing.assert_array_equal(t[1:], l[:-1])


@pytest.mark.parametrize("name", TASK_NAMES)
def test_event_tasks_valid(name):
    task = make_task(name, n_in=64, t_steps=20)
    ev, lab = task.sample(np.random.default_rng(0), 8)
    assert ev.shape == (20, 8, 64)
    assert set(np.unique(ev)).issubset({0.0, 1.0})
    assert lab.min() >= 0 and lab.max() < task.n_classes
    assert 0.005 < ev.mean() < 0.5   # plausible spike rates


def test_serdes_pack_roundtrip():
    rng = np.random.default_rng(0)
    spikes = (rng.random((10, 100)) < 0.2).astype(np.float32)
    packets = pack_events(spikes)
    assert packets.shape == (10, 4)   # ceil(100/30)
    back = unpack_events(packets, 100)
    np.testing.assert_array_equal(spikes, back)


def test_delay_buffer_taps():
    buf = DelayBuffer(4, depth=4)
    x1 = np.array([1.0, 0, 0, 0], np.float32)
    out1 = buf.push(x1)
    np.testing.assert_allclose(out1, x1)
    out2 = buf.push(np.zeros(4, np.float32))
    np.testing.assert_allclose(out2, 0.5 * x1)   # echo from the delay slot
