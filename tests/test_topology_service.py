"""Live topology evolution under serving traffic (serving/topology_service).

The acceptance properties of the DSST-under-traffic tentpole:

* a fleet with the service attached completes prune/regrow epochs under
  live traffic with exactly ONE chunk-step compilation;
* a serve trajectory across topology swaps is bit-identical to a
  drain-and-restart reference (the same chunks driven through ``run_chunk``
  by hand, with the same evolve applied offline between chunk calls);
* surviving connections keep their delta bits across every swap, and the
  exactly-N-per-group invariant holds after every epoch;
* hot-stream folding promotes a lane's delta into the shared base without
  changing that lane's effective weights (merge_weight=1.0), via the
  generic (future-key-preserving) ``merge_lane_into_base``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.core.dsst import DSSTConfig
from repro.core.snn import (SNNConfig, init_params, init_stream_deltas,
                            init_stream_state)
from repro.serving import (AdaptConfig, FleetTelemetry, ReplaySource,
                           StreamScheduler, StreamSession, TopologyService,
                           TopologyServiceConfig, make_chunk_fn,
                           merge_lane_into_base)

CFG = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=12,
                dsst=DSSTConfig(period=4, prune_frac=0.5))
CHUNK = 6


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _events(seed, t, rate=0.3):
    rng = np.random.default_rng(seed)
    return (rng.random((t, CFG.n_in)) < rate).astype(np.float32)


# --------------------------------------------------------------- lifecycle

def test_epochs_complete_under_traffic_one_compile(params):
    svc = TopologyService(CFG, TopologyServiceConfig(epoch_every=3,
                                                     merge_top=1))
    sched = StreamScheduler(params, CFG, n_slots=2, chunk_len=CHUNK,
                            topology=svc)
    for sid in range(2):
        sched.submit(StreamSession(
            sid=sid, source=ReplaySource(_events(sid, 9 * CHUNK),
                                         chunk_len=CHUNK)))
    done = sched.run_until_drained()
    assert len(done) == 2
    assert svc.epoch_idx >= 2, "no topology epochs ran under traffic"
    assert sched.n_compiles == 1, "topology swap recompiled the chunk step"
    # connectivity actually churned, and the invariant held every epoch
    # (svc.evolve asserts it; re-check the final state from outside)
    assert sum(e.pruned for e in svc.events) > 0
    assert topology.check(sched.params["hidden"]["mask"], CFG)
    # the evolved base no longer equals the boot params
    assert (np.asarray(sched.params["hidden"]["mask"])
            != np.asarray(params["hidden"]["mask"])).any()
    # telemetry mirrored the service's event log
    r = sched.telemetry.rollup()
    assert r["topology_epochs"] == len(svc.events)
    assert r["topology_pruned"] == sum(e.pruned for e in svc.events)
    assert r["streams_merged"] == sum(len(e.merged_slots) for e in svc.events)
    # streams kept producing predictions across the swaps
    for s in done:
        assert len(s.predictions) == 9 * CHUNK // CFG.t_steps


def test_swap_matches_drain_and_restart_reference(params):
    """Scheduler with live swaps == hand-driven run_chunk with the same
    evolve applied offline between chunk calls: deltas, carried state and
    every window prediction agree BIT-exactly."""
    n_streams, n_steps = 2, 9
    evs = [_events(10 + s, n_steps * CHUNK, rate=0.3 + 0.05 * s)
           for s in range(n_streams)]
    svc_cfg = TopologyServiceConfig(epoch_every=3, merge_top=1)

    # ---- live: scheduler + service, swaps under traffic
    svc = TopologyService(CFG, svc_cfg)
    sched = StreamScheduler(params, CFG, n_slots=n_streams, chunk_len=CHUNK,
                            topology=svc)
    for sid in range(n_streams):
        sched.submit(StreamSession(
            sid=sid, source=ReplaySource(evs[sid], chunk_len=CHUNK)))
    done = {s.sid: s for s in sched.run_until_drained()}
    assert svc.epoch_idx >= 2 and sched.n_compiles == 1

    # ---- reference: drain-and-restart — drive the same chunks through
    # run_chunk directly; at each epoch boundary stop, apply the evolve
    # offline (fresh service instance, same config), and continue from the
    # carried state with the swapped (params, deltas)
    ref_svc = TopologyService(CFG, svc_cfg)
    fn = make_chunk_fn(CFG, AdaptConfig())
    p = params
    st = init_stream_state(CFG, n_streams)
    dl = init_stream_deltas(CFG, n_streams)
    amask = np.ones(n_streams, bool)
    ref_preds = {s: [] for s in range(n_streams)}
    for i in range(n_steps):
        events = np.zeros((CHUNK, n_streams, CFG.n_in), np.float32)
        valid = np.zeros((CHUNK, n_streams), bool)
        for s in range(n_streams):
            events[:, s] = evs[s][i * CHUNK:(i + 1) * CHUNK]
            valid[:, s] = True
        dl, st, m = fn(p, dl, st, events, jnp.asarray(valid), amask)
        m = jax.device_get(m)
        for s in range(n_streams):
            for t in np.nonzero(m.window_end[:, s])[0]:
                ref_preds[s].append(m.logits[t, s].copy())
        ref_svc.observe(m)
        grid_step = i + 1
        # sessions retire before the evolve on their final step
        active = tuple(s for s in range(n_streams)
                       if (i + 1) * CHUNK < evs[s].shape[0])
        if ref_svc.due(grid_step):
            p, dl, _ = ref_svc.evolve(p, dl, merge_slots=active,
                                      grid_step=grid_step)

    # identical epoch history
    assert [e.pruned for e in ref_svc.events] == \
        [e.pruned for e in svc.events]
    assert [e.merged_slots for e in ref_svc.events] == \
        [e.merged_slots for e in svc.events]
    # bit-identical params, deltas, predictions
    for a, b in zip(jax.tree_util.tree_leaves(sched.params),
                    jax.tree_util.tree_leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(sched.deltas), np.asarray(dl))
    for sid in range(n_streams):
        got = done[sid].predictions
        assert len(got) == len(ref_preds[sid]) > 0
        for a, b in zip(got, ref_preds[sid]):
            np.testing.assert_array_equal(a.logits, b)


def test_deltas_bit_exact_across_swap(params):
    """Service-level pin of the projection property: one evolve on live
    accumulated factors keeps surviving delta bits and zeroes the rest."""
    svc = TopologyService(CFG, TopologyServiceConfig(epoch_every=1))
    fn = make_chunk_fn(CFG, AdaptConfig())
    st = init_stream_state(CFG, 2)
    dl = init_stream_deltas(CFG, 2)
    ev = _events(21, CFG.t_steps)[:, None, :].repeat(2, 1)
    valid = jnp.ones((CFG.t_steps, 2), bool)
    dl, st, m = fn(params, dl, st, ev, valid, np.ones(2, bool))
    svc.observe(jax.device_get(m))
    assert float(jnp.abs(dl).max()) > 0, "no adaptation accumulated"

    old_mask = params["hidden"]["mask"]
    p2, dl2, event = svc.evolve(params, dl, grid_step=1)
    assert event.pruned > 0
    # deltas are compact [S, L, J, T, bk, bo]; densify each side over its
    # own mask's kept-block ids for the dense survivor comparison
    from repro.core import engine
    dl_dense = np.asarray(engine.densify_deltas(
        dl, topology.stacked_kept_ids(old_mask, CFG), CFG))
    dl2_dense = np.asarray(engine.densify_deltas(
        dl2, topology.stacked_kept_ids(p2["hidden"]["mask"], CFG), CFG))
    surv = np.asarray(topology.survivors_dense(
        old_mask, p2["hidden"]["mask"], CFG))
    np.testing.assert_array_equal(dl2_dense[:, surv], dl_dense[:, surv])
    assert np.all(dl2_dense[:, ~surv] == 0.0)


def test_frozen_config_never_evolves(params):
    """Serve honors the same connectivity freeze as train: dsst_enabled off,
    the dense baseline, and the RigL-style stop_step cool-down all make the
    service inert (and evolve() fails fast instead of churning anyway)."""
    import dataclasses
    for frozen_cfg in (
            dataclasses.replace(CFG, dsst_enabled=False),
            dataclasses.replace(CFG, dense=True),
            dataclasses.replace(CFG, dsst=DSSTConfig(
                period=4, prune_frac=0.5, stop_step=0))):
        svc = TopologyService(frozen_cfg, TopologyServiceConfig(epoch_every=1))
        svc.observed_steps = 100.0
        assert svc.frozen and not svc.due(10)
        with pytest.raises(ValueError, match="frozen"):
            svc.evolve(params, init_stream_deltas(frozen_cfg, 2), grid_step=1)
    # a live config crosses stop_step mid-serve: epochs stop there
    cfg = dataclasses.replace(CFG, dsst=DSSTConfig(
        period=4, prune_frac=0.5, stop_step=5))
    svc = TopologyService(cfg, TopologyServiceConfig(epoch_every=1))
    assert not svc.frozen                      # epoch 0: virtual step 0
    svc.epoch_idx = 2                          # virtual step 8 >= stop_step
    assert svc.frozen and not svc.due(100)


def test_no_epoch_without_traffic(params):
    """An idle fleet must not churn its topology on all-zero scores."""
    svc = TopologyService(CFG, TopologyServiceConfig(epoch_every=1))
    sched = StreamScheduler(params, CFG, n_slots=2, chunk_len=CHUNK,
                            topology=svc)
    for _ in range(3):
        sched.step()       # no sessions: all slots idle
    assert svc.epoch_idx == 0 and svc.events == []
    np.testing.assert_array_equal(np.asarray(sched.params["hidden"]["mask"]),
                                  np.asarray(params["hidden"]["mask"]))


# --------------------------------------------------------------- folding

def test_fold_hot_stream_exact_and_generic(params):
    """merge_weight=1: the hot lane's delta moves into the base and its
    lane delta zeroes — the lane's effective weights are unchanged bits.
    With prune_frac rounding k to 0 the epoch's mask is untouched, so the
    fold is isolated. merge_lane_into_base preserves unknown params keys."""
    cfg = SNNConfig(n_in=32, n_hidden=32, n_layers=2, n_out=8, t_steps=12,
                    dsst=DSSTConfig(period=4, prune_frac=0.01))  # k = 0
    p = init_params(jax.random.PRNGKey(1), cfg)
    svc = TopologyService(cfg, TopologyServiceConfig(epoch_every=1,
                                                     merge_top=1))
    fn = make_chunk_fn(cfg, AdaptConfig())
    st = init_stream_state(cfg, 2)
    dl = init_stream_deltas(cfg, 2)
    ev = _events(31, cfg.t_steps, rate=0.4)[:, None, :].repeat(2, 1)
    dl, st, m = fn(p, dl, st, ev, jnp.ones((cfg.t_steps, 2), bool),
                   np.array([True, False]))      # lane 1 frozen: delta 0
    svc.observe(jax.device_get(m))
    assert float(jnp.abs(dl[0]).max()) > 0

    # deltas are compact [S, L, J, T, bk, bo] — they live only on kept
    # blocks by construction, so densifying over the base mask's kept ids
    # IS the masked dense delta
    from repro.core import engine
    dl_dense = np.asarray(engine.densify_deltas(
        dl, topology.stacked_kept_ids(p["hidden"]["mask"], cfg), cfg))
    want_w = np.asarray(p["hidden"]["w"]) + dl_dense[0]
    p2, dl2, event = svc.evolve(p, dl, merge_slots=(0,), grid_step=1)
    assert event.merged_slots == (0,) and event.pruned == 0
    np.testing.assert_array_equal(np.asarray(p2["hidden"]["mask"]),
                                  np.asarray(p["hidden"]["mask"]))
    np.testing.assert_allclose(np.asarray(p2["hidden"]["w"]), want_w,
                               atol=0, rtol=0)
    assert np.all(np.asarray(dl2[0]) == 0.0)     # promoted, lane reset

    # generic pytree update: future keys survive the merge (regression for
    # the hand-rolled dict rebuild that silently dropped them)
    fat = {**p, "aux_head": jnp.ones(3),
           "hidden": {**p["hidden"], "scales": jnp.ones(2)}}
    out = merge_lane_into_base(fat, dl, 0, cfg)
    assert "aux_head" in out and "scales" in out["hidden"]


# --------------------------------------------------------------- telemetry

def test_topology_telemetry_unit():
    tel = FleetTelemetry()
    assert tel.rollup()["topology_epochs"] == 0
    tel.record_topology_epoch(grid_step=10, pruned=24, regrown=24,
                              mask_change=0.125, merged_streams=2)
    tel.record_topology_epoch(grid_step=20, pruned=12, regrown=12,
                              mask_change=0.0625, merged_streams=0)
    r = tel.topology_rollup()
    assert r["topology_epochs"] == 2
    assert r["topology_pruned"] == 36 and r["topology_regrown"] == 36
    assert r["streams_merged"] == 2
    np.testing.assert_allclose(r["topology_mask_change_mean"], 0.09375)
    # the fleet rollup carries the same keys
    assert tel.rollup()["topology_epochs"] == 2
