"""Doc health: every ```python code block in README.md and docs/*.md runs.

The extractor executes each file's python blocks top-to-bottom in one
shared namespace (so a later block may use names an earlier one defined,
exactly as a reader follows the page). Blocks whose fence info string
contains ``noexec`` (e.g. ```` ```python noexec ````) are illustration
only — multi-device or production-scale sketches — and are skipped but
still counted, so the convention itself is visible here.

This is the CI tripwire that keeps the docs subsystem honest: a doc
snippet that stops compiling or asserts false fails the build instead of
rotting quietly.
"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

_FENCE = re.compile(r"^```(\w+)([^\n]*)\n(.*?)^```\s*$", re.M | re.S)


def extract_blocks(path: pathlib.Path):
    """[(lineno, info, source)] for every fenced ``python`` block."""
    text = path.read_text()
    out = []
    for m in _FENCE.finditer(text):
        lang, info, body = m.group(1), m.group(2).strip(), m.group(3)
        if lang != "python":
            continue
        lineno = text[: m.start()].count("\n") + 2   # first line of the body
        out.append((lineno, info, body))
    return out


def test_doc_files_exist_and_carry_executable_snippets():
    """The docs subsystem's floor: the guides exist and each contributes
    at least one *executed* (non-noexec) python block — if every snippet
    were opted out, this extractor would be checking nothing."""
    for name in ("ARCHITECTURE.md", "SERVING.md", "OBSERVABILITY.md",
                 "ANALYSIS.md"):
        path = ROOT / "docs" / name
        assert path.exists(), f"docs/{name} missing"
        blocks = extract_blocks(path)
        live = [b for b in blocks if "noexec" not in b[1]]
        assert live, f"docs/{name} has no executed python snippets"
    assert any("noexec" not in b[1] for b in extract_blocks(ROOT / "README.md"))


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_snippets_execute(path):
    blocks = extract_blocks(path)
    if not blocks:
        pytest.skip(f"{path.name}: no python blocks")
    ns: dict = {"__name__": f"docsnippet_{path.stem}"}
    ran = 0
    for lineno, info, src in blocks:
        if "noexec" in info:
            continue
        code = compile(src, f"{path.name}:{lineno}", "exec")
        try:
            exec(code, ns)
        except Exception as e:   # pragma: no cover - failure reporting
            raise AssertionError(
                f"{path.name} code block at line {lineno} failed: "
                f"{type(e).__name__}: {e}") from e
        ran += 1
    if not ran:
        pytest.skip(f"{path.name}: all python blocks are noexec")
