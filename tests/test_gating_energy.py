"""Gating engine unit tests + the energy model's paper-constant arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gating as G
from repro.core import energy as E


def test_gate_opens_for_novel_closes_for_repeat():
    cfg = G.GatingConfig()
    st = G.init_state(1, cfg)
    # novel: low similarity
    open1, lg = G.gate_update(st, 0, jnp.float32(0.1), jnp.float32(0.1), cfg)
    assert bool(open1)
    st = G.merge(st, [lg])
    # drive the running mean down with low-SS samples, then present a repeat
    for _ in range(20):
        _, lg = G.gate_update(st, 0, jnp.float32(0.1), jnp.float32(0.2), cfg)
        st = G.merge(st, [lg])
    open2, _ = G.gate_update(st, 0, jnp.float32(0.1), jnp.float32(0.95), cfg)
    assert not bool(open2)


def test_gate_ia_threshold():
    cfg = G.GatingConfig(theta_ia=0.05)
    st = G.init_state(1, cfg)
    open_, _ = G.gate_update(st, 0, jnp.float32(0.01), jnp.float32(0.0), cfg)
    assert not bool(open_)      # silent input -> skip regardless of SS


def test_gate_batch_matches_scalar():
    cfg = G.GatingConfig()
    st = G.init_state(3, cfg)
    ia = jnp.array([0.1, 0.001, 0.2])
    ss = jnp.array([0.0, 0.0, 2.0])
    open_, st2 = G.gate_batch(st, ia, ss, cfg)
    assert open_.tolist() == [1.0, 0.0, 0.0]
    assert abs(float(G.skip_rate(st2)) - (1 - 1 / 3)) < 1e-5


def test_energy_report_paper_constants():
    """2.4 pJ/SOP @0.6 V: 1 MSOP/s ≈ 2.5 µW dynamic (+17 bits SRAM read)
    on top of the 8 µW leakage."""
    op = E.OperatingPoint.low_power()
    rep = E.EnergyReport(sop_forward=1e3, sop_wu=0, sop_wu_offered=0,
                         duration_s=1e-3, op=op)
    dyn_uw = rep.e_forward_j / 1e-3 * 1e6
    assert 2.3 < dyn_uw < 2.6
    assert abs(rep.power_w * 1e6 - (dyn_uw + 8.0)) < 0.1


def test_energy_wu_skip_rate():
    rep = E.report(sop_forward=1e6, sop_wu=3e5, sop_wu_offered=1e6,
                   n_timesteps=50)
    assert abs(rep.wu_skip_rate - 0.7) < 1e-6
    d = rep.as_dict()
    assert d["power_uW"] > 0 and d["e_per_sop_pJ"] == 2.4


def test_nce_matches_paper_table():
    """Table I: NCE = 1040 neurons... ElfCore reports 1926 with max scale
    (512+512+512+16 = 1552? — the paper uses max NN scale / (area × pJ/SOP);
    we check our formula reproduces the paper's own figure within rounding
    using its published numbers."""
    # 0.62 mm^2 core, 2.4 pJ/SOP, NCE=1926 -> implied scale ≈ 2866... the
    # paper's 'Max NN scale' counts synaptic capacity units; we verify the
    # formula's *relative* ordering vs ANP-I and ReckOn instead.
    ours = E.network_capacity_efficiency(2866, 0.62, 2.4)
    anp = E.network_capacity_efficiency(1546, 1.25, 1.5)
    reckon = E.network_capacity_efficiency(784, 0.45, 5.3)
    assert ours > anp > reckon   # Table I ordering: 1926 > 825 > 328
