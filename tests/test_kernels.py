"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity as sp
from repro.kernels.nm_spmm import ops as nm_ops, ref as nm_ref
from repro.kernels.nm_spmm.kernel import nm_spmm_pallas
from repro.kernels.lif import ops as lif_ops, ref as lif_ref
from repro.kernels.lif.kernel import lif_pallas
from repro.kernels.wu_outer import ref as wu_ref
from repro.kernels.wu_outer.kernel import wu_outer_pallas


def _mk_sparse(seed, k, o, bk, bo, n, m, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    spec = sp.NMSpec(n=n, m=m, block=bk, out_tile=bo)
    mask = sp.random_unit_mask(ks[0], spec, k, o)
    w = jax.random.normal(ks[1], (k, o)).astype(dtype)
    wc, idx = nm_ops.make_compact(w, mask, bk, bo)
    x = jax.random.normal(ks[2], (16, k)).astype(dtype)
    return x, w, wc, idx, mask, spec


NM_CASES = [
    # (k, o, bk, bo, n, m, bm)
    (32, 16, 4, 8, 2, 4, 8),
    (64, 32, 8, 16, 1, 2, 16),
    (128, 128, 16, 32, 2, 8, 8),
    (48, 24, 4, 8, 3, 4, 4),
]


@pytest.mark.parametrize("k,o,bk,bo,n,m,bm", NM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nm_spmm_kernel_vs_refs(k, o, bk, bo, n, m, bm, dtype):
    x, w, wc, idx, mask, spec = _mk_sparse(0, k, o, bk, bo, n, m, dtype)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    y_k = nm_spmm_pallas(x, wc, idx, bm=bm, interpret=True)
    y_r = nm_ref.nm_spmm(x, wc, idx)
    y_d = nm_ref.nm_spmm_dense_ref(x, wc, idx)
    y_m = x @ sp.apply_mask(w, mask, spec)
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_r, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(y_r, np.float32), np.asarray(y_d, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(y_r, np.float32), np.asarray(y_m, np.float32),
                               atol=tol, rtol=tol)


def test_nm_spmm_custom_vjp_matches_dense_autodiff():
    x, w, wc, idx, mask, spec = _mk_sparse(1, 64, 32, 8, 16, 1, 2, jnp.float32)
    dy = jax.random.normal(jax.random.PRNGKey(7), (16, 32))

    f = lambda x_, wc_: (nm_ops.nm_spmm(x_, wc_, idx) * dy).sum()
    gx, gwc = jax.grad(f, argnums=(0, 1))(x, wc)
    fd = lambda x_, wd_: ((x_ @ wd_) * dy).sum()
    gxd, gwd = jax.grad(fd, argnums=(0, 1))(x, nm_ref.densify(wc, idx, 64))
    gwd_c, _ = nm_ops.make_compact(gwd, mask, 8, 16)
    np.testing.assert_allclose(gx, gxd, atol=1e-5)
    np.testing.assert_allclose(gwc, gwd_c, atol=1e-5)


def test_nm_spmm_flop_scaling():
    """Kernel work scales with n/m: the compact layout only visits kept blocks."""
    _, _, wc, idx, _, _ = _mk_sparse(0, 128, 128, 16, 32, 2, 8, jnp.float32)
    assert wc.shape[1] == idx.shape[1] == 2 * (128 // 16 // 8)   # G*n kept blocks
    assert wc.size == 128 * 128 * 2 // 8                         # density × dense


@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (8, 250), (5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lif_kernel_sweep(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    v = jax.random.normal(ks[0], shape).astype(dtype)
    tr = jax.random.uniform(ks[1], shape).astype(dtype)
    cur = jax.random.normal(ks[2], shape).astype(dtype)
    kw = dict(alpha=0.9, beta=0.85, theta=1.0)
    got = lif_ops.lif_step(v, tr, cur, force_pallas=True, interpret=True, **kw)
    want = lif_ref.lif_step(v, tr, cur, **kw)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), atol=1e-4)


def test_lif_kernel_direct_tiles():
    v = jax.random.normal(jax.random.PRNGKey(0), (16, 256))
    tr = jnp.zeros((16, 256))
    cur = jax.random.normal(jax.random.PRNGKey(1), (16, 256))
    a = lif_pallas(v, tr, cur, alpha=0.5, beta=0.9, theta=0.7, bm=8, bn=128,
                   interpret=True)
    b = lif_ref.lif_step(v, tr, cur, alpha=0.5, beta=0.9, theta=0.7)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=1e-5)


@pytest.mark.parametrize("b,k,o,bk,bo,bb", [(8, 32, 16, 4, 8, 4),
                                            (16, 64, 32, 8, 16, 8),
                                            (4, 16, 8, 4, 8, 4)])
def test_wu_outer_sweep(b, k, o, bk, bo, bb):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    spec = sp.NMSpec(n=1, m=2, block=bk, out_tile=bo)
    mask = sp.random_unit_mask(ks[0], spec, k, o)
    _, idx = nm_ops.make_compact(jnp.zeros((k, o)), mask, bk, bo)
    pre = jax.random.normal(ks[1], (b, k))
    mod = jax.random.normal(ks[2], (b, o))
    scale = jnp.float32(0.05)
    got = wu_outer_pallas(pre, mod, idx, scale, bk=bk, bo=bo, bb=bb, interpret=True)
    want = wu_ref.wu_outer(pre, mod, idx, scale, bk, bo)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_wu_outer_gate_zero_is_noop():
    """A gated-off layer's WU is exactly zero (the skip the chip doesn't pay for)."""
    spec = sp.NMSpec(n=1, m=2, block=4, out_tile=8)
    mask = sp.random_unit_mask(jax.random.PRNGKey(0), spec, 16, 8)
    _, idx = nm_ops.make_compact(jnp.zeros((16, 8)), mask, 4, 8)
    pre = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    mod = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    out = wu_ref.wu_outer(pre, mod, idx, jnp.float32(0.0), 4, 8)
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# flash attention (kernels/flash_attn): fwd + bwd vs ref, causal + SWA + GQA
# ---------------------------------------------------------------------------
from repro.kernels.flash_attn import ops as fa_ops, ref as fa_ref
from repro.kernels.flash_attn.kernel import flash_fwd


@pytest.mark.parametrize("b,s,h,kv,dh,bq,bk", [
    (2, 32, 4, 2, 16, 8, 8),
    (1, 64, 2, 2, 32, 16, 16),
    (2, 16, 4, 1, 8, 16, 16),   # single kv head (MQA), one tile
])
@pytest.mark.parametrize("window", [None, 8])
def test_flash_attention_fwd_sweep(b, s, h, kv, dh, bq, bk, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    want = fa_ref.attention(q, k, v, window)
    qk, kk, vk = fa_ops._to_kernel_layout(q, k, v)
    o, lse = flash_fwd(qk, kk, vk, bq=bq, bk=bk, window=window, interpret=True)
    got = fa_ops._from_kernel_layout(o, b, s, h, dh)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    assert bool(jnp.isfinite(lse).all())


@pytest.mark.parametrize("window", [None, 8])
def test_flash_attention_bwd_matches_autodiff(window):
    b, s, h, kv, dh = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    dout = jax.random.normal(ks[3], (b, s, h, dh))
    g_ref = jax.grad(lambda *a: (fa_ref.attention(*a, window) * dout).sum(),
                     argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(lambda *a: (fa_ops.flash_attention(*a, window, True, True)
                                * dout).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, c in zip(g_ref, g_fl):
        np.testing.assert_allclose(a, c, atol=5e-5, rtol=5e-5)


def test_flash_attention_model_path():
    """attn_full_flash == attn_full on the model layout."""
    import repro.configs as C
    from repro.models import layers as L
    cfg = C.get_reduced("phi3_medium_14b")
    p = L.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32, None)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    ang = L.rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    want, _ = L.attn_full(p, x, ang, cfg)
    got, _ = L.attn_full_flash(p, x, ang, cfg, interpret=True, force_pallas=True)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_flash_hbm_traffic_model():
    """BlockSpec-exact traffic is far below the unfused score path and scales
    ~linearly in S for fixed tiles (per q-tile k/v re-reads)."""
    from repro.kernels.flash_attn.ops import hbm_bytes, xla_score_path_bytes
    fl = hbm_bytes(16, 4096, 4, 128)
    xla = xla_score_path_bytes(16, 4096, 4, 128)
    assert fl < xla / 5
    assert hbm_bytes(16, 8192, 4, 128) < 5 * hbm_bytes(16, 4096, 4, 128)
