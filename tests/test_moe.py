"""MoE dispatch: routing correctness, capacity behaviour, FLOP scaling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import moe as MOE
from repro.models import transformer as T


def _cfg(**kw):
    import dataclasses
    cfg = C.get_reduced("mixtral_8x7b")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def test_capacity_formula():
    cfg = _cfg()
    c = MOE.capacity(1024, cfg)
    expect = 1024 * cfg.moe_top_k * cfg.moe_capacity_factor / cfg.moe_experts
    assert c >= expect and c % 8 == 0


def test_moe_matches_dense_gather_reference():
    """Scatter-dispatch output == straightforward per-token expert mixture
    (when nothing is dropped)."""
    import dataclasses
    cfg = dataclasses.replace(_cfg(), moe_capacity_factor=8.0)  # no drops
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = MOE.moe_apply(p, x, cfg)
    assert float(aux["moe_dropped"]) == 0.0

    # reference: run every token through its top-k experts directly
    flat = x.reshape(-1, cfg.d_model)
    logits = flat @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eids = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(flat)
    for t in range(flat.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe_top_k):
            e = int(eids[t, j])
            h = flat[t] @ p["w1"]["w"][e]
            h = jax.nn.silu(h) * (flat[t] @ p["w3"]["w"][e])
            acc += gate[t, j] * (h @ p["w2"]["w"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(out.reshape(-1, cfg.d_model), ref,
                               atol=2e-4, rtol=2e-4)


def test_overflow_drops_not_corrupts():
    import dataclasses
    cfg = dataclasses.replace(_cfg(), moe_capacity_factor=0.25)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = MOE.moe_apply(p, x, cfg)
    assert float(aux["moe_dropped"]) > 0.0
    assert not bool(jnp.isnan(out).any())


def test_load_balance_loss_range():
    cfg = _cfg()
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, aux = MOE.moe_apply(p, x, cfg)
    # perfectly balanced -> 1.0; pathological -> up to E
    assert 0.9 < float(aux["moe_aux"]) < cfg.moe_experts
    np.testing.assert_allclose(float(aux["moe_load"].sum()), 1.0, atol=1e-5)


def test_moonshot_ep_decode():
    cfg = C.get_reduced("moonshot_v1_16b_a3b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, 2, 8)
    lg, cache = T.decode_step(params, cache, jnp.zeros((2,), jnp.int32), cfg)
    assert lg.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(lg).any())
