"""End-to-end behaviour of the whole system: the paper's three engines
working together, on both the chip-scale SNN and the LM-scale framework."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import SparsityConfig
from repro.configs.elfcore_snn import reduced as snn_reduced
from repro.core.gating import GatingConfig
from repro.core.snn import (accuracy, init_params, init_state, make_eval_fn,
                            make_train_fn)
from repro.core import sparsity as sp
from repro.data.events import make_task
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.train import TrainHParams, run_training
from repro.optim import AdamWConfig


def test_elfcore_system_end_to_end():
    """OSSL + DSST + gating + SL readout learn a stream online: accuracy
    above chance, masks exactly N:M throughout, gates actually skip."""
    import dataclasses
    cfg = dataclasses.replace(snn_reduced(t_steps=16), n_out=10)
    task = make_task("nmnist", n_in=cfg.n_in, t_steps=cfg.t_steps)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(cfg, batch=16)
    step = make_train_fn(cfg)
    rng = np.random.default_rng(0)
    for i in range(60):
        ev, lab = task.sample(rng, 16)
        params, state, m = step(params, state, jnp.asarray(ev), jnp.asarray(lab))
    # masks exact N:M after multiple DSST events
    from repro.core import engine
    for l, fan_in in enumerate(cfg.layer_fanins):
        _, mask = engine.hidden_slice(params, l, cfg)
        assert bool(sp.check_unit_mask(mask, cfg.spec(fan_in)))
    # gate engine skipped something on a repeating stream
    assert float(m.gate_open_frac) < 1.0
    # readout above chance on held-out data
    ev, lab = task.sample(np.random.default_rng(99), 64)
    _, me = make_eval_fn(cfg)(params, init_state(cfg, batch=64), jnp.asarray(ev))
    assert float(accuracy(me.logits, jnp.asarray(lab))) > 0.3   # chance 0.1


def test_lm_framework_end_to_end():
    """The same three engines as LM training features: N:M masked MLPs with
    DSST, gated AdamW updates — loss decreases, invariants hold."""
    cfg = C.get_reduced("phi3_medium_14b").with_sparsity(
        SparsityConfig(n=1, m=2, block=8, targets=("mlp",), mode="masked"))
    hp = TrainHParams(opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100),
                      gating=GatingConfig(), dsst_every=10)
    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=8))
    (params, _, _), hist = run_training(cfg, hp, pipe, 35, log_every=5)
    assert hist["loss"][-1] < hist["loss"][0] - 0.3
    um = params["layers"]["mlp"]["w1"]["umask"]
    counts = um.reshape(um.shape[0], -1, 2, um.shape[-1]).sum(2)
    assert bool((counts == 1).all())   # exactly 1-of-2 after DSST events
