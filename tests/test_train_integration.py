"""End-to-end training integration: loss goes down; paper add-ons behave."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import SparsityConfig
from repro.core.gating import GatingConfig
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.train import (TrainHParams, init_train_state,
                                make_train_step, run_training)
from repro.optim import AdamWConfig


def _tiny_cfg(sparsity=None):
    cfg = C.get_reduced("stablelm_12b")
    if sparsity:
        cfg = cfg.with_sparsity(sparsity)
    return cfg


def _run(cfg, hp, steps=40, seq=32, batch=8):
    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=seq,
                                        global_batch=batch))
    (_, _, _), hist = run_training(cfg, hp, pipe, steps, log_every=5)
    return hist


def test_backprop_loss_decreases():
    hp = TrainHParams(opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200))
    hist = _run(_tiny_cfg(), hp)
    assert hist["loss"][-1] < hist["loss"][0] - 0.5


def test_sparse_masked_training_works():
    sp = SparsityConfig(n=1, m=2, block=8, targets=("mlp",), mode="masked")
    hp = TrainHParams(opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200),
                      dsst_every=10)
    hist = _run(_tiny_cfg(sp), hp)
    assert hist["loss"][-1] < hist["loss"][0] - 0.4


def test_gating_saves_updates_without_divergence():
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200)
    hist_g = _run(_tiny_cfg(), TrainHParams(opt=opt, gating=GatingConfig()))
    hist_n = _run(_tiny_cfg(), TrainHParams(opt=opt))
    # gating must not explode the loss (small regression allowed)
    assert hist_g["loss"][-1] < hist_n["loss"][0]
    assert hist_g["loss"][-1] < hist_g["loss"][0]


def test_local_mode_trains():
    cfg = _tiny_cfg()
    hp = TrainHParams(mode="local",
                      opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200))
    hist = _run(cfg, hp, steps=40)
    assert hist["loss"][-1] < hist["loss"][0]


def test_resume_from_checkpoint_identical(tmp_path):
    cfg = _tiny_cfg()
    hp = TrainHParams(opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100))
    mk = lambda: TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=16,
                                              global_batch=4))
    # uninterrupted
    (_, _, _), h_ref = run_training(cfg, hp, mk(), 20, log_every=1)
    # interrupted at 12 (ckpt at 9), then resumed to 20 in a new call
    d = str(tmp_path / "ck")
    run_training(cfg, hp, mk(), 12, ckpt_dir=d, ckpt_every=10, log_every=1)
    pipe2 = mk()
    for _ in range(10):      # a real restart replays the pipeline position
        next(pipe2)
    (_, _, _), h_res = run_training(cfg, hp, pipe2, 20, ckpt_dir=d,
                                    ckpt_every=10, log_every=1)
    np.testing.assert_allclose(h_ref["loss"][-1], h_res["loss"][-1],
                               rtol=2e-4, atol=2e-4)


def test_serve_generate_greedy():
    from repro.launch.serve import generate
    cfg = _tiny_cfg()
    hp = TrainHParams()
    params, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, hp)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    out = generate(params, cfg, prompt, n_new=5)
    assert out.shape == (2, 11)
    assert bool((out[:, :6] == prompt).all())
