"""N:M mask invariants — unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, strategies as st

from repro.core import sparsity as sp


def _spec_cases():
    return [sp.NMSpec(1, 4), sp.NMSpec(2, 8), sp.NMSpec(2, 4, block=4, out_tile=8),
            sp.NMSpec(1, 2, block=8, out_tile=16), sp.NMSpec(3, 4)]


@pytest.mark.parametrize("spec", _spec_cases())
def test_random_mask_exact_n_per_group(spec):
    k = spec.m * spec.block * 3
    o = spec.out_tile * 2
    mask = sp.random_unit_mask(jax.random.PRNGKey(0), spec, k, o)
    assert bool(sp.check_unit_mask(mask, spec))
    assert abs(float(mask.mean()) - spec.density) < 1e-6


@pytest.mark.parametrize("spec", _spec_cases())
def test_compact_roundtrip(spec):
    k, o = spec.m * spec.block * 2, spec.out_tile * 3
    mask = sp.random_unit_mask(jax.random.PRNGKey(1), spec, k, o)
    idx = sp.compact_indices(mask, spec)
    assert idx.shape[1] == spec.n
    back = sp.indices_to_unit_mask(idx, spec)
    assert bool((back == mask).all())


@pytest.mark.parametrize("spec", _spec_cases())
def test_densify_matches_masked(spec):
    k, o = spec.m * spec.block * 2, spec.out_tile * 2
    w = jax.random.normal(jax.random.PRNGKey(2), (k, o))
    mask = sp.random_unit_mask(jax.random.PRNGKey(3), spec, k, o)
    idx = sp.compact_indices(mask, spec)
    vals = sp.compact_values(w, idx, spec)
    dense = sp.densify_values(vals, idx, spec, k, o)
    np.testing.assert_allclose(dense, sp.apply_mask(w, mask, spec), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 4), mult=st.integers(1, 3), groups=st.integers(1, 4),
       o=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_property_random_mask_invariant(n, mult, groups, o, seed):
    m = n * mult + (0 if mult > 1 else 1)  # ensure n <= m
    m = max(m, n)
    spec = sp.NMSpec(n=n, m=m)
    mask = sp.random_unit_mask(jax.random.PRNGKey(seed), spec, m * groups, o)
    counts = np.asarray(mask).reshape(groups, m, o).sum(axis=1)
    assert (counts == n).all()


def test_memory_accounting_paper_point():
    """Chip config: 80% sparsity cuts weight-value memory by exactly 80%;
    value+9-bit-index storage still beats dense by >55% (8-bit weights)."""
    spec = sp.paper_spec_4groups(512, sparsity=0.8)
    bits = sp.memory_bits(512, 512, spec, weight_bits=8)
    value_only = spec.density
    assert abs(value_only - (1 - 0.797)) < 0.02   # n=26/m=128 ≈ 20.3% kept
    assert bits["reduction"] > 0.55
    assert bits["compact_bits"] < bits["dense_bits"]


def test_unit_scores_reduction_modes():
    spec = sp.NMSpec(2, 4, block=2, out_tile=4)
    x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4) - 10
    s = sp.unit_scores(x, spec, 8, 4)
    assert s.shape == (4, 1)
    expected = np.abs(np.asarray(x)).reshape(4, 2, 1, 4).sum(axis=(1, 3))
    np.testing.assert_allclose(s, expected)
