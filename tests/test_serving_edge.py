"""Serving edge cases: ring-buffer windows, long decode, prefill handoff."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T


def test_swa_ring_cache_crossing_window():
    """Decode far past the SWA window: ring cache must equal full forward."""
    cfg = dataclasses.replace(C.get_reduced("mixtral_8x7b"),
                              moe_capacity_factor=16.0)   # window 8, no drops
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 24                                          # 3x the window
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits, _ = T.forward(params, cfg, tokens=toks)
    cache = T.init_cache(cfg, b, s)                       # ring: len == window
    assert cache["k"].shape[2] == cfg.swa_window
    for t in range(s):
        lg, cache = T.decode_step(params, cache, toks[:, t], cfg)
        err = float(jnp.abs(lg - logits[:, t]).max())
        assert err < 2e-4, (t, err)


def test_prefill_then_decode_matches_forward():
    """generate() greedy continuation == argmax of teacher-forced forward."""
    cfg = C.get_reduced("phi3_medium_14b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    last, cache = T.prefill(params, cfg, toks, max_seq=s + 4)
    logits, _ = T.forward(params, cfg, tokens=toks)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1]),
                               atol=2e-4, rtol=2e-4)
    assert int(cache["pos"]) == s
    # one decode step from the prefilled cache == forward on extended seq
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    lg2, cache = T.decode_step(params, cache, nxt, cfg)
    ext = jnp.concatenate([toks, nxt[:, None]], axis=1)
    logits_ext, _ = T.forward(params, cfg, tokens=ext)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(logits_ext[:, -1]),
                               atol=2e-4, rtol=2e-4)


def test_ssm_prefill_replay():
    cfg = C.get_reduced("mamba2_2p7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    last, cache = T.prefill(params, cfg, toks, max_seq=16)
    logits, _ = T.forward(params, cfg, tokens=toks)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1]),
                               atol=2e-4, rtol=2e-4)


def test_long_decode_stays_finite():
    """Decode 3x beyond the training-ish context: no NaN/inf drift (RoPE +
    ring caches + SSD recurrence are all unbounded-horizon safe)."""
    for arch in ("mixtral_8x7b", "zamba2_1p2b"):
        cfg = dataclasses.replace(C.get_reduced(arch), moe_capacity_factor=8.0) \
            if arch == "mixtral_8x7b" else C.get_reduced(arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        cache = T.init_cache(cfg, 2, 64)
        tok = jnp.zeros((2,), jnp.int32)
        step = jax.jit(lambda c, t: T.decode_step(params, c, t, cfg))
        for _ in range(48):
            lg, cache = step(cache, tok)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
        assert bool(jnp.isfinite(lg).all()), arch


def test_sample_key_chain_distinct_lineage():
    """Every sampled position gets its own key; none of them is the root.

    Regression: ``generate`` used to sample the first token with the unsplit
    root rng and then re-split that same root for later positions, so the
    first sample shared lineage with every subsequent key.
    """
    from repro.launch.serve import sample_key_chain
    root = jax.random.PRNGKey(7)
    n = 6
    keys = np.asarray(sample_key_chain(root, n))
    assert keys.shape[0] == n
    assert len(np.unique(keys, axis=0)) == n            # all positions differ
    assert not (keys == np.asarray(root)).all(-1).any()  # root never sampled
    # deterministic: the chain is a pure function of the root
    np.testing.assert_array_equal(
        keys, np.asarray(sample_key_chain(jax.random.PRNGKey(7), n)))


def test_generate_sampling_uses_key_chain():
    """Temperature sampling is reproducible per root key and actually uses
    distinct per-position keys (first token not tied to the root)."""
    from repro.launch.serve import generate
    cfg = C.get_reduced("phi3_medium_14b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab)
    a = generate(params, cfg, prompt, n_new=5, temperature=1.0,
                 rng=jax.random.PRNGKey(3))
    b = generate(params, cfg, prompt, n_new=5, temperature=1.0,
                 rng=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
